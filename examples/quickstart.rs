//! Quickstart: the smallest end-to-end CoCoDC run.
//!
//! Loads the `tiny` preset (2-layer transformer), simulates M=2 datacenters
//! for 60 local steps with H=10 and τ=2, and prints the validation curve.
//! Runs on the PJRT artifacts when present, or the pure-rust native backend
//! otherwise — no artifacts needed:
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::runtime::{load_backend, Backend, BackendKind};
use cocodc::Trainer;

fn main() -> anyhow::Result<()> {
    let backend =
        load_backend(BackendKind::Auto, std::path::Path::new("artifacts"), "tiny", false)?;
    println!(
        "loaded tiny preset on {} ({} params, K={} fragments)",
        backend.platform(),
        backend.param_count(),
        backend.fragments().k()
    );

    let mut cfg = RunConfig::paper("tiny", MethodKind::Cocodc);
    cfg.workers = 2;
    cfg.h_steps = 10;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 60;
    cfg.eval_every = 10;
    cfg.eval_batches = 4;

    let mut trainer = Trainer::new(backend.as_ref(), cfg)?;
    trainer.verbose = true;
    let out = trainer.run()?;

    println!("\nvalidation curve (step, loss, ppl):");
    for p in &out.curve.points {
        println!("  {:>4}  {:.4}  {:.2}", p.step, p.loss, p.ppl);
    }
    println!(
        "\ncompleted {} fragment syncs ({} initiated), {:.2} MB over the WAN, \
         virtual wall-clock {:.1}s",
        out.syncs_completed, out.syncs_initiated, out.bytes_sent / 1e6, out.wall_s
    );
    let first = out.curve.points.first().unwrap().loss;
    let last = out.curve.points.last().unwrap().loss;
    anyhow::ensure!(last < first, "loss should decrease (got {first} -> {last})");
    println!("loss decreased {first:.3} -> {last:.3}: quickstart OK");
    Ok(())
}
