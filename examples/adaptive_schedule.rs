//! Adaptive transmission (Alg. 2) in isolation — no PJRT required.
//!
//! Simulates workers whose parameter fragments drift at very different
//! rates (fragment 2 is 10× "hotter" than the rest) and shows how CoCoDC's
//! change-rate metric R_p = ‖Δθ_p^g‖₂/I_p steers extra synchronizations to
//! the hot fragment while the staleness guard keeps every fragment within
//! one H window — versus Streaming DiLoCo's rigid round-robin.
//!
//! ```text
//! cargo run --release --example adaptive_schedule
//! ```

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::coordinator::strategy::SyncCtx;
use cocodc::coordinator::{make_strategy, FragmentTable, GlobalState, SyncStats};
use cocodc::network::WanSimulator;
use cocodc::runtime::{Backend, HostBackend, WorkerHandle};
use cocodc::simclock::VirtualClock;
use cocodc::util::pool::BufferPool;
use cocodc::util::Rng;

fn run_method(method: MethodKind, steps: u32) -> anyhow::Result<(String, Vec<usize>, usize)> {
    let frags = FragmentTable::from_sizes(&[1000, 1000, 1000, 1000]);
    let mut cfg = RunConfig::paper("sim", method);
    cfg.h_steps = 100;
    cfg.tau = TauMode::Fixed { tau: 5 };
    cfg.gamma = 0.4;
    // T_s such that gamma*H*T_c/T_s = 8 syncs per H (paper's setting).
    cfg.network.step_compute_s = 0.15;
    cfg.network.latency_s = 0.1237;
    cfg.network.bandwidth_bps = 125e6;

    // Model-free host backend: resident flat vectors we drift by hand.
    let backend = HostBackend::new(frags.clone());
    let mut workers: Vec<WorkerHandle> = (0..cfg.workers)
        .map(|_| backend.create_worker())
        .collect::<anyhow::Result<_>>()?;
    let mut global = GlobalState::new(&backend.init_params()?);
    let mut net = WanSimulator::new(cfg.network, cfg.workers, 7);
    let mut clock = VirtualClock::new();
    let mut stats = SyncStats::new(frags.k());
    let mut pool = BufferPool::new();
    let mut strategy = make_strategy(&cfg, &frags);
    let mut rng = Rng::new(42, 0);

    // Per-fragment drift rates: fragment 2 changes 10x faster.
    let rates = [0.01f32, 0.01, 0.10, 0.01];
    for step in 1..=steps {
        for w in workers.iter_mut() {
            let st = backend.state_mut(w);
            for p in 0..frags.k() {
                let f = frags.get(p);
                for x in st.params[f.range()].iter_mut() {
                    *x += rates[p] * (1.0 + 0.1 * rng.next_gaussian() as f32);
                }
            }
            st.step = step;
        }
        clock.advance_compute(cfg.network.step_compute_s);
        let mut ctx = SyncCtx {
            workers: &mut workers,
            global: &mut global,
            net: &mut net,
            clock: &mut clock,
            backend: &backend,
            cfg: &cfg,
            frags: &frags,
            stats: &mut stats,
            pool: &mut pool,
            threads: None,
            live: None,
        };
        strategy.post_step(step, &mut ctx)?;
    }
    Ok((
        strategy.name().to_string(),
        stats.per_fragment.clone(),
        stats.staleness_guard_hits,
    ))
}

fn main() -> anyhow::Result<()> {
    println!("600 simulated steps, H=100, fragment 2 drifts 10x faster:\n");
    println!(
        "{:<18} {:>6} {:>6} {:>6} {:>6}  guard_hits",
        "method", "f0", "f1", "f2", "f3"
    );
    for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
        let (name, counts, guards) = run_method(method, 600)?;
        println!(
            "{:<18} {:>6} {:>6} {:>6} {:>6}  {guards}",
            name, counts[0], counts[1], counts[2], counts[3]
        );
    }
    println!(
        "\nStreaming DiLoCo synchronizes each fragment exactly once per H;\n\
         CoCoDC reinvests the idle network budget (N=8 syncs/H at gamma=0.4)\n\
         into the hot fragment while the staleness guard bounds the others."
    );
    Ok(())
}
