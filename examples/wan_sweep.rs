//! WAN sweep: where does overlapping win? Sweeps inter-DC latency and
//! bandwidth with τ derived from the network model and reports the virtual
//! wall-clock each method needs for a fixed number of steps — reproducing
//! the paper's §I motivation (DiLoCo's blocking sync dominates as the WAN
//! degrades) quantitatively.
//!
//! ```text
//! cargo run --release --example wan_sweep -- [--preset tiny] [--steps 120]
//! ```
//!
//! Artifact-free: runs the native backend when no artifacts are present.

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::runtime::{load_backend, BackendKind};
use cocodc::util::cli::Args;
use cocodc::Trainer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let preset = args.get("preset").unwrap_or("tiny").to_string();
    let steps: u32 = args.get_or("steps", 120)?;
    let kind = BackendKind::parse(args.get("backend").unwrap_or("auto"))?;
    args.finish()?;
    let backend = load_backend(kind, std::path::Path::new("artifacts"), &preset, false)?;

    println!(
        "{:>9} {:>10} | {:>12} {:>12} {:>12} | winner",
        "latency", "bandwidth", "diloco", "streaming", "cocodc"
    );
    for (lat_ms, bw_mbps) in [
        (5.0, 1000.0),
        (50.0, 1000.0),
        (50.0, 100.0),
        (150.0, 100.0),
        (150.0, 25.0),
        (300.0, 10.0),
    ] {
        let mut walls = Vec::new();
        for method in MethodKind::all() {
            let mut cfg = RunConfig::paper(&preset, method);
            cfg.total_steps = steps;
            cfg.h_steps = 20;
            cfg.tau = TauMode::Network;
            cfg.eval_every = steps; // only final eval; this sweep times comms
            cfg.eval_batches = 2;
            cfg.network.latency_s = lat_ms / 1e3;
            cfg.network.bandwidth_bps = bw_mbps * 1e6 / 8.0;
            cfg.network.step_compute_s = 0.05;
            let mut tr = Trainer::new(backend.as_ref(), cfg)?;
            let out = tr.run()?;
            walls.push((method.name(), out.wall_s));
        }
        let winner = walls
            .iter()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|w| w.0)
            .unwrap();
        println!(
            "{:>7}ms {:>6}Mbps | {:>11.1}s {:>11.1}s {:>11.1}s | {winner}",
            lat_ms, bw_mbps, walls[0].1, walls[1].1, walls[2].1
        );
    }
    println!(
        "\n(overlapped methods hold wall-clock near compute-bound as the WAN \
         degrades; DiLoCo pays 2(M-1)L + S/B per round, serialized)"
    );
    Ok(())
}
