//! Degraded-mode resilience (DESIGN.md §Faults): the same scripted fault
//! plan — a mid-run link outage, in-flight transfer loss, and one worker
//! crashing and rejoining — driven through all three methods on the native
//! backend (no artifacts needed).
//!
//! DiLoCo's blocking all-reduce eats the outage as a dead stall on the
//! critical path; Streaming DiLoCo keeps computing and retries/requeues the
//! dropped fragments; CoCoDC additionally feeds the observed transfer times
//! into its Eq. 9 schedule (the EWMA T_s estimate backs the sync rate off
//! to its K floor during the outage) and renormalizes the pseudo-gradient
//! mean over the surviving quorum while the worker is down.
//!
//! ```text
//! cargo run --release --example fault_tolerance -- [--steps 240]
//! ```

use cocodc::config::{CrashWindow, FaultConfig, FaultWindow, MethodKind, RunConfig, TauMode};
use cocodc::runtime::{load_backend, Backend, BackendKind};
use cocodc::util::cli::Args;
use cocodc::{TrainOutcome, Trainer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let steps: u32 = args.get_or("steps", 240)?;
    let kind = BackendKind::parse(args.get("backend").unwrap_or("native"))?;
    args.finish()?;
    let backend = load_backend(kind, std::path::Path::new("artifacts"), "tiny", false)?;

    // One shared fault plan on the virtual clock: the outage opens a third
    // of the way in and spans two DiLoCo sync points; the last worker is
    // down for a stretch inside it; every transfer has a 15% chance of
    // being lost in flight (retried with exponential backoff).
    let horizon = steps as f64 * 0.15; // T_c = 0.15 s/step on this preset
    let plan = FaultConfig {
        outages: vec![FaultWindow {
            start_s: 0.30 * horizon,
            duration_s: 0.35 * horizon,
        }],
        transfer_loss_prob: 0.25,
        crashes: vec![CrashWindow {
            worker: 3,
            window: FaultWindow { start_s: 0.50 * horizon, duration_s: 0.20 * horizon },
        }],
        ..Default::default()
    };
    println!(
        "fault plan over a ~{horizon:.0}s horizon: outage {:.0}s-{:.0}s, 25% transfer \
         loss, worker 3 down {:.0}s-{:.0}s\n",
        plan.outages[0].start_s,
        plan.outages[0].end_s(),
        plan.crashes[0].window.start_s,
        plan.crashes[0].window.end_s(),
    );

    let mut outcomes: Vec<TrainOutcome> = Vec::new();
    for method in MethodKind::all() {
        let mut cfg = RunConfig::paper("tiny", method);
        cfg.total_steps = steps;
        cfg.eval_every = steps;
        cfg.h_steps = 40; // several blocking rounds land inside the outage
        cfg.tau = TauMode::Network; // let the outage stretch τ, not crash it
        cfg.faults = plan.clone();
        let mut tr = Trainer::new(backend.as_ref(), cfg)?;
        let out = tr.run()?;
        println!(
            "[{:<16}] wall {:>6.0}s = compute {:>5.0}s + stall {:>5.0}s | \
             syncs {:>3} | retries {:>3} drops {:>3} timeouts {:>2} requeues {:>2} | \
             final loss {:.3}",
            out.method,
            out.wall_s,
            out.compute_s,
            out.comm_stall_s,
            out.syncs_completed,
            out.retries,
            out.drops,
            out.timeouts,
            out.requeues,
            out.final_train_loss,
        );
        outcomes.push(out);
    }

    let get = |name: &str| {
        outcomes
            .iter()
            .find(|o| o.method == name)
            .expect("all three methods ran")
    };
    let (diloco, cocodc) = (get("diloco"), get("cocodc"));
    println!(
        "\nDiLoCo spent {:.0}s stalled on the blocked link; CoCoDC overlapped the \
         outage away ({:.0}s stalled) and kept training on the surviving quorum.",
        diloco.comm_stall_s, cocodc.comm_stall_s
    );
    anyhow::ensure!(
        cocodc.comm_stall_s < diloco.comm_stall_s,
        "overlap must beat blocking under the same fault plan"
    );
    let mut activity = 0usize;
    for o in &outcomes {
        anyhow::ensure!(o.final_train_loss.is_finite(), "{} diverged under faults", o.method);
        activity += o.retries + o.drops + o.timeouts + o.requeues;
    }
    anyhow::ensure!(activity > 0, "no fault activity at all — the plan never touched the runs");
    println!("fault tolerance OK: all methods finished, overlap beat blocking");
    Ok(())
}
