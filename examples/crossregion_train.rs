//! End-to-end driver (DESIGN.md "End-to-end validation"): trains the `e2e`
//! transformer preset across M=4 simulated datacenters on the non-IID
//! synthetic-C4 corpus with all three methods and logs the loss curves —
//! the full three-layer stack (rust coordinator → PJRT → HLO train step →
//! Pallas flash-attention/AdamW kernels) composing on a real workload.
//!
//! ```text
//! cargo run --release --example crossregion_train -- [--steps 300] \
//!     [--preset e2e] [--methods cocodc,streaming,diloco] \
//!     [--backend auto|pjrt|native] [--out results/e2e.csv]
//! ```
//!
//! Runs against `artifacts/e2e` when built (`make artifacts`), or the
//! pure-rust native backend otherwise. Recorded in EXPERIMENTS.md
//! §End-to-end.

use cocodc::config::{MethodKind, RunConfig};
use cocodc::metrics::{table1, write_curves_csv};
use cocodc::runtime::{load_backend, Backend, BackendKind};
use cocodc::util::cli::Args;
use cocodc::Trainer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    let preset = args.get("preset").unwrap_or("e2e").to_string();
    let steps: u32 = args.get_or("steps", 300)?;
    let out_path = args.get("out").unwrap_or("results/e2e.csv").to_string();
    let methods: Vec<MethodKind> = args
        .get("methods")
        .unwrap_or("diloco,streaming,cocodc")
        .split(',')
        .map(MethodKind::parse)
        .collect::<anyhow::Result<_>>()?;
    let kind = BackendKind::parse(args.get("backend").unwrap_or("auto"))?;
    args.finish()?;

    let backend = load_backend(kind, std::path::Path::new("artifacts"), &preset, false)?;
    let model = backend.model();
    println!(
        "e2e: {}-param LLaMA-style transformer ({} layers, d={}, vocab={}) on {}, \
         M=4 simulated DCs, non-IID synthetic-C4",
        backend.param_count(), model.n_layers, model.d_model,
        model.vocab_size, backend.platform()
    );

    let mut curves = Vec::new();
    for method in methods {
        // Paper §IV-A scaled: H=50 so several outer rounds fit in the run.
        let mut cfg = RunConfig::paper(&preset, method);
        cfg.total_steps = steps;
        cfg.h_steps = 50;
        cfg.eval_every = 20;
        cfg.eval_batches = 6;
        let mut trainer = Trainer::new(backend.as_ref(), cfg)?;
        trainer.verbose = true;
        let out = trainer.run()?;
        println!(
            "[{}] final val loss {:.4} (ppl {:.2}), wall {:.0}s, {} syncs, real {:.0}s\n",
            out.method,
            out.curve.final_loss().unwrap_or(f64::NAN),
            out.curve.final_ppl().unwrap_or(f64::NAN),
            out.wall_s,
            out.syncs_completed,
            out.real_s,
        );
        curves.push(out.curve);
    }

    write_curves_csv(&out_path, &curves)?;
    println!("curves -> {out_path}");
    // The synthetic task reaches "interesting" PPL fast; report a mid-curve
    // threshold for the steps-to-PPL comparison.
    let thr = curves
        .iter()
        .filter_map(|c| c.best_ppl())
        .fold(f64::NEG_INFINITY, f64::max)
        * 1.15;
    println!("{}", table1(&curves, thr));
    for c in &curves {
        let (first, last) = (
            c.points.first().unwrap().loss,
            c.points.last().unwrap().loss,
        );
        anyhow::ensure!(
            last < first,
            "{}: loss must decrease ({first:.3} -> {last:.3})",
            c.method
        );
    }
    println!("all methods converged: e2e OK");
    Ok(())
}
