"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the dist-train guide; every property is
checked with assert_allclose against kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.attention import flash_attention, _block_for
from compile.kernels.elementwise import (BLOCK, delay_comp, fused_adamw,
                                         outer_step)

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 4),
    t_pow=st.integers(3, 7),  # T in {8..128}
    dh=st.sampled_from([8, 16, 32, 48]),
    seed=st.integers(0, 2**16),
)
def test_attention_forward_matches_ref(n, t_pow, dh, seed):
    T = 2**t_pow
    key = jax.random.PRNGKey(seed)
    q, k, v = (_rand(jax.random.fold_in(key, i), (n, T, dh)) for i in range(3))
    got = flash_attention(q, k, v)
    want = ref.ref_attention(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(max_examples=6, deadline=None)
@given(
    t_pow=st.integers(3, 6),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_gradients_match_ref(t_pow, dh, seed):
    T = 2**t_pow
    key = jax.random.PRNGKey(seed)
    q, k, v = (_rand(jax.random.fold_in(key, i), (2, T, dh)) for i in range(3))
    w = _rand(jax.random.fold_in(key, 9), (2, T, dh))

    def lp(q, k, v):
        return jnp.sum(flash_attention(q, k, v) * w)

    def lr_(q, k, v):
        return jnp.sum(ref.ref_attention(q, k, v) * w)

    gp = jax.grad(lp, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr_, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5, rtol=5e-5)


def test_attention_is_causal():
    """Perturbing future positions must not change earlier outputs."""
    key = jax.random.PRNGKey(0)
    T, dh = 32, 16
    q, k, v = (_rand(jax.random.fold_in(key, i), (1, T, dh)) for i in range(3))
    o1 = flash_attention(q, k, v)
    k2 = k.at[:, T // 2:, :].set(99.0)
    v2 = v.at[:, T // 2:, :].set(-99.0)
    o2 = flash_attention(q, k2, v2)
    assert_allclose(np.asarray(o1[:, : T // 2]), np.asarray(o2[:, : T // 2]),
                    atol=1e-5)
    assert not np.allclose(np.asarray(o1[:, T // 2:]),
                           np.asarray(o2[:, T // 2:]))


def test_block_for_divides():
    for T in (8, 16, 24, 64, 128, 1024):
        assert T % _block_for(T) == 0


def test_attention_softmax_rows_sum_to_one():
    """o must be a convex combination of v rows: with constant v, o == v."""
    T, dh = 16, 8
    key = jax.random.PRNGKey(1)
    q, k = (_rand(jax.random.fold_in(key, i), (1, T, dh)) for i in range(2))
    v = jnp.ones((1, T, dh), jnp.float32) * 3.5
    o = flash_attention(q, k, v)
    assert_allclose(np.asarray(o), 3.5 * np.ones_like(o), atol=1e-5)


# ---------------------------------------------------------------------------
# fused AdamW
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    p_size=st.sampled_from([1, 17, 1000, BLOCK, BLOCK + 3, 2 * BLOCK + 11]),
    step=st.integers(1, 10_000),
    lr=st.floats(1e-6, 1e-1),
    seed=st.integers(0, 2**16),
)
def test_adamw_matches_ref(p_size, step, lr, seed):
    key = jax.random.PRNGKey(seed)
    p, m, g = (_rand(jax.random.fold_in(key, i), (p_size,)) for i in range(3))
    v = jnp.abs(_rand(jax.random.fold_in(key, 7), (p_size,)))
    kw = dict(beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.1)
    got = fused_adamw(p, m, v, g, jnp.float32(lr), jnp.float32(step), **kw)
    want = ref.ref_adamw(p, m, v, g, lr, float(step), **kw)
    for a, b in zip(got, want):
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4)


def test_adamw_zero_grad_is_pure_decay():
    p = jnp.ones((100,), jnp.float32)
    z = jnp.zeros_like(p)
    kw = dict(beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.1)
    p2, m2, v2 = fused_adamw(p, z, z, z, jnp.float32(0.01), jnp.float32(1.0), **kw)
    assert_allclose(np.asarray(p2), np.asarray(p * (1 - 0.01 * 0.1)), rtol=1e-6)
    assert float(jnp.max(jnp.abs(m2))) == 0.0
    assert float(jnp.max(jnp.abs(v2))) == 0.0


# ---------------------------------------------------------------------------
# delay compensation (CoCoDC Alg. 1)
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    size=st.sampled_from([3, 100, BLOCK + 5]),
    tau=st.integers(1, 50),
    H=st.integers(1, 500),
    lam=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**16),
)
def test_delay_comp_matches_ref(size, tau, H, lam, seed):
    key = jax.random.PRNGKey(seed)
    tg, tl, tp = (_rand(jax.random.fold_in(key, i), (size,)) for i in range(3))
    got = delay_comp(tg, tl, tp, jnp.float32(tau), jnp.float32(H),
                     jnp.float32(lam))
    want = ref.ref_delay_comp(tg, tl, tp, tau=tau, H=H, lam=lam)
    assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_delay_comp_lambda_zero_is_linear_extrapolation():
    """lam=0: theta' = theta_g + (theta_tl - theta_tp)."""
    key = jax.random.PRNGKey(3)
    tg, tl, tp = (_rand(jax.random.fold_in(key, i), (64,)) for i in range(3))
    got = delay_comp(tg, tl, tp, jnp.float32(7.0), jnp.float32(100.0),
                     jnp.float32(0.0))
    assert_allclose(np.asarray(got), np.asarray(tg + (tl - tp)), atol=1e-5)


def test_delay_comp_no_local_movement_adopts_global():
    """If the local model did not move during overlap, theta' == theta_g."""
    key = jax.random.PRNGKey(4)
    tg = _rand(key, (64,))
    tl = _rand(jax.random.fold_in(key, 1), (64,))
    got = delay_comp(tg, tl, tl, jnp.float32(5.0), jnp.float32(100.0),
                     jnp.float32(0.5))
    assert_allclose(np.asarray(got), np.asarray(tg), atol=1e-6)


# ---------------------------------------------------------------------------
# Nesterov outer step
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    size=st.sampled_from([2, 333, BLOCK + 1]),
    lr=st.floats(0.01, 1.0),
    mu=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**16),
)
def test_outer_step_matches_ref(size, lr, mu, seed):
    key = jax.random.PRNGKey(seed)
    tg, dl, mom = (_rand(jax.random.fold_in(key, i), (size,)) for i in range(3))
    got = outer_step(tg, dl, mom, jnp.float32(lr), jnp.float32(mu))
    want = ref.ref_outer_step(tg, dl, mom, lr=lr, momentum=mu)
    for a, b in zip(got, want):
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4)


def test_outer_step_zero_momentum_is_sgd_toward_consensus():
    """mu=0, lr=1: theta' = theta + delta (full adoption of the average)."""
    key = jax.random.PRNGKey(5)
    tg, dl = (_rand(jax.random.fold_in(key, i), (32,)) for i in range(2))
    t2, m2 = outer_step(tg, dl, jnp.zeros_like(tg), jnp.float32(1.0),
                        jnp.float32(0.0))
    assert_allclose(np.asarray(t2), np.asarray(tg + dl), atol=1e-6)
    assert_allclose(np.asarray(m2), np.asarray(-dl), atol=1e-6)
