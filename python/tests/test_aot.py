"""AOT pipeline tests: HLO text emission, meta.json consistency, and that the
emitted artifacts include what the rust coordinator will look up."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.config import MODEL_PRESETS, flat_layout

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_parseable_text():
    def fn(x, y):
        return (x @ y + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "ENTRY" in text
    # 64-bit-id protos are exactly what we avoid; text must be plain ASCII.
    text.encode("ascii")


@pytest.mark.parametrize("preset", ["tiny", "exp"])
def test_artifact_dir_complete(preset):
    d = os.path.join(ART, preset)
    if not os.path.isdir(d):
        pytest.skip(f"run `make artifacts` first ({d} missing)")
    meta = json.load(open(os.path.join(d, "meta.json")))
    # every referenced artifact exists
    for rel in meta["artifacts"].values():
        assert os.path.isfile(os.path.join(d, rel)), rel
    for names in meta["fragment_artifacts"].values():
        for stem in names.values():
            assert os.path.isfile(os.path.join(d, stem + ".hlo.txt")), stem
    # init params match param_count
    init = np.fromfile(os.path.join(d, "init_params.bin"), np.float32)
    assert init.shape[0] == meta["param_count"]
    # fragment table is consistent with a fresh flat_layout
    cfg = MODEL_PRESETS[preset]
    leaves, fragments, total = flat_layout(cfg, meta["n_fragments"])
    assert total == meta["param_count"]
    assert fragments == meta["fragments"]
    assert leaves == meta["leaves"]


@pytest.mark.parametrize("preset", ["tiny"])
def test_artifact_hlo_signature_shapes(preset):
    """The train_step HLO entry must carry the shapes meta.json promises."""
    d = os.path.join(ART, preset)
    if not os.path.isdir(d):
        pytest.skip("run `make artifacts` first")
    meta = json.load(open(os.path.join(d, "meta.json")))
    text = open(os.path.join(d, "train_step.hlo.txt")).read()
    P = meta["param_count"]
    B, T = meta["model"]["batch_size"], meta["model"]["seq_len"]
    assert f"f32[{P}]" in text
    assert f"s32[{B},{T}]" in text
