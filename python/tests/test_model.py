"""L2 model tests: flat layout invariants, forward shapes, loss sanity,
training-step behaviour, LR schedule."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import (MODEL_PRESETS, TRAIN_PRESETS, flat_layout,
                            fragment_of, leaf_specs)
from compile.model import forward, init_flat, loss_fn, param_count, unflatten
from compile.train import lr_schedule, make_eval_step, make_train_step

CFG = MODEL_PRESETS["tiny"]
TC = TRAIN_PRESETS["tiny"]
K = 2  # tiny has 2 layers


# ---------------------------------------------------------------------------
# flat layout
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(preset=st.sampled_from(["tiny", "exp", "e2e"]), k=st.integers(1, 8))
def test_flat_layout_partition_invariants(preset, k):
    """Fragments are disjoint, contiguous, exhaustive; every leaf lives in
    exactly one fragment and inside its fragment's range."""
    cfg = MODEL_PRESETS[preset]
    k = min(k, cfg.n_layers)
    leaves, fragments, total = flat_layout(cfg, k)
    assert total == param_count(cfg)
    # fragments tile [0, total)
    off = 0
    for f in fragments:
        assert f["offset"] == off
        assert f["size"] > 0
        off += f["size"]
    assert off == total
    # leaves tile [0, total) and respect fragment containment
    seen = set()
    for leaf in leaves:
        assert leaf["name"] not in seen
        seen.add(leaf["name"])
        f = fragments[leaf["fragment"]]
        assert f["offset"] <= leaf["offset"]
        assert leaf["offset"] + leaf["size"] <= f["offset"] + f["size"]
    assert sum(l["size"] for l in leaves) == total
    assert len(seen) == len(leaf_specs(cfg))


def test_strided_fragment_assignment():
    """Paper/Streaming-DiLoCo strided pattern: layer l -> shard l % K."""
    for l in range(12):
        assert fragment_of(l, 4) == l % 4
    assert fragment_of(-1, 4) == 0       # embedding -> first shard
    assert fragment_of(-2, 4) == 3       # head -> last shard


def test_unflatten_round_trips_leaves():
    leaves, _, total = flat_layout(CFG, K)
    flat = jnp.arange(total, dtype=jnp.float32)
    tree = unflatten(flat, CFG, K)
    for leaf in leaves:
        want = np.arange(leaf["offset"], leaf["offset"] + leaf["size"],
                         dtype=np.float32).reshape(leaf["shape"])
        np.testing.assert_array_equal(np.asarray(tree[leaf["name"]]), want)


def test_init_flat_deterministic_and_normalized():
    a = init_flat(CFG, K, seed=7)
    b = init_flat(CFG, K, seed=7)
    c = init_flat(CFG, K, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    tree = unflatten(jnp.asarray(a), CFG, K)
    np.testing.assert_array_equal(np.asarray(tree["layer0.attn_norm"]), 1.0)
    assert abs(float(np.std(np.asarray(tree["embed"])) - 0.02)) < 5e-3


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def _batch(seed=0):
    rng = np.random.default_rng(seed)
    tok = rng.integers(0, CFG.vocab_size, (CFG.batch_size, CFG.seq_len))
    return (jnp.asarray(tok, jnp.int32),
            jnp.asarray(np.roll(tok, -1, 1), jnp.int32))


def test_forward_shapes_and_finite():
    flat = jnp.asarray(init_flat(CFG, K))
    tok, _ = _batch()
    logits = forward(flat, tok, CFG, K)
    assert logits.shape == (CFG.batch_size, CFG.seq_len, CFG.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    """At init the model should be ~uniform over the vocab."""
    flat = jnp.asarray(init_flat(CFG, K))
    tok, tgt = _batch()
    loss = loss_fn(flat, tok, tgt, CFG, K)
    assert abs(float(loss) - np.log(CFG.vocab_size)) < 0.3


def test_pallas_and_ref_attention_models_agree():
    cfg_ref = dataclasses.replace(CFG, use_pallas_attention=False)
    flat = jnp.asarray(init_flat(CFG, K))
    tok, tgt = _batch()
    l1 = loss_fn(flat, tok, tgt, CFG, K)
    l2 = loss_fn(flat, tok, tgt, cfg_ref, K)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_causality_of_full_model():
    flat = jnp.asarray(init_flat(CFG, K))
    tok, _ = _batch()
    logits1 = forward(flat, tok, CFG, K)
    tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % CFG.vocab_size)
    logits2 = forward(flat, tok2, CFG, K)
    np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                               np.asarray(logits2[:, :-1]), atol=1e-5)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def test_train_step_reduces_loss_on_fixed_batch():
    step_fn = jax.jit(make_train_step(CFG, TC, K))
    flat = jnp.asarray(init_flat(CFG, K))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    tok, tgt = _batch()
    losses = []
    for i in range(30):
        flat, m, v, loss = step_fn(flat, m, v, jnp.float32(i), tok, tgt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses[::10]
    assert all(np.isfinite(losses))


def test_train_step_updates_every_fragment():
    _, fragments, _ = flat_layout(CFG, K)
    step_fn = jax.jit(make_train_step(CFG, TC, K))
    flat0 = jnp.asarray(init_flat(CFG, K))
    z = jnp.zeros_like(flat0)
    tok, tgt = _batch()
    flat1, _, _, _ = step_fn(flat0, z, z, jnp.float32(0), tok, tgt)
    d = np.asarray(jnp.abs(flat1 - flat0))
    for f in fragments:
        assert d[f["offset"]:f["offset"] + f["size"]].max() > 0.0


def test_eval_step_matches_loss_fn():
    eval_fn = jax.jit(make_eval_step(CFG, K))
    flat = jnp.asarray(init_flat(CFG, K))
    tok, tgt = _batch()
    (l1,) = eval_fn(flat, tok, tgt)
    l2 = loss_fn(flat, tok, tgt, CFG, K)
    assert abs(float(l1) - float(l2)) < 1e-6


# ---------------------------------------------------------------------------
# LR schedule
# ---------------------------------------------------------------------------
def test_lr_schedule_warmup_and_decay():
    tc = TRAIN_PRESETS["exp"]
    lrs = [float(lr_schedule(jnp.float32(s), tc))
           for s in (0, tc.warmup_steps // 2, tc.warmup_steps,
                     tc.total_steps // 2, tc.total_steps)]
    assert lrs[0] < lrs[1] < lrs[2]                    # warmup rises
    assert abs(lrs[2] - tc.lr) / tc.lr < 0.02          # peak ~ lr
    assert lrs[3] < lrs[2]                             # cosine decays
    assert lrs[4] >= tc.lr * tc.min_lr_ratio * 0.99    # floor respected


@settings(max_examples=30, deadline=None)
@given(step=st.floats(0, 4000))
def test_lr_schedule_bounded(step):
    tc = TRAIN_PRESETS["exp"]
    lr = float(lr_schedule(jnp.float32(step), tc))
    assert 0.0 < lr <= tc.lr * 1.001


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def test_rope_preserves_norm_and_zero_position():
    from compile.model import _rope

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 16), jnp.float32)
    y = _rope(x, 10000.0)
    # Rotations preserve per-pair L2 norms.
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # Position 0 has angle 0: unrotated.
    np.testing.assert_allclose(np.asarray(y[:, :, 0]), np.asarray(x[:, :, 0]),
                               atol=1e-6)


def test_rope_is_relative():
    """<rope(q,i), rope(k,j)> must depend only on i-j (decoder RoPE)."""
    from compile.model import _rope

    key = jax.random.PRNGKey(1)
    dh = 16
    q = jax.random.normal(key, (dh,), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (dh,), jnp.float32)
    T = 8

    def dot_at(i, j):
        x = jnp.zeros((1, 1, T, dh)).at[0, 0, i].set(q)
        y = jnp.zeros((1, 1, T, dh)).at[0, 0, j].set(k)
        xr, yr = _rope(x, 10000.0), _rope(y, 10000.0)
        return float(jnp.dot(xr[0, 0, i], yr[0, 0, j]))

    assert abs(dot_at(2, 0) - dot_at(5, 3)) < 1e-4
    assert abs(dot_at(4, 1) - dot_at(6, 3)) < 1e-4


def test_gradient_flows_to_all_leaves():
    leaves, _, _ = flat_layout(CFG, K)
    tok, tgt = _batch()
    flat = jnp.asarray(init_flat(CFG, K))
    g = jax.grad(loss_fn)(flat, tok, tgt, CFG, K)
    g = np.asarray(g)
    for leaf in leaves:
        seg = g[leaf["offset"]:leaf["offset"] + leaf["size"]]
        assert np.abs(seg).max() > 0.0, f"zero gradient in {leaf['name']}"
