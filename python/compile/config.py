"""Model / training configuration shared by the L2 model, the AOT lowering
pipeline, and (via artifacts/<preset>/meta.json) the rust coordinator.

Presets mirror the paper's setup scaled to this testbed (see DESIGN.md §2):
the paper trains a 150M-param, 12-layer LLaMA-style model on C4 with M=4
workers; we keep the architecture family and shrink width/depth so that the
full three-method comparison fits a CPU PJRT budget.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch_size: int  # per-worker micro batch
    rope_theta: float = 10000.0
    use_pallas_attention: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Inner-optimizer (AdamW) hyperparameters, baked into the train_step
    artifact except for `step`, which is a runtime input feeding the
    warmup+cosine schedule (paper §IV-A)."""

    lr: float = 4e-4
    warmup_steps: int = 100
    total_steps: int = 4000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    min_lr_ratio: float = 0.1


# ---------------------------------------------------------------------------
# Presets. `exp` drives the Fig.1/Fig.2/Table I reproduction sweeps; `e2e`
# is the headline end-to-end example; `tiny` keeps unit tests fast;
# `paper150m` is the paper's exact architecture (config only on CPU).
# ---------------------------------------------------------------------------
MODEL_PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(
        name="tiny", vocab_size=64, d_model=32, n_layers=2, n_heads=2,
        d_ff=64, seq_len=16, batch_size=2,
    ),
    "exp": ModelConfig(
        name="exp", vocab_size=256, d_model=64, n_layers=8, n_heads=4,
        d_ff=176, seq_len=64, batch_size=8,
    ),
    "e2e": ModelConfig(
        name="e2e", vocab_size=512, d_model=192, n_layers=8, n_heads=6,
        d_ff=512, seq_len=128, batch_size=8,
    ),
    "paper150m": ModelConfig(
        name="paper150m", vocab_size=32000, d_model=1024, n_layers=12,
        n_heads=16, d_ff=2816, seq_len=1024, batch_size=16,
    ),
}

TRAIN_PRESETS: Dict[str, TrainConfig] = {
    "tiny": TrainConfig(lr=1e-3, warmup_steps=10, total_steps=200),
    "exp": TrainConfig(lr=1e-3, warmup_steps=100, total_steps=4000),
    "e2e": TrainConfig(lr=6e-4, warmup_steps=100, total_steps=2000),
    "paper150m": TrainConfig(lr=4e-4, warmup_steps=1000, total_steps=18000),
}


def leaf_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], int]]:
    """Canonical leaf table: (name, shape, layer). layer == -1 for globals.

    Order here is *canonical model order*; the flat vector is laid out
    fragment-major on top of this (see flat_layout)."""
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    specs: List[Tuple[str, Tuple[int, ...], int]] = [("embed", (V, D), -1)]
    for l in range(cfg.n_layers):
        specs += [
            (f"layer{l}.attn_norm", (D,), l),
            (f"layer{l}.wq", (D, D), l),
            (f"layer{l}.wk", (D, D), l),
            (f"layer{l}.wv", (D, D), l),
            (f"layer{l}.wo", (D, D), l),
            (f"layer{l}.mlp_norm", (D,), l),
            (f"layer{l}.w1", (D, F), l),
            (f"layer{l}.w3", (D, F), l),
            (f"layer{l}.w2", (F, D), l),
        ]
    specs += [("final_norm", (D,), -2), ("lm_head", (D, V), -2)]
    return specs


def fragment_of(layer: int, n_fragments: int) -> int:
    """Strided depth partition, exactly Streaming DiLoCo's scheme: layer l
    belongs to shard l % K. The embedding table joins shard 0; the final
    norm + LM head join shard K-1."""
    if layer == -1:
        return 0
    if layer == -2:
        return n_fragments - 1
    return layer % n_fragments


def flat_layout(cfg: ModelConfig, n_fragments: int):
    """Fragment-major flat layout.

    Returns (leaves, fragments, total) where
      leaves    = [{name, shape, offset, size, fragment}]  in flat order
      fragments = [{index, offset, size}]                  contiguous ranges
      total     = parameter count P
    """
    import numpy as np

    per_frag: List[list] = [[] for _ in range(n_fragments)]
    for name, shape, layer in leaf_specs(cfg):
        per_frag[fragment_of(layer, n_fragments)].append((name, shape))
    leaves, fragments = [], []
    off = 0
    for p in range(n_fragments):
        frag_off = off
        for name, shape in per_frag[p]:
            size = int(np.prod(shape))
            leaves.append(
                {"name": name, "shape": list(shape), "offset": off,
                 "size": size, "fragment": p}
            )
            off += size
        fragments.append({"index": p, "offset": frag_off, "size": off - frag_off})
    return leaves, fragments, off
