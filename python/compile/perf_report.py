"""L2 profiling: XLA cost analysis of the lowered artifacts.

Usage (from python/): python -m compile.perf_report --preset exp

Prints per-artifact FLOPs, bytes accessed, and the arithmetic intensity of
the compiled module, plus a pallas-vs-jnp attention comparison — the data
behind EXPERIMENTS.md §Perf (L2) and the DESIGN.md roofline discussion.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from .config import MODEL_PRESETS, TRAIN_PRESETS
from .model import init_flat
from .train import make_eval_step, make_train_step


def analyze(name: str, fn, args) -> None:
    jitted = jax.jit(fn)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:  # pragma: no cover
        print(f"  {name}: cost analysis unavailable ({e})")
        return
    flops = cost.get("flops", float("nan"))
    bytes_ = cost.get("bytes accessed", float("nan"))
    print(
        f"  {name:<28} {flops/1e9:8.3f} GFLOP  {bytes_/1e6:9.2f} MB touched  "
        f"AI={flops/max(bytes_,1):6.1f} flop/byte"
    )


def timeit(name: str, fn, args, iters=10) -> float:
    jitted = jax.jit(fn)
    out = jitted(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = jitted(*args)
    jax.block_until_ready(out)
    dt = (time.time() - t0) / iters
    print(f"  {name:<28} {dt*1e3:8.1f} ms/iter")
    return dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="exp")
    ap.add_argument("--fragments", type=int, default=4)
    args = ap.parse_args()
    cfg = MODEL_PRESETS[args.preset]
    tc = TRAIN_PRESETS[args.preset]
    k = min(args.fragments, cfg.n_layers)

    flat = jnp.asarray(init_flat(cfg, k))
    z = jnp.zeros_like(flat)
    rng = np.random.default_rng(0)
    tok = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (cfg.batch_size, cfg.seq_len)), jnp.int32
    )
    tgt = jnp.roll(tok, -1, axis=1)
    step = jnp.float32(0)

    print(f"preset={args.preset} params={flat.shape[0]} K={k}")
    print("— XLA cost analysis (compiled modules) —")
    analyze("train_step (pallas attn)", make_train_step(cfg, tc, k),
            (flat, z, z, step, tok, tgt))
    cfg_ref = dataclasses.replace(cfg, use_pallas_attention=False)
    analyze("train_step (jnp attn)", make_train_step(cfg_ref, tc, k),
            (flat, z, z, step, tok, tgt))
    analyze("eval_step", make_eval_step(cfg, k), (flat, tok, tgt))

    print("— wallclock (CPU; structure signal only, not a TPU proxy) —")
    t_pallas = timeit("train_step (pallas attn)", make_train_step(cfg, tc, k),
                      (flat, z, z, step, tok, tgt))
    t_jnp = timeit("train_step (jnp attn)", make_train_step(cfg_ref, tc, k),
                   (flat, z, z, step, tok, tgt))
    print(f"  pallas/jnp ratio: {t_pallas/t_jnp:.2f}x "
          "(interpret-mode emulation overhead on CPU)")

    # L1 VMEM footprint estimate from the BlockSpecs (DESIGN.md §Perf).
    from .kernels.attention import _block_for
    T, dh = cfg.seq_len, cfg.head_dim
    blk = _block_for(T)
    vmem = (blk * dh + 2 * T * dh + blk * dh + 2 * blk) * 4
    print(
        f"— L1 flash-attention VMEM/block estimate: q({blk}x{dh}) + kv(2x{T}x{dh}) "
        f"+ acc({blk}x{dh}) + stats ≈ {vmem/1024:.1f} KiB per (head, q-block) "
        f"program (TPU VMEM ≈ 16 MiB: fits with double-buffering headroom)"
    )


if __name__ == "__main__":
    main()
