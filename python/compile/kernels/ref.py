"""Pure-jnp oracles for every Pallas kernel in this package.

Each `ref_*` function is the mathematical ground truth the corresponding
Pallas kernel is tested against (python/tests/test_kernel.py sweeps shapes
and dtypes with hypothesis and asserts allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal softmax attention. q,k,v: (..., T, dh) -> (..., T, dh)."""
    dh = q.shape[-1]
    T = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("...td,...sd->...ts", q, k) * scale
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask, s, jnp.asarray(-jnp.inf, s.dtype))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...ts,...sd->...td", p, v)


def ref_adamw(p, m, v, g, lr, step, *, beta1, beta2, eps, weight_decay):
    """Decoupled AdamW single update. step is 1-indexed (f32 scalar)."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**step)
    vhat = v2 / (1.0 - beta2**step)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
    return p2, m2, v2


def ref_delay_comp(theta_g, theta_tl, theta_tp, *, tau, H, lam):
    """CoCoDC delay compensation (paper Alg. 1, Eqs. 4/7/8).

    Sign convention (documented in DESIGN.md): the paper's Eqs. 4-8 use an
    internally inconsistent sign for the local change rate. We implement the
    consistent reading:

      g      = (theta_tl - theta_tp) / tau        forward local change rate
      g_corr = g + lam * g*g * (theta_g - theta_tp) / H   Eq.5's Hessian term,
               pulling the rate toward the observed global-local divergence
      theta' = theta_g + g_corr * tau             extrapolate global state

    With lam=0 this extrapolates the fresh global state by the local
    trajectory over the tau overlap steps; with tau=0 it adopts theta_g.
    """
    g = (theta_tl - theta_tp) / tau
    g_corr = g + lam * g * g * (theta_g - theta_tp) / H
    return theta_g + g_corr * tau


def ref_outer_step(theta_g, delta, mom, *, lr, momentum):
    """Nesterov-momentum outer optimizer over pseudo-gradients (DiLoCo).

    delta = mean_m(theta^m - theta^g) is the averaged pseudo-gradient; the
    outer gradient is its negation. Matches torch SGD(nesterov=True).
    """
    grad = -delta
    mom2 = momentum * mom + grad
    theta2 = theta_g - lr * (grad + momentum * mom2)
    return theta2, mom2


def ref_rmsnorm(x, gain, eps: float = 1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def ref_swiglu(x, w1, w3, w2):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2
