"""Pallas flash-attention (causal) with a custom VJP.

This is the paper's compute hot spot (the transformer fwd/bwd inside each
local step) re-thought for TPU per DESIGN.md §Hardware-Adaptation:

  * the HBM->VMEM schedule is expressed with BlockSpecs — Q/dO are tiled
    over sequence blocks, K/V live in VMEM and are visited block-by-block
    by an in-kernel loop (the flash recurrence);
  * softmax uses the running-max / running-sum recurrence so no (T, T)
    score matrix is ever materialized;
  * matmuls accumulate in f32 (`preferred_element_type`), the MXU-friendly
    layout.

Lowered with ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO ops
that XLA:CPU compiles natively (see /opt/xla-example/README.md).

Because ``pallas_call`` has no autodiff rule, the backward pass is two more
Pallas kernels (dq, and dk/dv) wired up through ``jax.custom_vjp`` — this is
what lets the kernel live inside the differentiated train_step artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _block_for(T: int) -> int:
    for b in (128, 64, 32, 16, 8):
        if T % b == 0:
            return b
    return T


# ---------------------------------------------------------------------------
# Forward kernel: one (batch*head, q-block) program; flash recurrence over
# kv blocks j <= i. Emits o and the log-sum-exp (needed by the backward).
# ---------------------------------------------------------------------------
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block: int, scale: float):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (Bq, dh)
    dh = q.shape[-1]
    rows = i * block + jax.lax.iota(jnp.int32, block)

    def body(j, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * block, block, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * block, block, 0)
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (Bq, Bk)
        cols = j * block + jax.lax.iota(jnp.int32, block)
        s = jnp.where(rows[:, None] >= cols[None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block, dh), jnp.float32)
    m0 = jnp.full((block,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, i + 1, body, (acc0, m0, l0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[0] = (m + jnp.log(l)).astype(lse_ref.dtype)


def _fwd(q, k, v):
    N, T, dh = q.shape
    block = _block_for(T)
    scale = 1.0 / float(dh) ** 0.5
    grid = (N, T // block)
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, block=block, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block, dh), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, T, dh), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((1, T, dh), lambda n, i: (n, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block, dh), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, block), lambda n, i: (n, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, T, dh), q.dtype),
            jax.ShapeDtypeStruct((N, T), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# Backward kernels. delta = rowsum(do * o) is elementwise and precomputed
# outside. dq is gridded over q blocks (loop over kv blocks j <= i);
# dk/dv are gridded over kv blocks (loop over q blocks i >= j).
# ---------------------------------------------------------------------------
def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, block: int, scale: float):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]
    dh = q.shape[-1]
    rows = i * block + jax.lax.iota(jnp.int32, block)

    def body(j, dq):
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * block, block, 0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * block, block, 0).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        cols = j * block + jax.lax.iota(jnp.int32, block)
        mask = rows[:, None] >= cols[None, :]
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        return dq + jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, i + 1, body, jnp.zeros((block, dh), jnp.float32))
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, block: int, scale: float, nblocks: int):
    j = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)  # (Bk, dh)
    v = v_ref[0].astype(jnp.float32)
    dh = k.shape[-1]
    cols = j * block + jax.lax.iota(jnp.int32, block)

    def body(i, carry):
        dk, dv = carry
        q = jax.lax.dynamic_slice_in_dim(q_ref[0], i * block, block, 0).astype(jnp.float32)
        do = jax.lax.dynamic_slice_in_dim(do_ref[0], i * block, block, 0).astype(jnp.float32)
        lse = jax.lax.dynamic_slice_in_dim(lse_ref[0], i * block, block, 0)
        delta = jax.lax.dynamic_slice_in_dim(delta_ref[0], i * block, block, 0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = i * block + jax.lax.iota(jnp.int32, block)
        mask = rows[:, None] >= cols[None, :]
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)  # (Bq, Bk)
        dv2 = dv + jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk2 = dk + jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
        return dk2, dv2

    z = jnp.zeros((block, dh), jnp.float32)
    dk, dv = jax.lax.fori_loop(j, nblocks, body, (z, z))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _bwd(res, do):
    q, k, v, o, lse = res
    N, T, dh = q.shape
    block = _block_for(T)
    scale = 1.0 / float(dh) ** 0.5
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # (N, T)
    grid = (N, T // block)
    qspec = pl.BlockSpec((1, block, dh), lambda n, i: (n, i, 0))
    fullspec = pl.BlockSpec((1, T, dh), lambda n, i: (n, 0, 0))
    rowspec = pl.BlockSpec((1, block), lambda n, i: (n, i))
    fullrow = pl.BlockSpec((1, T), lambda n, i: (n, 0))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, block=block, scale=scale),
        grid=grid,
        in_specs=[qspec, fullspec, fullspec, qspec, rowspec, rowspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((N, T, dh), q.dtype),
        interpret=True,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, block=block, scale=scale,
                          nblocks=T // block),
        grid=grid,
        in_specs=[fullspec, qspec, qspec, fullspec, fullrow, fullrow],
        out_specs=[qspec, qspec],
        out_shape=[
            jax.ShapeDtypeStruct((N, T, dh), k.dtype),
            jax.ShapeDtypeStruct((N, T, dh), v.dtype),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


@jax.custom_vjp
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal flash attention over (N, T, dh) tensors (N = batch*heads)."""
    return _fwd(q, k, v)[0]


def _vjp_fwd(q, k, v):
    o, lse = _fwd(q, k, v)
    return o, (q, k, v, o, lse)


flash_attention.defvjp(_vjp_fwd, _bwd)
