"""1-D tiled elementwise Pallas kernels (VPU-bound on real TPU).

All three operate over the flat f32 parameter vector (or a fragment slice of
it). Tiling: the caller pads to a multiple of BLOCK and slices the result
back, so arbitrary fragment sizes are supported without masked tail blocks.

 * fused_adamw   — decoupled AdamW with bias correction; runs inside the
                   train_step artifact after the backward pass (no AD needed).
 * delay_comp    — CoCoDC Alg. 1 (Eqs. 4/7/8); lowered per fragment size as
                   its own artifact and dispatched by the rust coordinator.
 * outer_step    — DiLoCo's Nesterov-momentum outer optimizer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536


def _pad(x, n):
    return jnp.pad(x, (0, n - x.shape[0])) if x.shape[0] != n else x


def _padded(P: int) -> int:
    if P <= BLOCK:
        return P
    return -(-P // BLOCK) * BLOCK


def _tile1d(P: int):
    Pp = _padded(P)
    blk = min(BLOCK, Pp)
    grid = (Pp // blk,)
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    return Pp, grid, spec


# ---------------------------------------------------------------------------
# fused AdamW
# ---------------------------------------------------------------------------
def _adamw_kernel(p_ref, m_ref, v_ref, g_ref, lr_ref, step_ref,
                  p_out, m_out, v_out, *, beta1, beta2, eps, wd):
    p, m, v, g = p_ref[...], m_ref[...], v_ref[...], g_ref[...]
    lr = lr_ref[0]
    step = step_ref[0]
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 - jnp.power(beta1, step)
    bc2 = 1.0 - jnp.power(beta2, step)
    update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps) + wd * p
    p_out[...] = p - lr * update
    m_out[...] = m2
    v_out[...] = v2


def fused_adamw(p, m, v, g, lr, step, *, beta1, beta2, eps, weight_decay):
    """p,m,v,g: f32[P]; lr, step: f32 scalars (step 1-indexed). -> (p',m',v')."""
    P = p.shape[0]
    Pp, grid, spec = _tile1d(P)
    scal = pl.BlockSpec((1,), lambda i: (0,))
    lr1 = jnp.reshape(lr, (1,)).astype(jnp.float32)
    step1 = jnp.reshape(step, (1,)).astype(jnp.float32)
    outs = pl.pallas_call(
        functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2, eps=eps,
                          wd=weight_decay),
        grid=grid,
        in_specs=[spec, spec, spec, spec, scal, scal],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 3,
        interpret=True,
    )(_pad(p, Pp), _pad(m, Pp), _pad(v, Pp), _pad(g, Pp), lr1, step1)
    return tuple(o[:P] for o in outs)


# ---------------------------------------------------------------------------
# CoCoDC delay compensation (Alg. 1). tau/H/lam are *runtime* scalar inputs
# so a single artifact per fragment size serves every (tau, H, lam) sweep —
# tau in particular varies with the measured overlap in adaptive runs.
# ---------------------------------------------------------------------------
def _delay_comp_kernel(g_ref, tl_ref, tp_ref, tau_ref, h_ref, lam_ref, out_ref):
    theta_g, theta_tl, theta_tp = g_ref[...], tl_ref[...], tp_ref[...]
    tau, H, lam = tau_ref[0], h_ref[0], lam_ref[0]
    g = (theta_tl - theta_tp) / tau
    g_corr = g + lam * g * g * (theta_g - theta_tp) / H
    out_ref[...] = theta_g + g_corr * tau


def delay_comp(theta_g, theta_tl, theta_tp, tau, H, lam):
    """See kernels.ref.ref_delay_comp for the math + sign convention.
    tau/H/lam: f32 scalars (traced)."""
    P = theta_g.shape[0]
    Pp, grid, spec = _tile1d(P)
    scal = pl.BlockSpec((1,), lambda i: (0,))
    s = lambda x: jnp.reshape(x, (1,)).astype(jnp.float32)
    out = pl.pallas_call(
        _delay_comp_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, scal, scal, scal],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=True,
    )(_pad(theta_g, Pp), _pad(theta_tl, Pp), _pad(theta_tp, Pp),
      s(tau), s(H), s(lam))
    return out[:P]


# ---------------------------------------------------------------------------
# Nesterov outer optimizer (DiLoCo / Streaming DiLoCo / CoCoDC all share it)
# ---------------------------------------------------------------------------
def _outer_kernel(theta_ref, delta_ref, mom_ref, lr_ref, mu_ref,
                  theta_out, mom_out):
    theta, delta, mom = theta_ref[...], delta_ref[...], mom_ref[...]
    lr, momentum = lr_ref[0], mu_ref[0]
    grad = -delta
    mom2 = momentum * mom + grad
    theta_out[...] = theta - lr * (grad + momentum * mom2)
    mom_out[...] = mom2


def outer_step(theta_g, delta, mom, lr, momentum):
    """theta_g,delta,mom: f32[S]; lr,momentum: f32 scalars.
    -> (theta_g', mom'). Matches ref_outer_step."""
    P = theta_g.shape[0]
    Pp, grid, spec = _tile1d(P)
    scal = pl.BlockSpec((1,), lambda i: (0,))
    s = lambda x: jnp.reshape(x, (1,)).astype(jnp.float32)
    outs = pl.pallas_call(
        _outer_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, scal, scal],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 2,
        interpret=True,
    )(_pad(theta_g, Pp), _pad(delta, Pp), _pad(mom, Pp), s(lr), s(momentum))
    return outs[0][:P], outs[1][:P]
