"""AOT lowering: jax -> HLO TEXT artifacts + meta.json + init_params.bin.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):
    python -m compile.aot --preset exp --outdir ../artifacts
Produces artifacts/<preset>/{train_step,eval_step,grad_step,
delay_comp_f<i>,outer_step_f<i>}.hlo.txt plus meta.json and init_params.bin.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import (MODEL_PRESETS, TRAIN_PRESETS, ModelConfig, TrainConfig,
                     flat_layout)
from .kernels.elementwise import delay_comp, outer_step
from .model import init_flat
from .train import make_eval_step, make_grad_step, make_train_step


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True: the rust
    side unwraps with to_tuple / to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, lowered) -> None:
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)/1e6:.2f} MB)")


def build(preset: str, outdir: str, n_fragments: int, seed: int,
          skip_grad: bool = False) -> None:
    cfg: ModelConfig = MODEL_PRESETS[preset]
    tc: TrainConfig = TRAIN_PRESETS[preset]
    # K > n_layers would leave empty strided shards; clamp (paper uses
    # K=4 over 12 layers, ~3 layers per shard).
    n_fragments = min(n_fragments, cfg.n_layers)
    leaves, fragments, P = flat_layout(cfg, n_fragments)
    B, T = cfg.batch_size, cfg.seq_len
    d = os.path.join(outdir, preset)
    os.makedirs(d, exist_ok=True)
    print(f"[aot] preset={preset} P={P} K={n_fragments} B={B} T={T}")

    fP = jax.ShapeDtypeStruct((P,), jnp.float32)
    fS = jax.ShapeDtypeStruct((), jnp.float32)
    iBT = jax.ShapeDtypeStruct((B, T), jnp.int32)

    _write(os.path.join(d, "train_step.hlo.txt"),
           jax.jit(make_train_step(cfg, tc, n_fragments))
           .lower(fP, fP, fP, fS, iBT, iBT))
    _write(os.path.join(d, "eval_step.hlo.txt"),
           jax.jit(make_eval_step(cfg, n_fragments)).lower(fP, iBT, iBT))
    if not skip_grad:
        _write(os.path.join(d, "grad_step.hlo.txt"),
               jax.jit(make_grad_step(cfg, n_fragments)).lower(fP, iBT, iBT))

    # One delay-comp / outer-step artifact per DISTINCT fragment size.
    sizes = sorted({f["size"] for f in fragments})
    size_to_name = {}
    for s in sizes:
        fF = jax.ShapeDtypeStruct((s,), jnp.float32)
        name_dc = f"delay_comp_s{s}"
        name_os = f"outer_step_s{s}"
        _write(os.path.join(d, name_dc + ".hlo.txt"),
               jax.jit(lambda g, tl, tp, tau, H, lam:
                       (delay_comp(g, tl, tp, tau, H, lam),))
               .lower(fF, fF, fF, fS, fS, fS))
        _write(os.path.join(d, name_os + ".hlo.txt"),
               jax.jit(lambda t, dl, m, lr, mu: outer_step(t, dl, m, lr, mu))
               .lower(fF, fF, fF, fS, fS))
        size_to_name[s] = {"delay_comp": name_dc, "outer_step": name_os}

    init = init_flat(cfg, n_fragments, seed=seed)
    init.tofile(os.path.join(d, "init_params.bin"))

    meta = {
        "preset": preset,
        "model": {
            "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
            "batch_size": cfg.batch_size,
            "use_pallas_attention": cfg.use_pallas_attention,
        },
        "train": {
            "lr": tc.lr, "warmup_steps": tc.warmup_steps,
            "total_steps": tc.total_steps, "weight_decay": tc.weight_decay,
            "beta1": tc.beta1, "beta2": tc.beta2, "eps": tc.eps,
            "min_lr_ratio": tc.min_lr_ratio,
        },
        "param_count": P,
        "n_fragments": n_fragments,
        "seed": seed,
        "leaves": leaves,
        "fragments": fragments,
        "fragment_artifacts": {
            str(f["index"]): size_to_name[f["size"]] for f in fragments
        },
        "artifacts": {
            "train_step": "train_step.hlo.txt",
            "eval_step": "eval_step.hlo.txt",
            **({} if skip_grad else {"grad_step": "grad_step.hlo.txt"}),
        },
    }
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  wrote {d}/meta.json + init_params.bin ({4*P/1e6:.1f} MB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="exp",
                    choices=sorted(MODEL_PRESETS.keys()) + ["all"])
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--fragments", type=int, default=4,
                    help="K, the number of strided depth shards (paper: 4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-grad", action="store_true")
    args = ap.parse_args()
    presets = (["tiny", "exp", "e2e"] if args.preset == "all"
               else [args.preset])
    for p in presets:
        build(p, args.outdir, args.fragments, args.seed,
              skip_grad=args.skip_grad)


if __name__ == "__main__":
    main()
