"""L2: differentiated train/eval steps with the fused-AdamW Pallas kernel.

`train_step` is the single artifact executed on every local step by every
simulated datacenter worker (L3 hot path). The warmup+cosine LR schedule
(paper §IV-A) is computed *inside* the artifact from the runtime `step`
input, so the rust side never re-implements it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, TrainConfig
from .kernels.elementwise import fused_adamw
from .model import loss_fn


def lr_schedule(step: jax.Array, tc: TrainConfig) -> jax.Array:
    """Linear warmup to tc.lr, then cosine decay to min_lr_ratio*lr.
    `step` is 0-indexed f32."""
    warm = jnp.asarray(tc.warmup_steps, jnp.float32)
    total = jnp.asarray(tc.total_steps, jnp.float32)
    lr_warm = tc.lr * (step + 1.0) / jnp.maximum(warm, 1.0)
    prog = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    lr_cos = tc.lr * (tc.min_lr_ratio + (1.0 - tc.min_lr_ratio) * cos)
    return jnp.where(step < warm, lr_warm, lr_cos)


def make_train_step(cfg: ModelConfig, tc: TrainConfig, n_fragments: int):
    """(params, m, v, step, tokens, targets) -> (params', m', v', loss)."""

    def train_step(flat, m, v, step, tokens, targets):
        loss, grad = jax.value_and_grad(loss_fn)(flat, tokens, targets, cfg,
                                                 n_fragments)
        lr = lr_schedule(step, tc)
        flat2, m2, v2 = fused_adamw(
            flat, m, v, grad, lr, step + 1.0,
            beta1=tc.beta1, beta2=tc.beta2, eps=tc.eps,
            weight_decay=tc.weight_decay,
        )
        return flat2, m2, v2, loss

    return train_step


def make_eval_step(cfg: ModelConfig, n_fragments: int):
    """(params, tokens, targets) -> (loss,). PPL = exp(loss)."""

    def eval_step(flat, tokens, targets):
        return (loss_fn(flat, tokens, targets, cfg, n_fragments),)

    return eval_step


def make_grad_step(cfg: ModelConfig, n_fragments: int):
    """(params, tokens, targets) -> (loss, grad). Ablation/testing artifact:
    the raw backward pass without the optimizer, used by the L2 fusion bench
    and rust-side gradient-path tests."""

    def grad_step(flat, tokens, targets):
        loss, grad = jax.value_and_grad(loss_fn)(flat, tokens, targets, cfg,
                                                 n_fragments)
        return loss, grad

    return grad_step
