"""L2: LLaMA-style decoder-only transformer over a FLAT parameter vector.

Architecture (paper §IV-A: "decoder-only and LLaMA-style transformer"):
RMSNorm pre-norm, rotary position embeddings, SwiGLU MLP, causal attention,
untied LM head. All parameters are packed into one f32[P] vector laid out
fragment-major (see config.flat_layout) so the rust coordinator can treat
Streaming-DiLoCo/CoCoDC fragments as contiguous slices.

Attention runs through the Pallas flash kernel (kernels.attention) by
default, or the pure-jnp reference when cfg.use_pallas_attention=False
(used by tests and the L2-ablation bench).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, flat_layout, leaf_specs
from .kernels.attention import flash_attention
from .kernels.ref import ref_attention, ref_rmsnorm, ref_swiglu


def unflatten(flat: jax.Array, cfg: ModelConfig, n_fragments: int) -> Dict[str, jax.Array]:
    """Slice the flat vector back into named leaves (static offsets: the
    slices lower to free HLO slices/reshapes)."""
    leaves, _, total = flat_layout(cfg, n_fragments)
    assert flat.shape == (total,), (flat.shape, total)
    out = {}
    for leaf in leaves:
        x = jax.lax.slice_in_dim(flat, leaf["offset"], leaf["offset"] + leaf["size"])
        out[leaf["name"]] = x.reshape(leaf["shape"])
    return out


def init_flat(cfg: ModelConfig, n_fragments: int, seed: int = 0) -> np.ndarray:
    """Deterministic init (numpy so the artifact build can dump it to disk).

    Scaled-normal init a la GPT-2/LLaMA: std = 0.02 for embeddings/inputs,
    residual-out projections scaled by 1/sqrt(2*n_layers); norms at 1."""
    rng = np.random.default_rng(seed)
    leaves, _, total = flat_layout(cfg, n_fragments)
    flat = np.zeros(total, np.float32)
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.n_layers)
    for leaf in leaves:
        name = leaf["name"]
        sl = slice(leaf["offset"], leaf["offset"] + leaf["size"])
        if name.endswith("_norm"):
            flat[sl] = 1.0
        else:
            std = 0.02
            if name.endswith(".wo") or name.endswith(".w2"):
                std *= resid_scale
            flat[sl] = rng.normal(0.0, std, leaf["size"]).astype(np.float32)
    return flat


def _rope(x: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding over (B, nh, T, dh)."""
    B, nh, T, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.arange(T, dtype=jnp.float32)[:, None] * freqs[None, :]  # (T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def _attention(x: jax.Array, p: Dict[str, jax.Array], l: int, cfg: ModelConfig) -> jax.Array:
    B, T, D = x.shape
    nh, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p[f"layer{l}.wq"]).reshape(B, T, nh, dh).transpose(0, 2, 1, 3)
    k = (x @ p[f"layer{l}.wk"]).reshape(B, T, nh, dh).transpose(0, 2, 1, 3)
    v = (x @ p[f"layer{l}.wv"]).reshape(B, T, nh, dh).transpose(0, 2, 1, 3)
    q, k = _rope(q, cfg.rope_theta), _rope(k, cfg.rope_theta)
    if cfg.use_pallas_attention:
        o = flash_attention(
            q.reshape(B * nh, T, dh), k.reshape(B * nh, T, dh),
            v.reshape(B * nh, T, dh),
        ).reshape(B, nh, T, dh)
    else:
        o = ref_attention(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, D)
    return o @ p[f"layer{l}.wo"]


def forward(flat: jax.Array, tokens: jax.Array, cfg: ModelConfig,
            n_fragments: int) -> jax.Array:
    """tokens: i32[B, T] -> logits f32[B, T, V]."""
    p = unflatten(flat, cfg, n_fragments)
    x = p["embed"][tokens]  # (B, T, D)
    for l in range(cfg.n_layers):
        x = x + _attention(ref_rmsnorm(x, p[f"layer{l}.attn_norm"]), p, l, cfg)
        x = x + ref_swiglu(
            ref_rmsnorm(x, p[f"layer{l}.mlp_norm"]),
            p[f"layer{l}.w1"], p[f"layer{l}.w3"], p[f"layer{l}.w2"],
        )
    x = ref_rmsnorm(x, p["final_norm"])
    return x @ p["lm_head"]


def loss_fn(flat: jax.Array, tokens: jax.Array, targets: jax.Array,
            cfg: ModelConfig, n_fragments: int) -> jax.Array:
    """Mean token cross-entropy (natural log; perplexity = exp(loss))."""
    logits = forward(flat, tokens, cfg, n_fragments)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s, _ in leaf_specs(cfg))
