//! Self-healing state-layer acceptance contracts (DESIGN.md §Recovery):
//!
//! * a ring whose newest snapshot is torn (writer died mid-save without the
//!   atomic rename) resumes from the previous good snapshot and replays the
//!   rest of the run bit-identically to an uninterrupted same-seed run;
//! * a mid-run corruption window applies *zero* corrupt fragment payloads —
//!   every checksum mismatch is quarantined and retransmitted — stays
//!   deterministic across same-seed reruns, and lands back on the
//!   fault-free validation curve once every payload arrives intact;
//! * a forced loss spike trips the divergence sentinel, rolls back to the
//!   last good snapshot and replays deterministically (`rollbacks >= 1` in
//!   the outcome); an exhausted rollback budget fails loudly.
//!
//! Everything runs on the native backend (no artifacts) at the tiny preset.

use std::path::{Path, PathBuf};

use cocodc::config::{Corruption, FaultWindow, MethodKind, RunConfig, TauMode};
use cocodc::runtime::NativeBackend;
use cocodc::{TrainOutcome, Trainer};

/// Shared run shape (mirrors tests/faults.rs) with the recovery layer
/// armed: snapshot every 5 steps, ring of 4, and a sentinel threshold so
/// high that only an injected spike (or a non-finite loss) can trip it —
/// genuine trajectory jitter replays identically after a rollback, so a
/// false positive would loop the budget dry.
fn recovery_cfg(method: MethodKind, total_steps: u32, ring_dir: &Path) -> RunConfig {
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 3;
    cfg.h_steps = 10;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = total_steps;
    cfg.eval_every = 10;
    cfg.eval_batches = 2;
    cfg.recovery.snapshot_every = 5;
    cfg.recovery.snapshot_ring = 4;
    cfg.recovery.snapshot_dir = ring_dir.to_string_lossy().into_owned();
    cfg.recovery.sentinel_zscore = 1e9;
    cfg
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("cocodc_recovery_test").join(name);
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn run_to_end(
    backend: &NativeBackend,
    cfg: RunConfig,
) -> (TrainOutcome, Vec<Vec<f32>>) {
    let mut tr = Trainer::new(backend, cfg).unwrap();
    let out = tr.run().unwrap();
    let params = (0..tr.workers().len())
        .map(|i| tr.worker_params(i).unwrap())
        .collect();
    (out, params)
}

#[test]
fn torn_newest_snapshot_falls_back_and_resumes_bit_identically() {
    let backend = NativeBackend::preset("tiny").unwrap();
    let dir = fresh_dir("torn_ring");
    let mut first =
        Trainer::new(&backend, recovery_cfg(MethodKind::Cocodc, 20, &dir)).unwrap();
    let _ = first.run().unwrap();
    drop(first);

    // Tear the newest snapshot in half — the on-disk shape left by a
    // non-atomic writer killed mid-save.
    let newest = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map_or(false, |n| n.starts_with("ckpt-") && n.ends_with(".bin"))
        })
        .max()
        .unwrap();
    let bytes = std::fs::read(&newest).unwrap();
    std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();

    let mut resumed =
        Trainer::new(&backend, recovery_cfg(MethodKind::Cocodc, 40, &dir)).unwrap();
    let at = resumed.resume_from_ring().unwrap().expect("ring has snapshots");
    assert!(at < 20, "resume did not fall back past the torn step-20 snapshot (at={at})");
    let out_res = resumed.run().unwrap();
    assert!(out_res.fallback_loads >= 1, "torn snapshot was not counted as a fallback");
    assert_eq!(out_res.curve.points.last().unwrap().step, 40);

    // Uninterrupted same-seed reference (its own ring directory).
    let dir_ref = fresh_dir("torn_ring_ref");
    let (out_full, params_full) =
        run_to_end(&backend, recovery_cfg(MethodKind::Cocodc, 40, &dir_ref));

    let mut shared = 0;
    for rp in &out_res.curve.points {
        if let Some(fp) = out_full.curve.points.iter().find(|p| p.step == rp.step) {
            assert_eq!(rp.loss, fp.loss, "loss diverged at step {}", rp.step);
            assert_eq!(rp.wall_s, fp.wall_s, "timeline diverged at step {}", rp.step);
            shared += 1;
        }
    }
    assert!(shared >= 3, "only {shared} shared eval points compared");
    for i in 0..resumed.workers().len() {
        assert_eq!(
            resumed.worker_params(i).unwrap(),
            params_full[i],
            "worker {i} final params differ after torn-snapshot resume"
        );
    }
}

#[test]
fn corruption_window_quarantines_every_corrupt_fragment_and_recovers() {
    let backend = NativeBackend::preset("tiny").unwrap();
    for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
        let corrupt_cfg = |dir: &Path| {
            let mut cfg = recovery_cfg(method, 80, dir);
            cfg.faults.corruptions.push(Corruption {
                window: FaultWindow { start_s: 1.0, duration_s: 4.0 },
                prob: 0.9,
            });
            cfg
        };
        let name = method.name();
        let (out_a, params_a) =
            run_to_end(&backend, corrupt_cfg(&fresh_dir(&format!("{name}_corrupt_a"))));
        let (out_b, params_b) =
            run_to_end(&backend, corrupt_cfg(&fresh_dir(&format!("{name}_corrupt_b"))));

        // Same-seed corrupted reruns are bit-identical.
        assert_eq!(out_a.curve.points, out_b.curve.points, "{name}: corrupted rerun diverged");
        assert_eq!(params_a, params_b, "{name}: corrupted rerun params diverged");
        assert_eq!(out_a.corrupt_fragments, out_b.corrupt_fragments);

        // The window fired, and every corrupt payload was quarantined —
        // never applied (quarantine implies a retransmission later, so the
        // retry counter moves too).
        assert!(out_a.corrupt_fragments > 0, "{name}: corruption window never fired");
        assert_eq!(
            out_a.quarantined, out_a.corrupt_fragments,
            "{name}: a corrupt fragment was applied instead of quarantined"
        );
        assert!(out_a.retries > 0, "{name}: quarantined fragments were never retransmitted");
        assert_eq!(out_a.nonfinite_losses, 0, "{name}: corruption leaked into the losses");
        assert!(out_a.curve.points.iter().all(|p| p.loss.is_finite()));
        assert!(out_a.final_train_loss.is_finite());

        // Once every payload is retransmitted intact the run converges back
        // onto the fault-free curve (the clean tail drains the queue).
        let (clean, _) =
            run_to_end(&backend, recovery_cfg(method, 80, &fresh_dir(&format!("{name}_clean"))));
        assert_eq!(clean.corrupt_fragments, 0);
        assert_eq!(clean.quarantined, 0);
        let gap = (out_a.curve.final_loss().unwrap() - clean.curve.final_loss().unwrap()).abs();
        assert!(
            gap < 0.5,
            "{name}: corrupted run did not recover to the fault-free curve (gap={gap:.4})"
        );
    }
}

#[test]
fn loss_spike_triggers_rollback_and_replays_to_clean_trajectory() {
    let backend = NativeBackend::preset("tiny").unwrap();
    let dir = fresh_dir("spike_ring");
    let mut tr = Trainer::new(&backend, recovery_cfg(MethodKind::Cocodc, 40, &dir)).unwrap();
    // Finite spike, absurdly far above any real loss: exercises the
    // z-score path (a non-finite loss short-circuits it). Consumed once,
    // so the post-rollback replay sees the genuine loss.
    tr.inject_loss_spike = Some((27, 1e30));
    let out = tr.run().unwrap();
    assert_eq!(out.rollbacks, 1, "spike did not trigger exactly one rollback");
    assert!(out.curve.points.iter().all(|p| p.loss.is_finite()));

    // The replay lands on the exact trajectory of a never-spiked run.
    let dir_ref = fresh_dir("spike_ring_ref");
    let mut clean =
        Trainer::new(&backend, recovery_cfg(MethodKind::Cocodc, 40, &dir_ref)).unwrap();
    let out_clean = clean.run().unwrap();
    assert_eq!(out_clean.rollbacks, 0);
    assert_eq!(
        out.curve.points, out_clean.curve.points,
        "post-rollback replay diverged from the clean trajectory"
    );
    for i in 0..tr.workers().len() {
        assert_eq!(
            tr.worker_params(i).unwrap(),
            clean.worker_params(i).unwrap(),
            "worker {i} params differ after rollback + replay"
        );
    }
}

#[test]
fn rollback_budget_exhaustion_fails_loudly() {
    let backend = NativeBackend::preset("tiny").unwrap();
    let dir = fresh_dir("budget_ring");
    let mut cfg = recovery_cfg(MethodKind::Cocodc, 40, &dir);
    cfg.recovery.max_rollbacks = 0;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    tr.inject_loss_spike = Some((27, f32::NAN));
    let err = tr.run().unwrap_err().to_string();
    assert!(err.contains("rollback budget"), "unexpected error: {err}");
}
