//! Fault-injection + degraded-mode resilience contracts (DESIGN.md §Faults):
//!
//! * a seeded `FaultPlan` (link outage + 1% transfer loss + one worker
//!   crash/recover) drives all three methods to completion bit-identically
//!   across two runs of the same seed;
//! * the retry/drop/timeout/requeue counters are exercised under heavy
//!   loss with a tight retry budget, and stay exactly zero fault-free;
//! * under the same pure-outage plan at fixed τ, CoCoDC defers applies to
//!   the transfer's actual arrival (zero comm-stall) while Streaming
//!   DiLoCo's rigid α-blend schedule must stall;
//! * a checkpoint taken *inside* a fault window — outage open, a worker
//!   crashed, retried transfers in flight — restores into a fresh trainer
//!   and replays the rest of the run bit-for-bit.
//!
//! Everything runs on the native backend (no artifacts) at the tiny preset.

use cocodc::config::{
    CrashWindow, FaultConfig, FaultWindow, MethodKind, RetryPolicy, RunConfig, TauMode,
};
use cocodc::runtime::NativeBackend;
use cocodc::{TrainOutcome, Trainer};

/// Shared run shape: 3 workers, H = 10, fixed τ = 2, T_c = 0.15 s/step.
fn fault_cfg(method: MethodKind, total_steps: u32) -> RunConfig {
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 3;
    cfg.h_steps = 10;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = total_steps;
    cfg.eval_every = 10;
    cfg.eval_batches = 2;
    cfg
}

/// The acceptance-criteria plan: one mid-run outage, 1% in-flight transfer
/// loss, and one worker that crashes and later rejoins. On this run shape
/// the 60-step horizon is ~9 virtual seconds, so every window opens and
/// closes inside the run.
fn acceptance_plan() -> FaultConfig {
    FaultConfig {
        outages: vec![FaultWindow { start_s: 2.0, duration_s: 1.5 }],
        transfer_loss_prob: 0.01,
        crashes: vec![CrashWindow {
            worker: 2,
            window: FaultWindow { start_s: 3.5, duration_s: 1.2 },
        }],
        ..Default::default()
    }
}

fn run_with_faults(
    method: MethodKind,
    faults: FaultConfig,
    total_steps: u32,
) -> (TrainOutcome, Vec<Vec<f32>>) {
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut cfg = fault_cfg(method, total_steps);
    cfg.faults = faults;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    let out = tr.run().unwrap();
    let params = (0..tr.workers().len())
        .map(|i| tr.worker_params(i).unwrap())
        .collect();
    (out, params)
}

#[test]
fn seeded_fault_plan_runs_all_methods_deterministically() {
    let mut activity = 0usize;
    for method in MethodKind::all() {
        let (out_a, params_a) = run_with_faults(method, acceptance_plan(), 60);
        let (out_b, params_b) = run_with_faults(method, acceptance_plan(), 60);
        assert_eq!(out_a.curve.points.len(), out_b.curve.points.len());
        for (a, b) in out_a.curve.points.iter().zip(&out_b.curve.points) {
            assert_eq!(a.loss, b.loss, "{method:?}: same-seed faulted rerun diverged");
            assert_eq!(a.wall_s, b.wall_s, "{method:?}: fault timeline not deterministic");
        }
        assert_eq!(params_a, params_b, "{method:?}: final params diverged bitwise");
        assert_eq!(out_a.retries, out_b.retries);
        assert_eq!(out_a.drops, out_b.drops);
        assert_eq!(out_a.timeouts, out_b.timeouts);
        assert_eq!(out_a.requeues, out_b.requeues);

        // Completion under faults: the run finishes, learns, and keeps
        // syncing (the crashed worker rejoined — all its fragments adopt
        // the global state, so params stay finite everywhere).
        assert_eq!(out_a.curve.points.last().unwrap().step, 60);
        assert!(out_a.curve.points.iter().all(|p| p.loss.is_finite()));
        assert!(out_a.syncs_completed > 0, "{method:?} never synced under faults");
        assert!(out_a.final_train_loss.is_finite());
        assert!(
            params_a.iter().flatten().all(|x| x.is_finite()),
            "{method:?}: non-finite params after crash/rejoin"
        );
        activity += out_a.retries + out_a.drops + out_a.timeouts + out_a.requeues;
    }
    // The outage alone guarantees τ/queue activity; the loss draw is only
    // 1%, so assert the fault plan touched the runs in aggregate.
    assert!(activity > 0, "acceptance plan produced no fault activity at all");
}

#[test]
fn retry_drop_timeout_requeue_counters_are_exercised() {
    // Heavy in-flight loss with a tight retry budget: most logical
    // transfers drop at least once, many exhaust both attempts and are
    // requeued for retransmission on a later step.
    let lossy = FaultConfig {
        transfer_loss_prob: 0.7,
        retry: RetryPolicy {
            max_attempts: 2,
            backoff_base_s: 0.05,
            backoff_factor: 2.0,
            timeout_budget_s: 0.5,
        },
        ..Default::default()
    };
    for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
        let (out, _) = run_with_faults(method, lossy.clone(), 80);
        assert!(out.drops > 0, "{method:?}: no transfer ever dropped at 70% loss");
        assert!(out.retries > 0, "{method:?}: drops were never retried");
        assert!(out.timeouts > 0, "{method:?}: no transfer exhausted its budget");
        assert!(out.requeues > 0, "{method:?}: timed-out fragments not requeued");
        assert!(out.tau_dist.count > 0, "{method:?}: no delivered sync recorded τ");
        assert!(out.queue_delay_dist.count > 0, "{method:?}: queue delays not recorded");
        assert!(out.final_train_loss.is_finite(), "{method:?} diverged under loss");
    }

    // Fault-free runs must not touch the counters (the hot path stays on
    // the pre-fault code path, bit-identical to earlier builds).
    let (clean, _) = run_with_faults(MethodKind::Cocodc, FaultConfig::default(), 50);
    assert_eq!(clean.retries, 0);
    assert_eq!(clean.drops, 0);
    assert_eq!(clean.timeouts, 0);
    assert_eq!(clean.requeues, 0);
}

#[test]
fn cocodc_defers_applies_and_avoids_streaming_outage_stalls() {
    // Pure outage, no loss, no crash: the comparison is deterministic and
    // isolates the scheduling difference. Transfers requested inside the
    // window queue behind its end; Streaming still α-blends at t+τ and has
    // to stall until the queued transfer lands, while CoCoDC defers the
    // delay-compensated apply to the actual arrival (τ_eff = max(τ,
    // arrival)) and never blocks a worker.
    let outage_only = FaultConfig {
        outages: vec![FaultWindow { start_s: 1.5, duration_s: 3.0 }],
        ..Default::default()
    };
    let (streaming, _) = run_with_faults(MethodKind::StreamingDiloco, outage_only.clone(), 60);
    let (cocodc, _) = run_with_faults(MethodKind::Cocodc, outage_only, 60);

    assert!(
        streaming.comm_stall_s > 0.0,
        "streaming's fixed-τ apply should stall behind the outage"
    );
    assert!(streaming.apply_stalls > 0);
    assert_eq!(
        cocodc.comm_stall_s, 0.0,
        "cocodc must absorb the outage via deferred, delay-compensated applies"
    );
    assert_eq!(cocodc.apply_stalls, 0);
    assert!(cocodc.comm_stall_s < streaming.comm_stall_s);

    // Both still complete and learn through the outage.
    for out in [&streaming, &cocodc] {
        assert!(out.syncs_completed > 0);
        assert!(out.final_train_loss.is_finite());
    }
}

#[test]
fn checkpoint_inside_fault_window_replays_identically() {
    // Checkpoint at step 20 — ~3.0 virtual seconds in: the outage is open
    // (1.5 s – 4.5 s), worker 2 is crashed (2.0 s – 3.2 s), and transfers
    // requested since 1.5 s are queued/retrying in flight. The checkpoint
    // must capture the fault RNG stream, liveness, pending transfers and
    // the adaptive-schedule state so a fresh trainer replays the rest of
    // the fault window exactly.
    let plan = FaultConfig {
        outages: vec![FaultWindow { start_s: 1.5, duration_s: 3.0 }],
        transfer_loss_prob: 0.05,
        crashes: vec![CrashWindow {
            worker: 2,
            window: FaultWindow { start_s: 2.0, duration_s: 1.2 },
        }],
        ..Default::default()
    };
    let mk_cfg = |total: u32| {
        let mut cfg = fault_cfg(MethodKind::Cocodc, total);
        cfg.eval_every = 5;
        cfg.faults = plan.clone();
        cfg
    };
    let backend = NativeBackend::preset("tiny").unwrap();

    // Uninterrupted 40-step reference run.
    let mut full = Trainer::new(&backend, mk_cfg(40)).unwrap();
    let out_full = full.run().unwrap();

    // First 20 steps, checkpoint mid-window, fresh trainer resumes.
    let mut first = Trainer::new(&backend, mk_cfg(20)).unwrap();
    let _ = first.run().unwrap();
    let ck = first.checkpoint(20).unwrap();
    drop(first);
    let mut resumed = Trainer::new(&backend, mk_cfg(40)).unwrap();
    resumed.restore(&ck).unwrap();
    let out_resumed = resumed.run().unwrap();

    for rp in &out_resumed.curve.points {
        let fp = out_full
            .curve
            .points
            .iter()
            .find(|p| p.step == rp.step)
            .unwrap_or_else(|| panic!("full run has no eval at step {}", rp.step));
        assert_eq!(rp.loss, fp.loss, "loss diverged at step {}", rp.step);
        assert_eq!(rp.wall_s, fp.wall_s, "fault timeline diverged at step {}", rp.step);
    }
    assert_eq!(out_resumed.wall_s, out_full.wall_s, "final wall-clock differs");
    assert_eq!(out_resumed.syncs_completed, out_full.syncs_completed);
    assert_eq!(
        out_resumed.retries + out_resumed.drops + out_resumed.timeouts + out_resumed.requeues,
        out_full.retries + out_full.drops + out_full.timeouts + out_full.requeues,
        "restored fault counters / RNG stream out of sync"
    );

    let mut full2 = Trainer::new(&backend, mk_cfg(40)).unwrap();
    let _ = full2.run().unwrap();
    for i in 0..resumed.workers().len() {
        assert_eq!(
            resumed.worker_params(i).unwrap(),
            full2.worker_params(i).unwrap(),
            "worker {i} final params differ after resuming inside the fault window"
        );
    }
}
