//! Intra-step data parallelism: the determinism and kernel-equivalence
//! contracts of DESIGN.md §Parallelism.
//!
//!  1. Full trainer runs over all three sync methods produce bit-identical
//!     eval curves and final train losses for `--threads` 1/2/4/8 — shard
//!     count and reduction order are functions of the model shape alone,
//!     never of the pool size.
//!  2. A pooled run nests scopes (worker fan-out outside, row shards
//!     inside) on a pool smaller than the total task count; a watchdog
//!     turns a nested-scope deadlock into a test failure instead of a hung
//!     suite.
//!  3. The tiled matmul kernels are *exactly* equal (bit-identical, not
//!     1-ulp) to the seed triple-loop references at awkward shapes that
//!     exercise every register-tile remainder path.
//!  4. The 2D column partition: any contiguous column grid (including
//!     remainder widths the canonical [`col_chunk`] grid produces) covers
//!     each output column exactly once and is bit-identical to the
//!     full-range kernel; the chunked softmax–cross-entropy is within
//!     1 ulp of the fused single-sweep kernel (exactly equal at one
//!     chunk); batch-1 runs — where row sharding is pinned at one shard
//!     and all scaling comes from column chunks — stay bit-identical
//!     across `--threads 1/2/4/8` for all three sync methods.

use std::sync::mpsc;
use std::time::Duration;

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::runtime::native::{col_chunk, col_shards, softmax_xent_cols, XentScratch};
use cocodc::runtime::{ModelMeta, NativeBackend, NativeSpec, TrainMeta};
use cocodc::util::proptest::forall;
use cocodc::util::vecops::{self, reference};
use cocodc::Trainer;

/// One short tiny-preset run; returns the eval curve and final train loss.
/// Everything except `threads`/`parallel_workers` is held fixed, so any
/// difference between return values is the pool changing the math.
fn run_curve(method: MethodKind, threads: usize) -> (Vec<(u32, f64)>, f32) {
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 2;
    cfg.h_steps = 8;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 24;
    cfg.eval_every = 6;
    cfg.eval_batches = 2;
    cfg.threads = threads;
    cfg.parallel_workers = threads > 1;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    let out = tr.run().unwrap();
    let curve = out.curve.points.iter().map(|p| (p.step, p.loss)).collect();
    (curve, out.final_train_loss)
}

#[test]
fn thread_count_never_changes_the_math() {
    for method in MethodKind::all() {
        let serial = run_curve(method, 1);
        assert!(serial.0.len() >= 3, "{method:?}: curve too short to be meaningful");
        assert!(serial.1.is_finite());
        for threads in [2usize, 4, 8] {
            let pooled = run_curve(method, threads);
            assert_eq!(
                serial, pooled,
                "{method:?}: --threads {threads} diverged from --threads 1"
            );
        }
    }
}

/// Regression for the nested-scope deadlock: 2 workers × 2 row shards × 2
/// parallel eval batches on a 2-thread pool forces row-shard scopes to open
/// from inside already-running pool tasks with no idle thread left — only
/// job stealing by the blocked openers lets the run finish.
#[test]
fn pooled_run_with_nested_scopes_terminates() {
    let (tx, rx) = mpsc::channel();
    let watched = std::thread::spawn(move || {
        let out = run_curve(MethodKind::Cocodc, 2);
        tx.send(out).expect("send watchdog result");
    });
    let (curve, _) = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("pooled trainer run deadlocked (watchdog timeout)");
    watched.join().expect("watchdog thread panicked");
    assert!(!curve.is_empty());
}

/// Shapes covering every tile remainder: unit dims, sub-tile dims, exact
/// tile multiples, odd primes straddling MR/NR/LANES boundaries.
const SHAPES: [(usize, usize, usize); 8] = [
    (1, 1, 1),
    (2, 3, 5),
    (5, 7, 9),
    (8, 8, 8),
    (13, 17, 19),
    (33, 9, 40),
    (6, 64, 66),
    (23, 31, 29),
];

#[test]
fn tiled_matmul_bit_identical_to_reference() {
    forall(8, |rng| {
        for &(n, m, p) in &SHAPES {
            let a = rng.f32_vec(n * m, 1.0);
            let b = rng.f32_vec(m * p, 1.0);
            let mut got = vec![f32::NAN; n * p];
            let mut want = vec![f32::NAN; n * p];
            vecops::matmul(&mut got, &a, &b, n, m, p);
            reference::matmul(&mut want, &a, &b, n, m, p);
            if got != want {
                return Err(format!("matmul {n}x{m}x{p} not bit-identical"));
            }
        }
        Ok(())
    });
}

#[test]
fn tiled_matmul_bt_bit_identical_to_reference() {
    forall(8, |rng| {
        for &(n, m, p) in &SHAPES {
            let dout = rng.f32_vec(n * p, 1.0);
            let b = rng.f32_vec(m * p, 1.0);
            let mut got = vec![f32::NAN; n * m];
            let mut want = vec![f32::NAN; n * m];
            vecops::matmul_bt(&mut got, &dout, &b, n, m, p);
            reference::matmul_bt(&mut want, &dout, &b, n, m, p);
            if got != want {
                return Err(format!("matmul_bt {n}x{m}x{p} not bit-identical"));
            }
        }
        Ok(())
    });
}

#[test]
fn tiled_matmul_at_acc_bit_identical_to_reference() {
    forall(8, |rng| {
        for &(n, m, p) in &SHAPES {
            let a = rng.f32_vec(n * m, 1.0);
            let dout = rng.f32_vec(n * p, 1.0);
            // Accumulate into a shared non-zero starting buffer: the kernel
            // adds into gb, and the initial value is part of the contract.
            let init = rng.f32_vec(m * p, 1.0);
            let mut got = init.clone();
            let mut want = init;
            vecops::matmul_at_acc(&mut got, &a, &dout, n, m, p);
            reference::matmul_at_acc(&mut want, &a, &dout, n, m, p);
            if got != want {
                return Err(format!("matmul_at_acc {n}x{m}x{p} not bit-identical"));
            }
        }
        Ok(())
    });
}

/// A random contiguous partition of `0..cols` (1..=4 chunks, random
/// interior cut points), plus the canonical [`col_chunk`] grid — both must
/// behave identically to the unpartitioned kernel.
fn random_grid(rng: &mut cocodc::util::Rng, cols: usize) -> Vec<(usize, usize)> {
    let cc = rng.usize_in(1, 4.min(cols));
    let mut cuts: Vec<usize> = (0..cc - 1).map(|_| rng.usize_in(0, cols)).collect();
    cuts.push(0);
    cuts.push(cols);
    cuts.sort_unstable();
    cuts.windows(2).map(|w| (w[0], w[1])).collect()
}

/// Column-chunked matmul kernels, run chunk-by-chunk over arbitrary grids
/// (empty chunks, remainder widths narrower than a register tile), must
/// cover every output column exactly once and reproduce the full-range
/// kernel bit-for-bit — the kernels' accumulation order per output element
/// is independent of which column range computes it.
#[test]
fn column_chunked_matmuls_bit_identical_to_full() {
    forall(8, |rng| {
        for &(n, m, p) in &SHAPES {
            let a = rng.f32_vec(n * m, 1.0);
            let b = rng.f32_vec(m * p, 1.0);
            let dout = rng.f32_vec(n * p, 1.0);
            let init = rng.f32_vec(m * p, 1.0);

            let canonical: Vec<(usize, usize)> = {
                let cc = col_shards(p);
                (0..cc).map(|s| col_chunk(p, cc, s)).collect()
            };
            for grid in [random_grid(rng, p), canonical] {
                // Coverage/disjointness: contiguous, monotone, exact.
                let mut edge = 0;
                for &(c0, c1) in &grid {
                    if c0 != edge || c1 < c0 || c1 > p {
                        return Err(format!("bad grid {grid:?} over {p} cols"));
                    }
                    edge = c1;
                }
                if edge != p {
                    return Err(format!("grid {grid:?} does not cover {p} cols"));
                }

                let mut full = vec![f32::NAN; n * p];
                vecops::matmul(&mut full, &a, &b, n, m, p);
                let mut got = vec![f32::NAN; n * p];
                for &(c0, c1) in &grid {
                    vecops::matmul_cols(&mut got, &a, &b, n, m, p, c0, c1);
                }
                if got != full {
                    return Err(format!("matmul {n}x{m}x{p} grid {grid:?} diverged"));
                }

                let mut full = vec![f32::NAN; n * m];
                vecops::matmul_bt(&mut full, &dout, &b, n, m, p);
                let mut got = vec![f32::NAN; n * m];
                let jgrid = random_grid(rng, m);
                for &(j0, j1) in &jgrid {
                    vecops::matmul_bt_cols(&mut got, &dout, &b, n, m, p, j0, j1);
                }
                if got != full {
                    return Err(format!("matmul_bt {n}x{m}x{p} grid {jgrid:?} diverged"));
                }

                let mut full = init.clone();
                vecops::matmul_at_acc(&mut full, &a, &dout, n, m, p);
                let mut got = init.clone();
                for &(c0, c1) in &grid {
                    vecops::matmul_at_acc_cols(&mut got, &a, &dout, n, m, p, c0, c1);
                }
                if got != full {
                    return Err(format!("matmul_at_acc {n}x{m}x{p} grid {grid:?} diverged"));
                }
            }
        }
        Ok(())
    });
}

/// Monotone total order on f32 bit patterns, so ulp distance is a plain
/// integer subtraction (handles the sign-magnitude wraparound at zero).
fn f32_order(x: f32) -> i64 {
    let i = x.to_bits() as i32;
    if i < 0 { (i32::MIN as i64) - i as i64 } else { i as i64 }
}

fn ulp_diff(a: f32, b: f32) -> u64 {
    (f32_order(a) - f32_order(b)).unsigned_abs()
}

/// The chunked softmax–cross-entropy ([`softmax_xent_cols`], the kernel the
/// native step runs on both serial and pooled paths) vs the fused
/// single-sweep kernel and the multi-sweep reference: exactly equal at one
/// chunk, and within 1 ulp per dlogit (loss to f64 roundoff) at multi-chunk
/// grids — the only divergence is the f64 reassociation of the partition
/// sum z across chunk boundaries.
#[test]
fn chunked_softmax_xent_within_one_ulp_of_fused() {
    // Rows × vocab, including vocabs not divisible by MIN_COL_CHUNK and
    // vocabs below it (single chunk → bit-exact branch).
    const XSHAPES: [(usize, usize); 5] = [(1, 7), (2, 16), (3, 48), (5, 50), (4, 100)];
    forall(8, |rng| {
        for &(n, v) in &XSHAPES {
            let logits0 = rng.f32_vec(n * v, 2.0);
            let targets: Vec<i32> = (0..n).map(|_| rng.usize_in(0, v - 1) as i32).collect();
            let inv_n = 1.0 / n as f32;

            let mut fused = logits0.clone();
            let lf = vecops::softmax_xent(&mut fused, &targets, v, inv_n, true);
            let mut split = logits0.clone();
            let ls = reference::softmax_xent_split(&mut split, &targets, v, inv_n, true);
            if lf.to_bits() != ls.to_bits() || fused != split {
                return Err(format!("fused vs split diverged at {n}x{v}"));
            }

            let mut chunked = logits0.clone();
            let mut xs = XentScratch::new(n, v);
            let lc = softmax_xent_cols(None, &mut chunked, &targets, v, inv_n, true, &mut xs);
            if col_shards(v) == 1 {
                if lc.to_bits() != lf.to_bits() || chunked != fused {
                    return Err(format!("single-chunk xent not bit-exact at {n}x{v}"));
                }
            } else {
                let rel = (lc - lf).abs() / lf.abs().max(1e-30);
                if rel > 1e-12 {
                    return Err(format!("chunked loss off by {rel:e} at {n}x{v}"));
                }
                for (i, (&c, &f)) in chunked.iter().zip(fused.iter()).enumerate() {
                    let d = ulp_diff(c, f);
                    if d > 1 {
                        return Err(format!("dlogit[{i}] {d} ulps apart at {n}x{v}"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Batch-1 spec: one row shard, so every parallel gain and every potential
/// determinism hazard lives on the column axis. Vocab 64 → 4 column chunks
/// at the LM head; d_ff 64 → 4 on the MLP; d_model 32 → 2 elsewhere.
fn batch1_spec() -> NativeSpec {
    NativeSpec {
        name: "b1".into(),
        model: ModelMeta {
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            seq_len: 16,
            batch_size: 1,
            use_pallas_attention: false,
        },
        train: TrainMeta {
            lr: 1e-3,
            warmup_steps: 4,
            total_steps: 1_000_000,
            weight_decay: 0.1,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            min_lr_ratio: 0.1,
        },
        n_fragments: 2, // build_layout needs K <= n_layers
        seed: 0,
    }
}

fn run_curve_b1(method: MethodKind, threads: usize) -> (Vec<(u32, f64)>, f32) {
    let backend = NativeBackend::new(batch1_spec()).unwrap();
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 2;
    cfg.h_steps = 8;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 24;
    cfg.eval_every = 6;
    cfg.eval_batches = 2;
    cfg.threads = threads;
    cfg.parallel_workers = threads > 1;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    let out = tr.run().unwrap();
    let curve = out.curve.points.iter().map(|p| (p.step, p.loss)).collect();
    (curve, out.final_train_loss)
}

/// The acceptance gate of the 2D partition: batch-1 curves (column shards
/// only — the case PR 9's row sharding could not touch) are bit-identical
/// across `--threads 1/2/4/8` for DiLoCo, Streaming DiLoCo and CoCoDC.
#[test]
fn batch1_thread_count_never_changes_the_math() {
    for method in MethodKind::all() {
        let serial = run_curve_b1(method, 1);
        assert!(serial.0.len() >= 3, "{method:?}: curve too short to be meaningful");
        assert!(serial.1.is_finite());
        for threads in [2usize, 4, 8] {
            let pooled = run_curve_b1(method, threads);
            assert_eq!(
                serial, pooled,
                "{method:?}: batch-1 --threads {threads} diverged from --threads 1"
            );
        }
    }
}
