//! Intra-step data parallelism: the determinism and kernel-equivalence
//! contracts of DESIGN.md §Parallelism.
//!
//!  1. Full trainer runs over all three sync methods produce bit-identical
//!     eval curves and final train losses for `--threads` 1/2/4/8 — shard
//!     count and reduction order are functions of the model shape alone,
//!     never of the pool size.
//!  2. A pooled run nests scopes (worker fan-out outside, row shards
//!     inside) on a pool smaller than the total task count; a watchdog
//!     turns a nested-scope deadlock into a test failure instead of a hung
//!     suite.
//!  3. The tiled matmul kernels are *exactly* equal (bit-identical, not
//!     1-ulp) to the seed triple-loop references at awkward shapes that
//!     exercise every register-tile remainder path.

use std::sync::mpsc;
use std::time::Duration;

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::runtime::NativeBackend;
use cocodc::util::proptest::forall;
use cocodc::util::vecops::{self, reference};
use cocodc::Trainer;

/// One short tiny-preset run; returns the eval curve and final train loss.
/// Everything except `threads`/`parallel_workers` is held fixed, so any
/// difference between return values is the pool changing the math.
fn run_curve(method: MethodKind, threads: usize) -> (Vec<(u32, f64)>, f32) {
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 2;
    cfg.h_steps = 8;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 24;
    cfg.eval_every = 6;
    cfg.eval_batches = 2;
    cfg.threads = threads;
    cfg.parallel_workers = threads > 1;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    let out = tr.run().unwrap();
    let curve = out.curve.points.iter().map(|p| (p.step, p.loss)).collect();
    (curve, out.final_train_loss)
}

#[test]
fn thread_count_never_changes_the_math() {
    for method in MethodKind::all() {
        let serial = run_curve(method, 1);
        assert!(serial.0.len() >= 3, "{method:?}: curve too short to be meaningful");
        assert!(serial.1.is_finite());
        for threads in [2usize, 4, 8] {
            let pooled = run_curve(method, threads);
            assert_eq!(
                serial, pooled,
                "{method:?}: --threads {threads} diverged from --threads 1"
            );
        }
    }
}

/// Regression for the nested-scope deadlock: 2 workers × 2 row shards × 2
/// parallel eval batches on a 2-thread pool forces row-shard scopes to open
/// from inside already-running pool tasks with no idle thread left — only
/// job stealing by the blocked openers lets the run finish.
#[test]
fn pooled_run_with_nested_scopes_terminates() {
    let (tx, rx) = mpsc::channel();
    let watched = std::thread::spawn(move || {
        let out = run_curve(MethodKind::Cocodc, 2);
        tx.send(out).expect("send watchdog result");
    });
    let (curve, _) = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("pooled trainer run deadlocked (watchdog timeout)");
    watched.join().expect("watchdog thread panicked");
    assert!(!curve.is_empty());
}

/// Shapes covering every tile remainder: unit dims, sub-tile dims, exact
/// tile multiples, odd primes straddling MR/NR/LANES boundaries.
const SHAPES: [(usize, usize, usize); 8] = [
    (1, 1, 1),
    (2, 3, 5),
    (5, 7, 9),
    (8, 8, 8),
    (13, 17, 19),
    (33, 9, 40),
    (6, 64, 66),
    (23, 31, 29),
];

#[test]
fn tiled_matmul_bit_identical_to_reference() {
    forall(8, |rng| {
        for &(n, m, p) in &SHAPES {
            let a = rng.f32_vec(n * m, 1.0);
            let b = rng.f32_vec(m * p, 1.0);
            let mut got = vec![f32::NAN; n * p];
            let mut want = vec![f32::NAN; n * p];
            vecops::matmul(&mut got, &a, &b, n, m, p);
            reference::matmul(&mut want, &a, &b, n, m, p);
            if got != want {
                return Err(format!("matmul {n}x{m}x{p} not bit-identical"));
            }
        }
        Ok(())
    });
}

#[test]
fn tiled_matmul_bt_bit_identical_to_reference() {
    forall(8, |rng| {
        for &(n, m, p) in &SHAPES {
            let dout = rng.f32_vec(n * p, 1.0);
            let b = rng.f32_vec(m * p, 1.0);
            let mut got = vec![f32::NAN; n * m];
            let mut want = vec![f32::NAN; n * m];
            vecops::matmul_bt(&mut got, &dout, &b, n, m, p);
            reference::matmul_bt(&mut want, &dout, &b, n, m, p);
            if got != want {
                return Err(format!("matmul_bt {n}x{m}x{p} not bit-identical"));
            }
        }
        Ok(())
    });
}

#[test]
fn tiled_matmul_at_acc_bit_identical_to_reference() {
    forall(8, |rng| {
        for &(n, m, p) in &SHAPES {
            let a = rng.f32_vec(n * m, 1.0);
            let dout = rng.f32_vec(n * p, 1.0);
            // Accumulate into a shared non-zero starting buffer: the kernel
            // adds into gb, and the initial value is part of the contract.
            let init = rng.f32_vec(m * p, 1.0);
            let mut got = init.clone();
            let mut want = init;
            vecops::matmul_at_acc(&mut got, &a, &dout, n, m, p);
            reference::matmul_at_acc(&mut want, &a, &dout, n, m, p);
            if got != want {
                return Err(format!("matmul_at_acc {n}x{m}x{p} not bit-identical"));
            }
        }
        Ok(())
    });
}
