//! Multi-region topology integration tests:
//!
//! * the `flat` net-preset expansion is bit-identical to the pre-topology
//!   simulator — loss curve, wall-clock, sync stats, final worker params —
//!   and keeps the exact legacy 32-value `run/net` checkpoint layout,
//!   including a mid-run save → restore → continue;
//! * on `global-4` the hierarchical two-level sync finishes in strictly
//!   less simulated wall-clock than the matched flat single link and
//!   reports per-link utilization;
//! * per-link/per-region timelines survive a checkpoint round trip
//!   (the 36 + 8·links + 2·regions `run/net` layout) bit-exactly;
//! * a regional outage delays — but never changes — the training math.

use cocodc::config::{
    net_preset, FaultWindow, MethodKind, RegionalOutage, RunConfig, TauMode, TopologyConfig,
};
use cocodc::runtime::NativeBackend;
use cocodc::{TrainOutcome, Trainer};

fn tiny_cfg(method: MethodKind) -> RunConfig {
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 8;
    cfg.h_steps = 10;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 50;
    cfg.eval_every = 10;
    cfg.eval_batches = 2;
    cfg
}

/// Apply a `--net-preset` the way the CLIs do: matched flat-equivalent
/// network (compute pacing preserved) plus the region graph.
fn apply_preset(cfg: &mut RunConfig, name: &str) {
    let (net, topo) = net_preset(name).unwrap();
    let step = cfg.network.step_compute_s;
    cfg.network = net;
    cfg.network.step_compute_s = step;
    cfg.topology = topo;
}

fn run_one(cfg: RunConfig) -> (TrainOutcome, Vec<Vec<f32>>) {
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    let out = tr.run().unwrap();
    let params = (0..tr.workers().len()).map(|i| tr.worker_params(i).unwrap()).collect();
    (out, params)
}

#[test]
fn flat_net_preset_bit_identical_to_pre_topology_runs() {
    for method in MethodKind::all() {
        let (base, base_params) = run_one(tiny_cfg(method));
        let mut cfg = tiny_cfg(method);
        apply_preset(&mut cfg, "flat");
        let (flat, flat_params) = run_one(cfg);
        assert_eq!(base.curve.points.len(), flat.curve.points.len());
        for (a, b) in base.curve.points.iter().zip(&flat.curve.points) {
            assert_eq!(a.loss, b.loss, "{method:?}: flat preset changed the loss curve");
            assert_eq!(a.wall_s, b.wall_s, "{method:?}: flat preset changed the clock");
        }
        assert_eq!(base.wall_s, flat.wall_s);
        assert_eq!(base.syncs_completed, flat.syncs_completed);
        assert_eq!(base.bytes_sent, flat.bytes_sent);
        assert_eq!(base_params, flat_params, "{method:?}: final worker params diverged");
        assert!(flat.link_util.is_empty(), "flat run must not report per-link stats");
    }
}

#[test]
fn flat_preset_checkpoint_roundtrip_matches_uninterrupted_run() {
    let mk_cfg = |total: u32| {
        let mut cfg = tiny_cfg(MethodKind::Diloco);
        apply_preset(&mut cfg, "flat");
        cfg.total_steps = total;
        cfg.eval_every = 5;
        cfg
    };
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut full = Trainer::new(&backend, mk_cfg(40)).unwrap();
    let out_full = full.run().unwrap();

    let mut first = Trainer::new(&backend, mk_cfg(20)).unwrap();
    let _ = first.run().unwrap();
    let ck = first.checkpoint(20).unwrap();
    // Flat runs must keep the exact legacy `run/net` layout (32 values) so
    // pre-topology checkpoints and flat-preset checkpoints stay mutually
    // compatible.
    assert_eq!(ck.get("run/net").unwrap().len(), 32);
    drop(first);
    let mut resumed = Trainer::new(&backend, mk_cfg(40)).unwrap();
    resumed.restore(&ck).unwrap();
    let out_resumed = resumed.run().unwrap();
    for rp in &out_resumed.curve.points {
        let fp = out_full
            .curve
            .points
            .iter()
            .find(|p| p.step == rp.step)
            .unwrap_or_else(|| panic!("full run has no eval at step {}", rp.step));
        assert_eq!(rp.loss, fp.loss, "loss diverged at step {}", rp.step);
        assert_eq!(rp.wall_s, fp.wall_s, "wall-clock diverged at step {}", rp.step);
    }
    assert_eq!(out_resumed.wall_s, out_full.wall_s);
}

#[test]
fn hierarchical_global4_beats_matched_flat_single_link() {
    // DiLoCo pays every sync as a blocking stall, so the wall-clock gap is
    // exactly the WAN schedule difference: the two-level sync (LAN
    // all-reduce, leader ring over the mesh, LAN broadcast) must beat the
    // matched flat link whose latency/bandwidth are the mesh means.
    let mut flat_cfg = tiny_cfg(MethodKind::Diloco);
    apply_preset(&mut flat_cfg, "global-4");
    let hier_cfg = flat_cfg.clone();
    flat_cfg.topology = TopologyConfig::flat();
    let (flat, _) = run_one(flat_cfg);
    let (hier, _) = run_one(hier_cfg);
    assert!(flat.link_util.is_empty());
    assert_eq!(hier.link_util.len(), 12, "global-4 is a 4-region full mesh");
    assert!(hier.link_util.iter().map(|l| l.bytes).sum::<f64>() > 0.0);
    assert!(
        hier.wall_s < flat.wall_s,
        "hierarchical ({:.2}s) must beat matched flat ({:.2}s)",
        hier.wall_s,
        flat.wall_s
    );
    // The blocking schedule is step-driven either way: topology changes
    // when syncs land on the clock, never what they compute.
    for (a, b) in flat.curve.points.iter().zip(&hier.curve.points) {
        assert_eq!(a.loss, b.loss, "topology changed the sync math");
    }

    // CoCoDC on the same mesh exercises the adaptive per-link scheduler
    // end-to-end: the run must spread fragments over several links and not
    // be slower than its own matched-flat twin.
    let mut c_flat = tiny_cfg(MethodKind::Cocodc);
    c_flat.tau = TauMode::Network;
    apply_preset(&mut c_flat, "global-4");
    let c_hier = c_flat.clone();
    c_flat.topology = TopologyConfig::flat();
    let (cf, _) = run_one(c_flat);
    let (ch, _) = run_one(c_hier);
    assert!(ch.curve.points.iter().all(|p| p.loss.is_finite()));
    assert!(ch.syncs_completed > 0, "cocodc never synced on the mesh");
    assert!(
        ch.link_util.iter().filter(|l| l.transfers > 0).count() >= 2,
        "adaptive routing never left a single link"
    );
    assert!(
        ch.wall_s <= cf.wall_s + 1e-9,
        "cocodc hierarchical ({:.2}s) slower than matched flat ({:.2}s)",
        ch.wall_s,
        cf.wall_s
    );
}

#[test]
fn per_link_timelines_survive_checkpoint_roundtrip() {
    let mk_cfg = |total: u32| {
        let mut cfg = tiny_cfg(MethodKind::Diloco);
        apply_preset(&mut cfg, "global-4");
        cfg.total_steps = total;
        cfg.eval_every = 5;
        cfg
    };
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut full = Trainer::new(&backend, mk_cfg(40)).unwrap();
    let out_full = full.run().unwrap();

    let mut first = Trainer::new(&backend, mk_cfg(20)).unwrap();
    let _ = first.run().unwrap();
    let ck = first.checkpoint(20).unwrap();
    // 32 flat values, a [links, regions] header, then 8 values per link
    // (busy/bytes/busy_s/transfers as f64/u64 pairs) and 2 per region:
    // 36 + 8·12 + 2·4 on the 4-region mesh.
    assert_eq!(ck.get("run/net").unwrap().len(), 36 + 8 * 12 + 2 * 4);
    drop(first);
    let mut resumed = Trainer::new(&backend, mk_cfg(40)).unwrap();
    resumed.restore(&ck).unwrap();
    let out_resumed = resumed.run().unwrap();
    for rp in &out_resumed.curve.points {
        let fp = out_full
            .curve
            .points
            .iter()
            .find(|p| p.step == rp.step)
            .unwrap_or_else(|| panic!("full run has no eval at step {}", rp.step));
        assert_eq!(rp.loss, fp.loss, "loss diverged at step {}", rp.step);
        assert_eq!(rp.wall_s, fp.wall_s, "per-link timelines lost at step {}", rp.step);
    }
    assert_eq!(out_resumed.wall_s, out_full.wall_s);
    // Cumulative per-link counters restored from the checkpoint must land
    // on the uninterrupted run's totals.
    assert_eq!(out_resumed.link_util, out_full.link_util);
}

#[test]
fn regional_outage_stalls_syncs_crossing_its_window() {
    let mut clean_cfg = tiny_cfg(MethodKind::Diloco);
    apply_preset(&mut clean_cfg, "global-4");
    let mut outage_cfg = clean_cfg.clone();
    outage_cfg.faults.regional_outages.push(RegionalOutage {
        region: 1,
        window: FaultWindow { start_s: 1.0, duration_s: 3.0 },
    });
    let (clean, _) = run_one(clean_cfg);
    let (hit, _) = run_one(outage_cfg);
    // The first blocking sync lands at ~1.5s, inside the [1, 4) severance
    // of every WAN link touching region 1: that round queues behind the
    // window end while later rounds run at full speed.
    assert!(
        hit.wall_s > clean.wall_s + 1.0,
        "regional outage never stalled the run ({:.2}s vs {:.2}s)",
        hit.wall_s,
        clean.wall_s
    );
    for (a, b) in clean.curve.points.iter().zip(&hit.curve.points) {
        assert_eq!(a.loss, b.loss, "an outage must delay syncs, not change them");
    }
}
