//! Hot-path correctness: property tests pinning every fused/unrolled
//! vecops kernel to its naive scalar reference within 1 ulp (covering all
//! remainder lanes 0..=64 and large random vectors), plus BufferPool
//! steady-state behavior under the real streaming/CoCoDC strategies.

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::coordinator::strategy::SyncCtx;
use cocodc::coordinator::{make_strategy, FragmentTable, GlobalState, SyncStats};
use cocodc::network::WanSimulator;
use cocodc::runtime::{Backend, HostBackend, WorkerHandle};
use cocodc::simclock::VirtualClock;
use cocodc::util::pool::BufferPool;
use cocodc::util::proptest::forall;
use cocodc::util::vecops::{self, reference};
use cocodc::util::Rng;

// ---------------------------------------------------------------------
// 1-ulp comparison
// ---------------------------------------------------------------------

/// Map a float to an integer whose ordering matches the float ordering, so
/// adjacent representable values differ by exactly 1.
fn ulp_key(x: f32) -> i64 {
    let b = x.to_bits() as i64;
    if b & 0x8000_0000 != 0 {
        0x8000_0000 - b
    } else {
        b
    }
}

fn ulp_check(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        if g.is_nan() && w.is_nan() {
            continue;
        }
        if g.is_nan() != w.is_nan() {
            return Err(format!("{what}: elem {i}: {g} vs {w} (NaN mismatch)"));
        }
        let d = (ulp_key(g) - ulp_key(w)).abs();
        if d > 1 {
            return Err(format!("{what}: elem {i}: {g} vs {w} differ by {d} ulp"));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// kernel property tests
// ---------------------------------------------------------------------

fn check_all_kernels(rng: &mut Rng, n: usize) -> Result<(), String> {
    let a = rng.f32_vec(n, 1.0);
    let b = rng.f32_vec(n, 1.0);
    let (tau, h, lambda) = (
        1.0 + rng.next_f64() as f32 * 9.0,
        10.0 + rng.next_f64() as f32 * 90.0,
        rng.next_f64() as f32,
    );

    // sub
    let mut got = vec![0.0; n];
    let mut want = vec![0.0; n];
    vecops::sub(&mut got, &a, &b);
    reference::sub(&mut want, &a, &b);
    ulp_check(&got, &want, "sub")?;

    // add_assign
    let mut got = a.clone();
    let mut want = a.clone();
    vecops::add_assign(&mut got, &b);
    reference::add_assign(&mut want, &b);
    ulp_check(&got, &want, "add_assign")?;

    // scale
    let s = rng.next_f64() as f32 * 2.0 - 1.0;
    let mut got = a.clone();
    let mut want = a.clone();
    vecops::scale(&mut got, s);
    reference::scale(&mut want, s);
    ulp_check(&got, &want, "scale")?;

    // mean_of / fused_pseudo_mean over 1..=5 rows
    let m = rng.usize_in(1, 5);
    let rows: Vec<Vec<f32>> = (0..m).map(|_| rng.f32_vec(n, 1.0)).collect();
    let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let mut got = vec![0.0; n];
    let mut want = vec![0.0; n];
    vecops::mean_of(&mut got, &row_refs);
    reference::mean_of(&mut want, &row_refs);
    ulp_check(&got, &want, "mean_of")?;

    let theta_g = rng.f32_vec(n, 1.0);
    let mut got = vec![0.0; n];
    let mut want = vec![0.0; n];
    vecops::fused_pseudo_mean(&mut got, &row_refs, &theta_g);
    reference::pseudo_mean(&mut want, &row_refs, &theta_g);
    ulp_check(&got, &want, "fused_pseudo_mean")?;

    // The documented reassociation vs the seed accumulation order stays
    // tiny (a few ulps per element; bound loosely here).
    let mut seed_order = vec![0.0; n];
    reference::mean_pseudo_gradients_seed(&mut seed_order, &row_refs, &theta_g);
    for (i, (&x, &y)) in got.iter().zip(&seed_order).enumerate() {
        if (x - y).abs() > 1e-5 * (1.0 + y.abs()) {
            return Err(format!("pseudo_mean vs seed order: elem {i}: {x} vs {y}"));
        }
    }

    // delay compensation, in place and out of place
    let tl = rng.f32_vec(n, 1.0);
    let tp = rng.f32_vec(n, 1.0);
    let mut got = tl.clone();
    let mut want = tl.clone();
    vecops::fused_delay_comp(&mut got, &theta_g, &tp, tau, h, lambda);
    reference::delay_compensate_inplace(&mut want, &theta_g, &tp, tau, h, lambda);
    ulp_check(&got, &want, "fused_delay_comp")?;

    let mut got = vec![0.0; n];
    let mut want = vec![0.0; n];
    vecops::fused_delay_comp_into(&mut got, &theta_g, &tl, &tp, tau, h, lambda);
    reference::delay_compensate(&mut want, &theta_g, &tl, &tp, tau, h, lambda);
    ulp_check(&got, &want, "fused_delay_comp_into")?;

    // outer step (theta and momentum both checked)
    let delta = rng.f32_vec(n, 0.1);
    let mut tg_got = theta_g.clone();
    let mut mom_got = rng.f32_vec(n, 0.1);
    let mut tg_want = tg_got.clone();
    let mut mom_want = mom_got.clone();
    vecops::fused_outer_step(&mut tg_got, &delta, &mut mom_got, 0.7, 0.9);
    reference::outer_step(&mut tg_want, &delta, &mut mom_want, 0.7, 0.9);
    ulp_check(&tg_got, &tg_want, "fused_outer_step theta")?;
    ulp_check(&mom_got, &mom_want, "fused_outer_step momentum")?;

    // alpha blend
    let alpha = rng.next_f64() as f32;
    let mut got = tl.clone();
    let mut want = tl.clone();
    vecops::fused_alpha_blend(&mut got, &theta_g, alpha);
    reference::alpha_blend(&mut want, &theta_g, alpha);
    ulp_check(&got, &want, "fused_alpha_blend")?;

    // max_abs_diff agrees with a scalar maximum on clean data
    let mad = vecops::max_abs_diff(&a, &b);
    let want_mad = a
        .iter()
        .zip(&b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    if mad != want_mad {
        return Err(format!("max_abs_diff: {mad} vs {want_mad}"));
    }
    Ok(())
}

#[test]
fn kernels_match_reference_on_every_remainder_length() {
    // Exhaustive over 0..=64: every possible 8-lane remainder, repeatedly.
    let mut rng = Rng::new(0xFADE, 0);
    for n in 0..=64usize {
        check_all_kernels(&mut rng, n).unwrap_or_else(|e| panic!("n={n}: {e}"));
    }
}

#[test]
fn prop_kernels_match_reference_on_large_vectors() {
    forall(24, |rng| {
        let n = rng.usize_in(65, 8192);
        check_all_kernels(rng, n)
    });
}

#[test]
fn max_abs_diff_nan_contract() {
    // Documented behavior: a poisoned fragment must not compare clean.
    assert!(vecops::max_abs_diff(&[0.0, f32::NAN, 1.0], &[0.0, 0.0, 1.0]).is_nan());
    assert!(vecops::max_abs_diff(&[f32::INFINITY], &[f32::INFINITY]).is_nan());
    // Clean data keeps the plain maximum (including infinities).
    assert_eq!(
        vecops::max_abs_diff(&[f32::INFINITY, 1.0], &[0.0, 1.0]),
        f32::INFINITY
    );
}

// ---------------------------------------------------------------------
// BufferPool steady state under the real strategies
// ---------------------------------------------------------------------

struct Sim {
    cfg: RunConfig,
    frags: FragmentTable,
    backend: HostBackend,
    workers: Vec<WorkerHandle>,
    global: GlobalState,
    net: WanSimulator,
    clock: VirtualClock,
    stats: SyncStats,
    pool: BufferPool,
    rng: Rng,
}

impl Sim {
    fn new(method: MethodKind, k: usize, h: u32, tau: u32, workers: usize) -> Sim {
        let frags = FragmentTable::from_sizes(&vec![64; k]);
        let mut cfg = RunConfig::paper("sim", method);
        cfg.workers = workers;
        cfg.h_steps = h;
        cfg.tau = TauMode::Fixed { tau };
        let backend = HostBackend::new(frags.clone());
        let init = backend.init_params().unwrap();
        Sim {
            workers: (0..workers).map(|_| backend.create_worker().unwrap()).collect(),
            global: GlobalState::new(&init),
            net: WanSimulator::new(cfg.network, workers, 3),
            clock: VirtualClock::new(),
            stats: SyncStats::new(k),
            pool: BufferPool::new(),
            rng: Rng::new(23, 0),
            backend,
            cfg,
            frags,
        }
    }

    fn drift(&mut self, step: u32) {
        for w in self.workers.iter_mut() {
            let st = self.backend.state_mut(w);
            for x in st.params.iter_mut() {
                *x += 0.01 * self.rng.next_gaussian() as f32;
            }
            st.step = step;
        }
        self.clock.advance_compute(self.cfg.network.step_compute_s);
    }

    fn params(&self, i: usize) -> Vec<f32> {
        self.backend.state(&self.workers[i]).params.clone()
    }

    fn ctx(&mut self) -> SyncCtx<'_> {
        SyncCtx {
            workers: &mut self.workers,
            global: &mut self.global,
            net: &mut self.net,
            clock: &mut self.clock,
            backend: &self.backend,
            cfg: &self.cfg,
            frags: &self.frags,
            stats: &mut self.stats,
            pool: &mut self.pool,
            threads: None,
            live: None,
        }
    }
}

#[test]
fn pool_reaches_zero_fresh_allocations_after_warmup() {
    for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
        let mut sim = Sim::new(method, 4, 20, 3, 3);
        let mut strategy = make_strategy(&sim.cfg, &sim.frags);
        // Warm-up: several full H windows of initiate/complete cycles.
        for step in 1..=80 {
            sim.drift(step);
            strategy.post_step(step, &mut sim.ctx()).unwrap();
        }
        let warm = sim.pool.stats();
        assert!(warm.fresh > 0, "{method:?}: pool never used");
        assert!(sim.stats.syncs_completed > 0, "{method:?}: no syncs during warm-up");
        // Steady state: buffers must recycle, never allocate.
        for step in 81..=320 {
            sim.drift(step);
            strategy.post_step(step, &mut sim.ctx()).unwrap();
        }
        let after = sim.pool.stats();
        assert_eq!(
            after.fresh, warm.fresh,
            "{method:?}: fresh allocations grew after warm-up ({warm:?} -> {after:?})"
        );
        assert!(
            after.reused > warm.reused,
            "{method:?}: steady state did not reuse buffers"
        );
    }
}

#[test]
fn pool_outstanding_matches_in_flight_syncs() {
    // Every in-flight CoCoDC sync holds M snapshots + 1 delta buffer; when
    // nothing is pending, nothing is outstanding.
    let mut sim = Sim::new(MethodKind::Cocodc, 3, 12, 2, 4);
    let mut strategy = make_strategy(&sim.cfg, &sim.frags);
    for step in 1..=200 {
        sim.drift(step);
        strategy.post_step(step, &mut sim.ctx()).unwrap();
        let expect = strategy.pending() * (sim.cfg.workers + 1);
        assert_eq!(
            sim.pool.stats().outstanding,
            expect,
            "step {step}: {} pendings",
            strategy.pending()
        );
    }
}

#[test]
fn strategies_behave_identically_with_shared_pool() {
    // Two sims with identical drift, one pool fresh per run: the pooled
    // path must not change the training math (bit-identical worker state).
    let run = |steps: u32| {
        let mut sim = Sim::new(MethodKind::Cocodc, 4, 16, 3, 3);
        let mut strategy = make_strategy(&sim.cfg, &sim.frags);
        for step in 1..=steps {
            sim.drift(step);
            strategy.post_step(step, &mut sim.ctx()).unwrap();
        }
        (sim.params(0), sim.global.theta_g.clone())
    };
    let (w1, g1) = run(120);
    let (w2, g2) = run(120);
    assert_eq!(w1, w2);
    assert_eq!(g1, g2);
}
