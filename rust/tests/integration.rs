//! Integration tests across runtime + coordinator.
//!
//! Two tiers:
//!  * pure-simulation tests (always run): strategies driven with synthetic
//!    worker drift, property tests over the coordinator invariants;
//!  * PJRT tests (need `make artifacts`, skipped with a notice otherwise):
//!    artifact loading, train-step convergence, rust-vs-HLO fragment ops,
//!    full Trainer runs for all three methods, checkpoint round-trip.

use std::path::Path;
use std::sync::OnceLock;

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::coordinator::strategy::SyncCtx;
use cocodc::coordinator::{
    delay_comp::delay_compensate, make_strategy, outer_opt, FragmentTable,
    GlobalState, SyncStats,
};
use cocodc::network::WanSimulator;
use cocodc::runtime::{Backend, Engine, HostBackend, PjrtBackend, TrainState, WorkerHandle};
use cocodc::simclock::VirtualClock;
use cocodc::util::pool::BufferPool;
use cocodc::util::proptest::forall;
use cocodc::util::Rng;
use cocodc::Trainer;

// ---------------------------------------------------------------------
// pure-simulation harness
// ---------------------------------------------------------------------

struct Sim {
    cfg: RunConfig,
    frags: FragmentTable,
    backend: HostBackend,
    workers: Vec<WorkerHandle>,
    global: GlobalState,
    net: WanSimulator,
    clock: VirtualClock,
    stats: SyncStats,
    pool: BufferPool,
    rng: Rng,
}

impl Sim {
    fn new(method: MethodKind, k: usize, h: u32, tau: u32, workers: usize) -> Sim {
        let frags = FragmentTable::from_sizes(&vec![64; k]);
        let mut cfg = RunConfig::paper("sim", method);
        cfg.workers = workers;
        cfg.h_steps = h;
        cfg.tau = TauMode::Fixed { tau };
        let backend = HostBackend::new(frags.clone());
        let init = backend.init_params().unwrap();
        Sim {
            workers: (0..workers).map(|_| backend.create_worker().unwrap()).collect(),
            global: GlobalState::new(&init),
            net: WanSimulator::new(cfg.network, workers, 3),
            clock: VirtualClock::new(),
            stats: SyncStats::new(k),
            pool: BufferPool::new(),
            rng: Rng::new(11, 0),
            backend,
            cfg,
            frags,
        }
    }

    /// One lockstep "training" step: every worker drifts a bit.
    fn drift(&mut self, step: u32) {
        for w in self.workers.iter_mut() {
            let st = self.backend.state_mut(w);
            for x in st.params.iter_mut() {
                *x += 0.01 * self.rng.next_gaussian() as f32;
            }
            st.step = step;
        }
        self.clock.advance_compute(self.cfg.network.step_compute_s);
    }

    fn params(&self, i: usize) -> Vec<f32> {
        self.backend.state(&self.workers[i]).params.clone()
    }

    fn set_all_params(&mut self, f: impl Fn(&mut f32)) {
        for w in self.workers.iter_mut() {
            for x in self.backend.state_mut(w).params.iter_mut() {
                f(x);
            }
        }
    }

    fn ctx(&mut self) -> SyncCtx<'_> {
        SyncCtx {
            workers: &mut self.workers,
            global: &mut self.global,
            net: &mut self.net,
            clock: &mut self.clock,
            backend: &self.backend,
            cfg: &self.cfg,
            frags: &self.frags,
            stats: &mut self.stats,
            pool: &mut self.pool,
            threads: None,
            live: None,
        }
    }
}

#[test]
fn diloco_syncs_exactly_every_h_and_workers_agree() {
    let mut sim = Sim::new(MethodKind::Diloco, 3, 10, 1, 4);
    let mut strategy = make_strategy(&sim.cfg, &sim.frags);
    for step in 1..=35 {
        sim.drift(step);
        strategy.post_step(step, &mut sim.ctx()).unwrap();
        if step % 10 == 0 {
            // All workers adopt the identical global state.
            for w in 1..sim.workers.len() {
                assert_eq!(sim.params(0), sim.params(w));
            }
            assert_eq!(sim.params(0), sim.global.theta_g);
        }
    }
    // 3 rounds x 3 fragments.
    assert_eq!(sim.stats.syncs_completed, 9);
    assert_eq!(sim.stats.per_fragment, vec![3, 3, 3]);
    // Blocking sync stalls the virtual clock.
    assert!(sim.clock.comm_stall_s() > 0.0);
}

#[test]
fn streaming_initiates_each_fragment_once_per_h() {
    let mut sim = Sim::new(MethodKind::StreamingDiloco, 4, 20, 3, 3);
    let mut strategy = make_strategy(&sim.cfg, &sim.frags);
    for step in 1..=80 {
        sim.drift(step);
        strategy.post_step(step, &mut sim.ctx()).unwrap();
    }
    // 4 H-windows x 4 fragments, minus any still in flight at the end.
    assert!(sim.stats.syncs_initiated >= 15, "{}", sim.stats.syncs_initiated);
    assert!(sim.stats.syncs_completed >= 12);
    // Round-robin: balanced counts (within one in-flight sync).
    let max = *sim.stats.per_fragment.iter().max().unwrap() as i64;
    let min = *sim.stats.per_fragment.iter().min().unwrap() as i64;
    assert!(max - min <= 1, "{:?}", sim.stats.per_fragment);
    // Overlap: streaming never stalls the clock on this easy network.
    assert_eq!(sim.clock.comm_stall_s(), 0.0);
}

#[test]
fn streaming_blend_moves_workers_toward_global() {
    let mut sim = Sim::new(MethodKind::StreamingDiloco, 2, 10, 2, 2);
    sim.cfg.alpha = 0.5;
    let mut strategy = make_strategy(&sim.cfg, &sim.frags);
    // Give workers a large offset so the blend is visible.
    sim.set_all_params(|x| *x = 1.0);
    let mut applied = false;
    for step in 1..=30 {
        let before: Vec<f32> = sim.params(0);
        strategy.post_step(step, &mut sim.ctx()).unwrap();
        if sim.stats.syncs_completed > 0 && !applied {
            applied = true;
            // After the first completion some fragment must have moved.
            assert_ne!(before, sim.params(0));
        }
        sim.drift(step);
    }
    assert!(applied, "no sync ever completed");
}

#[test]
fn cocodc_syncs_more_often_and_respects_staleness_guard() {
    let mut stream = Sim::new(MethodKind::StreamingDiloco, 4, 40, 3, 3);
    let mut ccd = Sim::new(MethodKind::Cocodc, 4, 40, 3, 3);
    // Make the network fast enough that Eq. 9 allows > K syncs per H.
    for s in [&mut stream, &mut ccd] {
        s.cfg.network.latency_s = 0.01;
        s.cfg.gamma = 0.8;
    }
    let mut st1 = make_strategy(&stream.cfg, &stream.frags);
    let mut st2 = make_strategy(&ccd.cfg, &ccd.frags);
    for step in 1..=160 {
        stream.drift(step);
        ccd.drift(step);
        st1.post_step(step, &mut stream.ctx()).unwrap();
        st2.post_step(step, &mut ccd.ctx()).unwrap();
    }
    assert!(
        ccd.stats.syncs_completed > stream.stats.syncs_completed,
        "cocodc {} vs streaming {}",
        ccd.stats.syncs_completed,
        stream.stats.syncs_completed
    );
    // Staleness guard: every fragment synced at least once per H window
    // (4 windows of H=40 in 160 steps).
    for (p, &c) in ccd.stats.per_fragment.iter().enumerate() {
        assert!(c >= 3, "fragment {p} synced only {c} times");
    }
}

#[test]
fn cocodc_delay_comp_adopts_global_plus_progress() {
    // One fragment, lambda=0: after completion the worker state must equal
    // theta_g_new + (theta_now - theta_snapshot).
    let mut sim = Sim::new(MethodKind::Cocodc, 1, 10, 2, 2);
    sim.cfg.lambda = 0.0;
    sim.cfg.gamma = 1.0;
    let mut strategy = make_strategy(&sim.cfg, &sim.frags);
    // Constant drift so we can predict the local progress.
    for step in 1..=40 {
        sim.set_all_params(|x| *x += 0.5);
        sim.clock.advance_compute(0.15);
        strategy.post_step(step, &mut sim.ctx()).unwrap();
    }
    assert!(sim.stats.syncs_completed > 0);
    // With identical workers, delta = theta_snap - theta_g; outer step moves
    // theta_g; compensation then adds the tau-step local progress (tau*0.5).
    // We just assert workers stayed identical & finite (exact closed form is
    // covered by unit tests).
    for i in 0..sim.workers.len() {
        assert!(sim.params(i).iter().all(|x| x.is_finite()));
        assert_eq!(sim.params(i), sim.params(0));
    }
}

// ---------------------------------------------------------------------
// property tests (coordinator invariants; dist-train guide: proptest on
// routing/batching/state)
// ---------------------------------------------------------------------

#[test]
fn prop_streaming_balanced_schedules() {
    forall(24, |rng| {
        let k = rng.usize_in(1, 6);
        let h = rng.usize_in(k.max(2), 60) as u32;
        let tau = rng.usize_in(1, (h - 1) as usize) as u32;
        let workers = rng.usize_in(1, 5);
        let mut sim = Sim::new(MethodKind::StreamingDiloco, k, h, tau, workers);
        let mut strategy = make_strategy(&sim.cfg, &sim.frags);
        let windows = 3u32;
        for step in 1..=windows * h {
            sim.drift(step);
            strategy
                .post_step(step, &mut sim.ctx())
                .map_err(|e| e.to_string())?;
        }
        let max = *sim.stats.per_fragment.iter().max().unwrap() as i64;
        let min = *sim.stats.per_fragment.iter().min().unwrap() as i64;
        if max - min > 1 {
            return Err(format!(
                "unbalanced per-fragment syncs: {:?} (k={k} h={h} tau={tau})",
                sim.stats.per_fragment
            ));
        }
        if sim.stats.syncs_initiated < (windows as usize - 1) * k {
            return Err(format!(
                "too few syncs: {} for k={k} h={h}",
                sim.stats.syncs_initiated
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_cocodc_staleness_guard_bounds_intervals() {
    forall(16, |rng| {
        let k = rng.usize_in(2, 5);
        let h = rng.usize_in(20, 60) as u32;
        let tau = rng.usize_in(1, 8) as u32;
        let mut sim = Sim::new(MethodKind::Cocodc, k, h, tau, 3);
        sim.cfg.gamma = 0.2 + 0.6 * rng.next_f64();
        let mut strategy = make_strategy(&sim.cfg, &sim.frags);
        let total = 4 * h;
        for step in 1..=total {
            sim.drift(step);
            strategy
                .post_step(step, &mut sim.ctx())
                .map_err(|e| e.to_string())?;
        }
        // Every fragment must complete >= floor(total/h) - 2 syncs (guard
        // allows tau slack at window edges).
        let floor = (total / h).saturating_sub(2) as usize;
        for (p, &c) in sim.stats.per_fragment.iter().enumerate() {
            if c < floor {
                return Err(format!(
                    "fragment {p} synced {c} < {floor} (k={k} h={h} tau={tau})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_workers_stay_identical_under_identical_data() {
    // If all workers drift identically, every method must keep them
    // bitwise identical (determinism of the consensus path).
    forall(12, |rng| {
        let method = match rng.below(3) {
            0 => MethodKind::Diloco,
            1 => MethodKind::StreamingDiloco,
            _ => MethodKind::Cocodc,
        };
        let mut sim = Sim::new(method, 3, 12, 2, 4);
        let mut strategy = make_strategy(&sim.cfg, &sim.frags);
        let mut drift_rng = Rng::new(rng.next_u64(), 1);
        for step in 1..=40 {
            let drift: Vec<f32> = (0..sim.frags.total_params())
                .map(|_| 0.02 * drift_rng.next_gaussian() as f32)
                .collect();
            for w in sim.workers.iter_mut() {
                let st = sim.backend.state_mut(w);
                for (x, d) in st.params.iter_mut().zip(&drift) {
                    *x += *d;
                }
            }
            sim.clock.advance_compute(0.1);
            strategy
                .post_step(step, &mut sim.ctx())
                .map_err(|e| e.to_string())?;
            for w in 1..sim.workers.len() {
                if sim.params(0) != sim.params(w) {
                    return Err(format!("worker {w} diverged at step {step}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn compression_reduces_wire_bytes_but_preserves_consensus_shape() {
    // int8-compressed pseudo-gradients must charge ~1/4 the bytes and keep
    // workers bitwise identical (the codec is deterministic + shared).
    let mut plain = Sim::new(MethodKind::Cocodc, 3, 12, 2, 4);
    let mut compressed = Sim::new(MethodKind::Cocodc, 3, 12, 2, 4);
    compressed.cfg.compression = cocodc::compression::Codec::Int8;
    let mut s1 = make_strategy(&plain.cfg, &plain.frags);
    let mut s2 = make_strategy(&compressed.cfg, &compressed.frags);
    for step in 1..=48 {
        plain.drift(step);
        compressed.drift(step);
        s1.post_step(step, &mut plain.ctx()).unwrap();
        s2.post_step(step, &mut compressed.ctx()).unwrap();
    }
    assert!(plain.stats.syncs_completed > 0);
    assert_eq!(plain.stats.syncs_initiated, compressed.stats.syncs_initiated);
    let ratio = compressed.stats.bytes / plain.stats.bytes;
    assert!(ratio < 0.27 && ratio > 0.2, "wire ratio {ratio}");
    // Quantization error must stay small: global states of the two sims
    // track each other closely (drift streams are identical).
    let maxd = plain
        .global
        .theta_g
        .iter()
        .zip(&compressed.global.theta_g)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxd < 0.05, "int8 consensus diverged by {maxd}");
    // All params remain finite under quantized syncs.
    for i in 0..compressed.workers.len() {
        assert!(compressed.params(i).iter().all(|x| x.is_finite()));
    }
}

#[test]
fn outage_stretches_network_tau_and_recovers() {
    // With TauMode::Network, a WAN outage at sync time must delay the apply
    // (larger effective tau) without breaking the schedule.
    let mut sim = Sim::new(MethodKind::StreamingDiloco, 2, 10, 1, 2);
    sim.cfg.tau = TauMode::Network;
    let mut strategy = make_strategy(&sim.cfg, &sim.frags);
    for step in 1..=10 {
        sim.drift(step);
        if step == 4 {
            let until = sim.clock.now() + 30.0;
            sim.net.inject_outage_until(until);
        }
        strategy.post_step(step, &mut sim.ctx()).unwrap();
    }
    // Pending syncs eventually complete once the outage clears.
    for step in 11..=400 {
        sim.drift(step);
        strategy.post_step(step, &mut sim.ctx()).unwrap();
    }
    assert!(sim.stats.syncs_completed >= 4, "{}", sim.stats.syncs_completed);
    assert!(
        sim.stats.syncs_completed + 4 >= sim.stats.syncs_initiated,
        "in-flight backlog never drained"
    );
}

#[test]
fn prop_outer_step_fixed_point() {
    // delta == 0 must leave theta unchanged when momentum buffer is zero.
    forall(20, |rng| {
        let n = rng.usize_in(1, 200);
        let mut theta = rng.f32_vec(n, 1.0);
        let orig = theta.clone();
        let mut mom = vec![0.0f32; n];
        outer_opt::outer_step(&mut theta, &vec![0.0; n], &mut mom, 0.7, 0.9);
        if theta != orig {
            return Err("outer step moved theta with zero delta".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// PJRT-backed tests (need artifacts/tiny)
// ---------------------------------------------------------------------

fn tiny_backend() -> Option<&'static PjrtBackend> {
    static BACKEND: OnceLock<Option<PjrtBackend>> = OnceLock::new();
    BACKEND
        .get_or_init(|| {
            let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            if !dir.join("tiny").join("meta.json").exists() {
                eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
                return None;
            }
            Some(PjrtBackend::load(&dir, "tiny", false).expect("backend load"))
        })
        .as_ref()
}

fn tiny_engine() -> Option<&'static Engine> {
    tiny_backend().map(|b| b.engine())
}

fn tiny_cfg(method: MethodKind) -> RunConfig {
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 2;
    cfg.h_steps = 8;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 24;
    cfg.eval_every = 8;
    cfg.eval_batches = 2;
    cfg.parallel_workers = false; // determinism for the tests below
    cfg
}

#[test]
fn engine_loads_and_init_params_match_meta() {
    let Some(engine) = tiny_engine() else { return };
    let meta = engine.meta();
    let init = engine.init_params().unwrap();
    assert_eq!(init.len(), meta.param_count);
    assert!(init.iter().all(|x| x.is_finite()));
    // Norm gains are initialized to exactly 1.
    let norm_leaf = meta.leaves.iter().find(|l| l.name.ends_with("attn_norm")).unwrap();
    assert!(init[norm_leaf.offset..norm_leaf.offset + norm_leaf.size]
        .iter()
        .all(|&x| x == 1.0));
}

#[test]
fn train_step_learns_fixed_batch() {
    let Some(engine) = tiny_engine() else { return };
    let meta = engine.meta();
    let mut state = TrainState::new(engine.init_params().unwrap());
    let mut rng = Rng::new(5, 0);
    let n = meta.batch_elems();
    let tokens: Vec<i32> =
        (0..n).map(|_| rng.below(meta.model.vocab_size as u64) as i32).collect();
    let mut targets = tokens.clone();
    targets.rotate_left(1);
    let first = engine.train_step(&mut state, &tokens, &targets).unwrap();
    let mut last = first;
    for _ in 0..25 {
        last = engine.train_step(&mut state, &tokens, &targets).unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first - 0.05, "no learning: {first} -> {last}");
    assert_eq!(state.step, 26);
}

#[test]
fn eval_is_deterministic_and_matches_scale() {
    let Some(engine) = tiny_engine() else { return };
    let meta = engine.meta();
    let params = engine.init_params().unwrap();
    let mut rng = Rng::new(6, 0);
    let n = meta.batch_elems();
    let tokens: Vec<i32> =
        (0..n).map(|_| rng.below(meta.model.vocab_size as u64) as i32).collect();
    let targets = tokens.clone();
    let a = engine.eval_loss(&params, &tokens, &targets).unwrap();
    let b = engine.eval_loss(&params, &tokens, &targets).unwrap();
    assert_eq!(a, b);
    // Near-uniform at init: loss ~ ln(vocab).
    let uniform = (meta.model.vocab_size as f32).ln();
    assert!((a - uniform).abs() < 0.5, "init loss {a} vs ln V {uniform}");
}

#[test]
fn hlo_delay_comp_matches_rust() {
    let Some(engine) = tiny_engine() else { return };
    let meta = engine.meta();
    for frag in &meta.fragments {
        let mut rng = Rng::new(frag.index as u64 + 1, 0);
        let n = frag.size;
        let tg = rng.f32_vec(n, 0.5);
        let tl = rng.f32_vec(n, 0.5);
        let tp = rng.f32_vec(n, 0.5);
        let (tau, h, lam) = (5.0, 100.0, 0.5);
        let hlo = engine
            .delay_comp_hlo(frag.index, &tg, &tl, &tp, tau, h, lam)
            .unwrap();
        let mut rust = vec![0.0f32; n];
        delay_compensate(&mut rust, &tg, &tl, &tp, tau, h, lam);
        let max = rust
            .iter()
            .zip(&hlo)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max < 1e-5, "fragment {}: maxdiff {max}", frag.index);
    }
}

#[test]
fn hlo_outer_step_matches_rust() {
    let Some(engine) = tiny_engine() else { return };
    let meta = engine.meta();
    let frag = meta.fragments[0];
    let mut rng = Rng::new(9, 0);
    let tg = rng.f32_vec(frag.size, 1.0);
    let delta = rng.f32_vec(frag.size, 0.1);
    let mom = rng.f32_vec(frag.size, 0.1);
    let (hlo_t, hlo_m) = engine
        .outer_step_hlo(frag.index, &tg, &delta, &mom, 0.7, 0.9)
        .unwrap();
    let mut rust_t = tg.clone();
    let mut rust_m = mom.clone();
    outer_opt::outer_step(&mut rust_t, &delta, &mut rust_m, 0.7, 0.9);
    for (a, b) in rust_t.iter().zip(&hlo_t) {
        assert!((a - b).abs() < 1e-5);
    }
    for (a, b) in rust_m.iter().zip(&hlo_m) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn grad_step_matches_finite_difference_direction() {
    let Some(engine) = tiny_engine() else { return };
    let meta = engine.meta();
    let params = engine.init_params().unwrap();
    let mut rng = Rng::new(12, 0);
    let n = meta.batch_elems();
    let tokens: Vec<i32> =
        (0..n).map(|_| rng.below(meta.model.vocab_size as u64) as i32).collect();
    let mut targets = tokens.clone();
    targets.rotate_left(1);
    let (loss, grad) = engine.grad_step(&params, &tokens, &targets).unwrap();
    assert!(loss.is_finite());
    assert_eq!(grad.len(), meta.param_count);
    // Step along -grad must reduce the loss.
    let gnorm2: f32 = grad.iter().map(|g| g * g).sum();
    assert!(gnorm2 > 0.0);
    let eta = 0.1 / gnorm2.sqrt();
    let moved: Vec<f32> =
        params.iter().zip(&grad).map(|(p, g)| p - eta * g).collect();
    let loss2 = engine.eval_loss(&moved, &tokens, &targets).unwrap();
    assert!(loss2 < loss, "descent direction failed: {loss} -> {loss2}");
}

#[test]
fn all_three_methods_train_end_to_end() {
    let Some(backend) = tiny_backend() else { return };
    for method in MethodKind::all() {
        let mut tr = Trainer::new(backend, tiny_cfg(method)).unwrap();
        let out = tr.run().unwrap();
        assert_eq!(out.curve.points.last().unwrap().step, 24);
        assert!(out.curve.points.iter().all(|p| p.loss.is_finite()));
        assert!(out.syncs_completed > 0, "{method:?} never synced");
        match method {
            MethodKind::Diloco => {
                assert!(out.comm_stall_s > 0.0, "diloco must stall");
                assert_eq!(out.syncs_completed, 3 * backend.fragments().k());
            }
            _ => assert_eq!(out.comm_stall_s, 0.0, "{method:?} must overlap"),
        }
    }
}

#[test]
fn runs_are_deterministic_per_seed() {
    let Some(backend) = tiny_backend() else { return };
    let run = || {
        let mut tr = Trainer::new(backend, tiny_cfg(MethodKind::Cocodc)).unwrap();
        tr.run().unwrap()
    };
    let (a, b) = (run(), run());
    for (pa, pb) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(pa.loss, pb.loss);
    }
    let mut cfg2 = tiny_cfg(MethodKind::Cocodc);
    cfg2.seed = 99;
    let mut tr = Trainer::new(backend, cfg2).unwrap();
    let c = tr.run().unwrap();
    assert_ne!(
        a.curve.points.last().unwrap().loss,
        c.curve.points.last().unwrap().loss
    );
}

#[test]
fn hlo_fragment_ops_path_agrees_with_rust_path() {
    let Some(backend) = tiny_backend() else { return };
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let backend_hlo = PjrtBackend::load(&dir, "tiny", true).expect("backend load");
    let mut cfg = tiny_cfg(MethodKind::Cocodc);
    cfg.total_steps = 16;
    let mut cfg_hlo = cfg.clone();
    cfg_hlo.use_hlo_fragment_ops = true;
    let mut tr1 = Trainer::new(backend, cfg).unwrap();
    let out1 = tr1.run().unwrap();
    let mut tr2 = Trainer::new(&backend_hlo, cfg_hlo).unwrap();
    let out2 = tr2.run().unwrap();
    for (a, b) in out1.curve.points.iter().zip(&out2.curve.points) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4,
            "rust vs hlo fragment ops diverged: {} vs {}",
            a.loss,
            b.loss
        );
    }
}

#[test]
fn checkpoint_round_trips_through_trainer() {
    let Some(backend) = tiny_backend() else { return };
    let mut tr = Trainer::new(backend, tiny_cfg(MethodKind::Cocodc)).unwrap();
    let _ = tr.run().unwrap();
    let path = std::env::temp_dir().join("cocodc_integration_ckpt.bin");
    tr.save_checkpoint(&path, 24).unwrap();
    let before: Vec<Vec<f32>> = (0..tr.workers().len())
        .map(|i| tr.worker_params(i).unwrap())
        .collect();
    let ck = cocodc::checkpoint::Checkpoint::load(&path).unwrap();
    let mut tr2 = Trainer::new(backend, tiny_cfg(MethodKind::Cocodc)).unwrap();
    tr2.restore(&ck).unwrap();
    for (i, orig) in before.iter().enumerate() {
        assert_eq!(&tr2.worker_params(i).unwrap(), orig);
    }
    std::fs::remove_file(path).ok();
}
