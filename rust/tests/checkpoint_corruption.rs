//! Exhaustive checkpoint-integrity sweep (DESIGN.md §Recovery):
//!
//! * truncating a saved checkpoint at *every* byte offset must yield a
//!   clean `Err` from `Checkpoint::load` — never a panic, never a huge
//!   allocation from a half-read length field;
//! * flipping one byte at *every* offset (both a single-bit and a
//!   whole-byte flip) must likewise be rejected: the v2 format's FNV-1a
//!   hash covers the header, every section-length field and all payload
//!   bytes, so no single corruption can slip through.

use cocodc::checkpoint::Checkpoint;

fn sample() -> Checkpoint {
    let mut ck = Checkpoint::new(1234);
    ck.insert("global/theta_g", vec![0.5, -1.25, 3.0, 0.0125]);
    ck.insert("w0/step", vec![7.0, 0.0]);
    ck.insert("x", vec![]);
    ck
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("cocodc_ckpt_corruption_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn truncation_at_every_offset_is_rejected() {
    let bytes = sample().to_bytes();
    let path = tmp_path("truncated.bin");
    for cut in 0..bytes.len() {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let res = Checkpoint::load(&path);
        assert!(res.is_err(), "truncation to {cut}/{} bytes loaded", bytes.len());
    }
    // The untruncated file still round-trips (the sweep hit real content).
    std::fs::write(&path, &bytes).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back.step, 1234);
    assert_eq!(back.get("w0/step"), Some(&[7.0f32, 0.0][..]));
}

#[test]
fn byte_flip_at_every_offset_is_rejected() {
    let bytes = sample().to_bytes();
    let path = tmp_path("flipped.bin");
    for off in 0..bytes.len() {
        for mask in [0x01u8, 0xFF] {
            let mut bad = bytes.clone();
            bad[off] ^= mask;
            std::fs::write(&path, &bad).unwrap();
            let res = Checkpoint::load(&path);
            assert!(
                res.is_err(),
                "flip mask {mask:#04x} at offset {off}/{} loaded",
                bytes.len()
            );
        }
    }
}
