//! Backend equivalence and resident-state contracts:
//!
//! * NativeBackend fragment ops (delay-comp Alg. 1, Nesterov outer step,
//!   α-blend) match the scalar references in `vecops::reference` within
//!   1 ulp, driven through the opaque-handle trait API;
//! * a 50-step native training run is bit-identical across
//!   `parallel_workers` on/off and across two runs at the same seed;
//! * end-to-end native runs complete offline (no artifacts) for all three
//!   methods with decreasing loss;
//! * mid-run checkpoint → restore → continue reproduces the uninterrupted
//!   run exactly (validation curve, wall-clock and final state);
//! * the PJRT marshalling layer re-marshals only dirty fragments
//!   (counting-wrapper assertions against the vendored stub's Literal).

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::coordinator::FragmentTable;
use cocodc::runtime::{Backend, LiteralCache, NativeBackend, TrainState};
use cocodc::util::proptest::forall;
use cocodc::util::vecops::reference;
use cocodc::util::Rng;
use cocodc::{TrainOutcome, Trainer};

// ---------------------------------------------------------------------
// 1-ulp comparison (same keying as tests/hotpath.rs)
// ---------------------------------------------------------------------

fn ulp_key(x: f32) -> i64 {
    let b = x.to_bits() as i64;
    if b & 0x8000_0000 != 0 {
        0x8000_0000 - b
    } else {
        b
    }
}

fn ulp_check(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        if g.is_nan() != w.is_nan() {
            return Err(format!("{what}: elem {i}: {g} vs {w} (NaN mismatch)"));
        }
        if g.is_nan() {
            continue;
        }
        let d = (ulp_key(g) - ulp_key(w)).abs();
        if d > 1 {
            return Err(format!("{what}: elem {i}: {g} vs {w} differ by {d} ulp"));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Native fragment ops vs scalar references
// ---------------------------------------------------------------------

#[test]
fn prop_native_fragment_ops_match_reference() {
    let backend = NativeBackend::preset("tiny").unwrap();
    forall(16, |rng| {
        let p = rng.usize_in(0, backend.fragments().k() - 1);
        let frag = backend.fragments().get(p);
        let n = frag.size;
        let mut w = backend.create_worker().map_err(|e| e.to_string())?;

        // Seed the resident fragment with random values via the trait API.
        let local0 = rng.f32_vec(n, 1.0);
        backend.write_fragment(&mut w, frag, &local0).map_err(|e| e.to_string())?;
        let mut read_back = vec![0.0f32; n];
        backend.read_fragment(&w, frag, &mut read_back).map_err(|e| e.to_string())?;
        if read_back != local0 {
            return Err("read_fragment did not round-trip write_fragment".into());
        }

        // Delay compensation (Alg. 1).
        let theta_g = rng.f32_vec(n, 1.0);
        let theta_tp = rng.f32_vec(n, 1.0);
        let (tau, h, lambda) = (
            1.0 + rng.next_f64() as f32 * 9.0,
            10.0 + rng.next_f64() as f32 * 90.0,
            rng.next_f64() as f32,
        );
        backend
            .delay_comp_fragment(&mut w, frag, &theta_g, &theta_tp, tau, h, lambda)
            .map_err(|e| e.to_string())?;
        let mut got = vec![0.0f32; n];
        backend.read_fragment(&w, frag, &mut got).map_err(|e| e.to_string())?;
        let mut want = local0.clone();
        reference::delay_compensate_inplace(&mut want, &theta_g, &theta_tp, tau, h, lambda);
        ulp_check(&got, &want, "delay_comp_fragment")?;

        // α-blend (Eq. 3) on top of the compensated state.
        let alpha = rng.next_f64() as f32;
        backend
            .alpha_blend_fragment(&mut w, frag, &theta_g, alpha)
            .map_err(|e| e.to_string())?;
        backend.read_fragment(&w, frag, &mut got).map_err(|e| e.to_string())?;
        reference::alpha_blend(&mut want, &theta_g, alpha);
        ulp_check(&got, &want, "alpha_blend_fragment")?;

        // Zero-copy pseudo-gradient mean over resident worker state.
        let m = rng.usize_in(1, 4);
        let mut ws = Vec::new();
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for _ in 0..m {
            let mut wk = backend.create_worker().map_err(|e| e.to_string())?;
            let row = rng.f32_vec(n, 1.0);
            backend.write_fragment(&mut wk, frag, &row).map_err(|e| e.to_string())?;
            ws.push(wk);
            rows.push(row);
        }
        let mut pm_got = vec![0.0f32; n];
        backend
            .pseudo_mean_fragment(&ws, frag, &theta_g, &mut pm_got)
            .map_err(|e| e.to_string())?;
        let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut pm_want = vec![0.0f32; n];
        reference::pseudo_mean(&mut pm_want, &row_refs, &theta_g);
        ulp_check(&pm_got, &pm_want, "pseudo_mean_fragment")?;

        // Nesterov outer step (Eq. 2) on the global side.
        let delta = rng.f32_vec(n, 0.1);
        let mut tg_got = theta_g.clone();
        let mut mom_got = rng.f32_vec(n, 0.1);
        let mut tg_want = tg_got.clone();
        let mut mom_want = mom_got.clone();
        backend
            .outer_step_fragment(frag, &mut tg_got, &delta, &mut mom_got, 0.7, 0.9)
            .map_err(|e| e.to_string())?;
        reference::outer_step(&mut tg_want, &delta, &mut mom_want, 0.7, 0.9);
        ulp_check(&tg_got, &tg_want, "outer_step_fragment theta")?;
        ulp_check(&mom_got, &mom_want, "outer_step_fragment momentum")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Native end-to-end runs (no artifacts needed)
// ---------------------------------------------------------------------

fn native_cfg(method: MethodKind, parallel: bool) -> RunConfig {
    let mut cfg = RunConfig::paper("tiny", method);
    cfg.workers = 3;
    cfg.h_steps = 10;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 50;
    cfg.eval_every = 10;
    cfg.eval_batches = 2;
    cfg.parallel_workers = parallel;
    cfg
}

fn run_native(method: MethodKind, parallel: bool, seed: u64) -> (TrainOutcome, Vec<Vec<f32>>) {
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut cfg = native_cfg(method, parallel);
    cfg.seed = seed;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    let out = tr.run().unwrap();
    let params = (0..tr.workers().len())
        .map(|i| tr.worker_params(i).unwrap())
        .collect();
    (out, params)
}

#[test]
fn native_run_bit_identical_across_parallelism_and_reruns() {
    let (out_serial, params_serial) = run_native(MethodKind::Cocodc, false, 17);
    let (out_pool, params_pool) = run_native(MethodKind::Cocodc, true, 17);
    let (out_again, params_again) = run_native(MethodKind::Cocodc, false, 17);
    for (a, b) in out_serial.curve.points.iter().zip(&out_pool.curve.points) {
        assert_eq!(a.loss, b.loss, "parallel_workers changed the math");
        assert_eq!(a.wall_s, b.wall_s);
    }
    assert_eq!(params_serial, params_pool, "parallel run diverged bitwise");
    for (a, b) in out_serial.curve.points.iter().zip(&out_again.curve.points) {
        assert_eq!(a.loss, b.loss, "same-seed rerun diverged");
    }
    assert_eq!(params_serial, params_again);
    // A different seed must actually change the trajectory.
    let (out_other, _) = run_native(MethodKind::Cocodc, false, 18);
    assert_ne!(
        out_serial.curve.points.last().unwrap().loss,
        out_other.curve.points.last().unwrap().loss
    );
}

#[test]
fn all_three_methods_train_natively_offline() {
    for method in MethodKind::all() {
        let backend = NativeBackend::preset("tiny").unwrap();
        let mut tr = Trainer::new(&backend, native_cfg(method, false)).unwrap();
        let out = tr.run().unwrap();
        assert_eq!(out.curve.points.last().unwrap().step, 50);
        assert!(out.curve.points.iter().all(|p| p.loss.is_finite()));
        assert!(out.syncs_completed > 0, "{method:?} never synced");
        let first = out.curve.points.first().unwrap().loss;
        let last = out.curve.points.last().unwrap().loss;
        assert!(last < first, "{method:?}: no learning ({first:.4} -> {last:.4})");
        match method {
            MethodKind::Diloco => assert!(out.comm_stall_s > 0.0, "diloco must stall"),
            _ => assert_eq!(out.comm_stall_s, 0.0, "{method:?} must overlap"),
        }
    }
}

// ---------------------------------------------------------------------
// Checkpoint: mid-run save -> restore -> continue equivalence
// ---------------------------------------------------------------------

#[test]
fn restore_continues_exactly_where_the_run_left_off() {
    // DiLoCo is sync-quiescent at every step (blocking), so a mid-run
    // checkpoint captures the complete strategy-visible state.
    let mk_cfg = |total: u32| {
        let mut cfg = native_cfg(MethodKind::Diloco, false);
        cfg.total_steps = total;
        cfg.eval_every = 5;
        cfg
    };
    let backend = NativeBackend::preset("tiny").unwrap();

    // Uninterrupted 40-step reference run.
    let mut full = Trainer::new(&backend, mk_cfg(40)).unwrap();
    let out_full = full.run().unwrap();

    // First 20 steps, checkpoint, then a *fresh* trainer resumes.
    let mut first = Trainer::new(&backend, mk_cfg(20)).unwrap();
    let _ = first.run().unwrap();
    let ck = first.checkpoint(20).unwrap();
    drop(first);
    let mut resumed = Trainer::new(&backend, mk_cfg(40)).unwrap();
    resumed.restore(&ck).unwrap();
    let out_resumed = resumed.run().unwrap();

    // Every eval point the resumed run produces (steps 20..=40) must match
    // the uninterrupted run bit-for-bit — loss AND wall-clock: without the
    // restored clock/stats/stream cursors the curve would be wrong.
    for rp in &out_resumed.curve.points {
        let fp = out_full
            .curve
            .points
            .iter()
            .find(|p| p.step == rp.step)
            .unwrap_or_else(|| panic!("full run has no eval at step {}", rp.step));
        assert_eq!(rp.loss, fp.loss, "loss diverged at step {}", rp.step);
        assert_eq!(rp.wall_s, fp.wall_s, "wall-clock diverged at step {}", rp.step);
    }
    assert_eq!(out_resumed.wall_s, out_full.wall_s, "final wall-clock differs");
    assert_eq!(
        out_resumed.syncs_completed, out_full.syncs_completed,
        "restored sync stats missing"
    );
    let mut full2 = Trainer::new(&backend, mk_cfg(40)).unwrap();
    let _ = full2.run().unwrap();
    for i in 0..resumed.workers().len() {
        assert_eq!(
            resumed.worker_params(i).unwrap(),
            full2.worker_params(i).unwrap(),
            "worker {i} final params differ after resume"
        );
    }
}

#[test]
fn restore_without_run_context_still_loads_state() {
    // Forward-compat: a checkpoint stripped to the seed-era sections
    // (state only) must still restore.
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut tr = Trainer::new(&backend, native_cfg(MethodKind::Cocodc, false)).unwrap();
    let _ = tr.run().unwrap();
    let mut ck = tr.checkpoint(50).unwrap();
    let legacy: Vec<String> = ck
        .sections
        .keys()
        .filter(|k| k.starts_with("run/"))
        .cloned()
        .collect();
    for k in legacy {
        ck.sections.remove(&k);
    }
    let mut tr2 = Trainer::new(&backend, native_cfg(MethodKind::Cocodc, false)).unwrap();
    tr2.restore(&ck).unwrap();
    assert_eq!(tr.worker_params(0).unwrap(), tr2.worker_params(0).unwrap());
}

// ---------------------------------------------------------------------
// PJRT marshalling: only dirty fragments cross the boundary
// ---------------------------------------------------------------------

#[test]
fn literal_cache_marshals_only_dirty_fragments_per_sync() {
    let frags = FragmentTable::from_sizes(&[32, 48, 16, 64]);
    let mut rng = Rng::new(7, 0);
    let mut state = TrainState::new(rng.f32_vec(160, 1.0));
    let mut cache = LiteralCache::new(frags.k());

    // Step 0: first use is the single full marshal.
    cache.refresh(&state, &frags).unwrap();
    assert_eq!(cache.stats().full_marshals, 1);

    // Simulate 10 sync cycles, each touching one fragment (round-robin, as
    // Streaming DiLoCo would): every refresh must marshal exactly the one
    // dirty fragment, never the full state.
    for i in 0..10usize {
        let p = i % frags.k();
        let frag = frags.get(p);
        for x in &mut state.params[frag.range()] {
            *x += 1.0;
        }
        cache.mark_fragment(p);
        let (lit, _, _) = cache.refresh(&state, &frags).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), state.params, "cycle {i}");
        let s = cache.stats();
        assert_eq!(s.full_marshals, 1, "cycle {i} re-marshalled the full state");
        assert_eq!(s.fragment_marshals, i + 1, "cycle {i} marshalled extra fragments");
    }

    // Train-step analogue: adopting executor outputs marshals nothing.
    let before = cache.stats().fragment_marshals;
    cache.adopt(
        xla::Literal::vec1(&state.params),
        xla::Literal::vec1(&state.m),
        xla::Literal::vec1(&state.v),
    );
    cache.refresh(&state, &frags).unwrap();
    let s = cache.stats();
    assert_eq!(s.adopted, 1);
    assert_eq!(s.fragment_marshals, before);
    assert_eq!(s.full_marshals, 1);
}
