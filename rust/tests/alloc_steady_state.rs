//! Proof of the zero-allocation sync hot path: a counting global allocator
//! wraps `System`, the streaming and CoCoDC strategies run through warm-up,
//! and the test then asserts that further initiate/complete cycles perform
//! **zero** heap allocations.
//!
//! This file intentionally contains a single test (plus the allocator):
//! libtest runs tests in one binary concurrently, and any neighbour test
//! allocating during the measured window would poison the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::coordinator::strategy::SyncCtx;
use cocodc::coordinator::{make_strategy, FragmentTable, GlobalState, SyncStats};
use cocodc::network::WanSimulator;
use cocodc::runtime::TrainState;
use cocodc::simclock::VirtualClock;
use cocodc::util::pool::BufferPool;
use cocodc::util::Rng;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

struct Sim {
    cfg: RunConfig,
    frags: FragmentTable,
    workers: Vec<TrainState>,
    global: GlobalState,
    net: WanSimulator,
    clock: VirtualClock,
    stats: SyncStats,
    pool: BufferPool,
    rng: Rng,
}

impl Sim {
    fn new(method: MethodKind, k: usize, h: u32, tau: u32, workers: usize) -> Sim {
        let frags = FragmentTable::from_sizes(&vec![256; k]);
        let mut cfg = RunConfig::paper("sim", method);
        cfg.workers = workers;
        cfg.h_steps = h;
        cfg.tau = TauMode::Fixed { tau };
        let init = vec![0.0f32; frags.total_params()];
        Sim {
            workers: (0..workers).map(|_| TrainState::new(init.clone())).collect(),
            global: GlobalState::new(&init),
            net: WanSimulator::new(cfg.network, workers, 3),
            clock: VirtualClock::new(),
            stats: SyncStats::new(k),
            pool: BufferPool::new(),
            rng: Rng::new(41, 0),
            cfg,
            frags,
        }
    }

    fn drift(&mut self, step: u32) {
        for w in self.workers.iter_mut() {
            for x in w.params.iter_mut() {
                *x += 0.01 * self.rng.next_gaussian() as f32;
            }
            w.step = step;
        }
        self.clock.advance_compute(self.cfg.network.step_compute_s);
    }

    fn ctx(&mut self) -> SyncCtx<'_> {
        SyncCtx {
            workers: &mut self.workers,
            global: &mut self.global,
            net: &mut self.net,
            clock: &mut self.clock,
            engine: None,
            cfg: &self.cfg,
            frags: &self.frags,
            stats: &mut self.stats,
            pool: &mut self.pool,
            threads: None,
        }
    }
}

#[test]
fn sync_hot_path_is_allocation_free_in_steady_state() {
    for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
        let mut sim = Sim::new(method, 4, 20, 3, 4);
        let mut strategy = make_strategy(&sim.cfg, &sim.frags);
        // Warm-up: enough H windows that every buffer bucket, pending-queue
        // slot and snapshot shell has reached its steady-state capacity.
        for step in 1..=100 {
            sim.drift(step);
            strategy.post_step(step, &mut sim.ctx()).unwrap();
        }
        let completed_before = sim.stats.syncs_completed;

        let before = ALLOCATIONS.load(Ordering::SeqCst);
        for step in 101..=400 {
            sim.drift(step);
            strategy.post_step(step, &mut sim.ctx()).unwrap();
        }
        let after = ALLOCATIONS.load(Ordering::SeqCst);

        let completed = sim.stats.syncs_completed - completed_before;
        assert!(completed > 10, "{method:?}: only {completed} syncs measured");
        assert_eq!(
            after - before,
            0,
            "{method:?}: {} heap allocations across {completed} steady-state syncs",
            after - before
        );
    }
}
