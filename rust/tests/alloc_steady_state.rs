//! Proof of the zero-allocation hot paths: a counting global allocator
//! wraps `System`, and after warm-up the test asserts **zero** heap
//! allocations for
//!
//!  1. steady-state sync initiate/complete cycles (streaming + CoCoDC over
//!     the host backend, as in PR 1), and
//!  2. *full native-backend train steps* — batch generation, the
//!     transformer forward/backward/AdamW on resident state, and the sync
//!     path, all through `Trainer::step_once`, and
//!  3. serial `eval_loss` calls (the eval-scratch pool recycles the
//!     backward-free shard sets), plus a constant-cost check for *pooled*
//!     eval: row-shard fan-out boxes per-call queue traffic, so it cannot
//!     be zero-alloc, but two identical measurement windows must allocate
//!     the same amount — no steady-state growth, and
//!  4. the same two properties at *batch 1*, where the 2D partition runs
//!     column chunks only: serial batch-1 steps (column scratch, the fused
//!     softmax-xent `XentScratch`, `d_res2`) are zero-alloc once warm, and
//!     pooled batch-1 eval — column-chunk fan-out instead of row shards —
//!     stays window-constant.
//!
//! This file intentionally contains a single test (plus the allocator):
//! libtest runs tests in one binary concurrently, and any neighbour test
//! allocating during the measured window would poison the counter. The
//! measurements run sequentially inside it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::coordinator::strategy::SyncCtx;
use cocodc::coordinator::{make_strategy, FragmentTable, GlobalState, SyncStats};
use cocodc::network::WanSimulator;
use cocodc::runtime::{
    Backend, HostBackend, ModelMeta, NativeBackend, NativeSpec, TrainMeta, WorkerHandle,
};
use cocodc::simclock::VirtualClock;
use cocodc::util::pool::BufferPool;
use cocodc::util::{Rng, WorkerPool};
use cocodc::Trainer;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

struct Sim {
    cfg: RunConfig,
    frags: FragmentTable,
    backend: HostBackend,
    workers: Vec<WorkerHandle>,
    global: GlobalState,
    net: WanSimulator,
    clock: VirtualClock,
    stats: SyncStats,
    pool: BufferPool,
    rng: Rng,
}

impl Sim {
    fn new(method: MethodKind, k: usize, h: u32, tau: u32, workers: usize) -> Sim {
        let frags = FragmentTable::from_sizes(&vec![256; k]);
        let mut cfg = RunConfig::paper("sim", method);
        cfg.workers = workers;
        cfg.h_steps = h;
        cfg.tau = TauMode::Fixed { tau };
        let backend = HostBackend::new(frags.clone());
        let init = backend.init_params().unwrap();
        Sim {
            workers: (0..workers).map(|_| backend.create_worker().unwrap()).collect(),
            global: GlobalState::new(&init),
            net: WanSimulator::new(cfg.network, workers, 3),
            clock: VirtualClock::new(),
            stats: SyncStats::new(k),
            pool: BufferPool::new(),
            rng: Rng::new(41, 0),
            backend,
            cfg,
            frags,
        }
    }

    fn drift(&mut self, step: u32) {
        for w in self.workers.iter_mut() {
            let st = self.backend.state_mut(w);
            for x in st.params.iter_mut() {
                *x += 0.01 * self.rng.next_gaussian() as f32;
            }
            st.step = step;
        }
        self.clock.advance_compute(self.cfg.network.step_compute_s);
    }

    fn ctx(&mut self) -> SyncCtx<'_> {
        SyncCtx {
            workers: &mut self.workers,
            global: &mut self.global,
            net: &mut self.net,
            clock: &mut self.clock,
            backend: &self.backend,
            cfg: &self.cfg,
            frags: &self.frags,
            stats: &mut self.stats,
            pool: &mut self.pool,
            threads: None,
            live: None,
        }
    }
}

fn sync_cycles_are_allocation_free() {
    for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
        let mut sim = Sim::new(method, 4, 20, 3, 4);
        let mut strategy = make_strategy(&sim.cfg, &sim.frags);
        // Warm-up: enough H windows that every buffer bucket, pending-queue
        // slot and snapshot shell has reached its steady-state capacity.
        for step in 1..=100 {
            sim.drift(step);
            strategy.post_step(step, &mut sim.ctx()).unwrap();
        }
        let completed_before = sim.stats.syncs_completed;

        let before = ALLOCATIONS.load(Ordering::SeqCst);
        for step in 101..=400 {
            sim.drift(step);
            strategy.post_step(step, &mut sim.ctx()).unwrap();
        }
        let after = ALLOCATIONS.load(Ordering::SeqCst);

        let completed = sim.stats.syncs_completed - completed_before;
        assert!(completed > 10, "{method:?}: only {completed} syncs measured");
        assert_eq!(
            after - before,
            0,
            "{method:?}: {} heap allocations across {completed} steady-state syncs",
            after - before
        );
    }
}

fn native_train_steps_are_allocation_free() {
    // Full train steps through the trainer: synthetic-C4 batch refill,
    // native transformer forward/backward/AdamW on resident worker state,
    // and the CoCoDC sync path. Serial mode: the worker-pool fan-out boxes
    // its borrowed tasks, which is per-round queue traffic, not model
    // state — the resident hot path itself must not allocate.
    let backend = NativeBackend::preset("tiny").unwrap();
    let mut cfg = RunConfig::paper("tiny", MethodKind::Cocodc);
    cfg.workers = 2;
    cfg.h_steps = 8;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 1000; // never reached; we drive step_once by hand
    cfg.parallel_workers = false;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    // Warm-up: several sync windows so pools, pending queues and batch
    // buffers reach steady-state capacity.
    for _ in 0..40 {
        tr.step_once().unwrap();
    }
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..40 {
        tr.step_once().unwrap();
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} heap allocations across 40 steady-state native train steps",
        after - before
    );
}

fn native_eval_batch(backend: &NativeBackend, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let m = backend.model();
    let mut rng = Rng::new(seed, 0);
    let n = m.batch_size * m.seq_len;
    let tokens: Vec<i32> = (0..n).map(|_| rng.below(m.vocab_size as u64) as i32).collect();
    let mut targets = tokens.clone();
    targets.rotate_left(1);
    (tokens, targets)
}

fn eval_allocations_reach_steady_state() {
    let backend = NativeBackend::preset("tiny").unwrap();
    let params = backend.init_params().unwrap();
    let (tokens, targets) = native_eval_batch(&backend, 11);

    // Serial eval: once the first call has built its backward-free shard
    // set, the eval-scratch pool recycles it — zero allocations after.
    for _ in 0..2 {
        backend.eval_loss(&params, &tokens, &targets).unwrap();
    }
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..16 {
        backend.eval_loss(&params, &tokens, &targets).unwrap();
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} heap allocations across 16 steady-state serial evals",
        after - before
    );

    // Pooled eval boxes one task per row shard per call (scope queue
    // traffic, not model state), so zero is unattainable — but the cost
    // must be *constant*: identical windows, identical allocation counts.
    backend.set_compute_pool(Some(Arc::new(WorkerPool::new(2))));
    for _ in 0..6 {
        backend.eval_loss(&params, &tokens, &targets).unwrap();
    }
    let window = || {
        let before = ALLOCATIONS.load(Ordering::SeqCst);
        for _ in 0..16 {
            backend.eval_loss(&params, &tokens, &targets).unwrap();
        }
        ALLOCATIONS.load(Ordering::SeqCst) - before
    };
    let w1 = window();
    let w2 = window();
    assert_eq!(w1, w2, "pooled eval allocations grew between identical windows");
    backend.set_compute_pool(None);
}

/// Batch-1 backend: one row shard, so every parallel path in the step is a
/// column-chunk dispatch and the per-shard scratch (including the fused
/// softmax-xent `XentScratch`) is exercised at its smallest row count.
fn batch1_backend() -> NativeBackend {
    NativeBackend::new(NativeSpec {
        name: "alloc-b1".into(),
        model: ModelMeta {
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            seq_len: 16,
            batch_size: 1,
            use_pallas_attention: false,
        },
        train: TrainMeta {
            lr: 1e-3,
            warmup_steps: 4,
            total_steps: 1_000_000,
            weight_decay: 0.1,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            min_lr_ratio: 0.1,
        },
        n_fragments: 2, // build_layout needs K <= n_layers
        seed: 0,
    })
    .unwrap()
}

fn batch1_train_steps_are_allocation_free() {
    // Serial batch-1 trainer steps: the column-chunked kernels run inline
    // (no pool → `dispatch` loops in place, boxing nothing), so the whole
    // step must stay zero-alloc once scratch is warm.
    let backend = batch1_backend();
    let mut cfg = RunConfig::paper("tiny", MethodKind::Cocodc);
    cfg.workers = 2;
    cfg.h_steps = 8;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = 1000; // never reached; we drive step_once by hand
    cfg.parallel_workers = false;
    let mut tr = Trainer::new(&backend, cfg).unwrap();
    for _ in 0..40 {
        tr.step_once().unwrap();
    }
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..40 {
        tr.step_once().unwrap();
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} heap allocations across 40 steady-state batch-1 train steps",
        after - before
    );
}

fn batch1_eval_allocations_reach_steady_state() {
    // Pooled batch-1 eval: one row shard means the row-level scope inlines
    // and all queue traffic comes from column-chunk dispatches. Boxed
    // per-call, so zero is unattainable — but identical windows must cost
    // identical allocation counts.
    let backend = batch1_backend();
    let params = backend.init_params().unwrap();
    let (tokens, targets) = native_eval_batch(&backend, 13);
    backend.set_compute_pool(Some(Arc::new(WorkerPool::new(4))));
    for _ in 0..6 {
        backend.eval_loss(&params, &tokens, &targets).unwrap();
    }
    let window = || {
        let before = ALLOCATIONS.load(Ordering::SeqCst);
        for _ in 0..16 {
            backend.eval_loss(&params, &tokens, &targets).unwrap();
        }
        ALLOCATIONS.load(Ordering::SeqCst) - before
    };
    let w1 = window();
    let w2 = window();
    assert_eq!(w1, w2, "pooled batch-1 eval allocations grew between identical windows");
    backend.set_compute_pool(None);
}

#[test]
fn hot_paths_are_allocation_free_in_steady_state() {
    sync_cycles_are_allocation_free();
    native_train_steps_are_allocation_free();
    eval_allocations_reach_steady_state();
    batch1_train_steps_are_allocation_free();
    batch1_eval_allocations_reach_steady_state();
}
