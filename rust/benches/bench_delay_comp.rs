//! Bench: delay compensation + outer step — native rust loop vs the
//! Pallas/HLO artifact dispatched through PJRT. Quantifies why the trainer
//! defaults to the rust path for small fragments (per-dispatch overhead)
//! while proving both produce identical updates (see integration tests).

use std::path::Path;
use std::time::Duration;

use cocodc::coordinator::delay_comp::delay_compensate;
use cocodc::coordinator::outer_opt::outer_step;
use cocodc::runtime::Engine;
use cocodc::util::bench::{bench, black_box, HotpathReport};
use cocodc::util::Rng;

fn main() {
    println!("== bench_delay_comp (rust vs Pallas/HLO artifact) ==");
    let budget = Duration::from_millis(400);
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut report = HotpathReport::new();

    for preset in ["tiny", "exp"] {
        if !dir.join(preset).join("meta.json").exists() {
            println!("SKIP {preset}: run `make artifacts`");
            continue;
        }
        let engine = Engine::load(&dir, preset).expect("engine");
        let meta = engine.meta();
        let frag = meta.fragments[0];
        let n = frag.size;
        let mut rng = Rng::new(3, 0);
        let tg = rng.f32_vec(n, 0.5);
        let tl = rng.f32_vec(n, 0.5);
        let tp = rng.f32_vec(n, 0.5);
        let mut out = vec![0.0f32; n];

        let r_rust = bench(
            &format!("[{preset}] delay_comp rust (S={n})"),
            3,
            budget,
            || {
                delay_compensate(&mut out, black_box(&tg), &tl, &tp, 5.0, 100.0, 0.5);
                black_box(&out);
            },
        );
        let r_hlo = bench(
            &format!("[{preset}] delay_comp HLO/PJRT (S={n})"),
            3,
            budget,
            || {
                black_box(
                    engine
                        .delay_comp_hlo(0, &tg, &tl, &tp, 5.0, 100.0, 0.5)
                        .unwrap(),
                );
            },
        );
        println!(
            "    -> rust is {:.1}x faster at this fragment size",
            r_hlo.mean.as_secs_f64() / r_rust.mean.as_secs_f64()
        );
        report.push("delay_comp_rust", n, (4 * n) as f64 * 4.0, &r_rust);
        report.push("delay_comp_hlo_pjrt", n, (4 * n) as f64 * 4.0, &r_hlo);

        let delta = rng.f32_vec(n, 0.01);
        let mut theta = tg.clone();
        let mut mom = vec![0.0f32; n];
        let r_os = bench(&format!("[{preset}] outer_step rust (S={n})"), 3, budget, || {
            outer_step(&mut theta, black_box(&delta), &mut mom, 0.7, 0.9);
            black_box(&theta);
        });
        let r_os_hlo = bench(
            &format!("[{preset}] outer_step HLO/PJRT (S={n})"),
            3,
            budget,
            || {
                black_box(
                    engine.outer_step_hlo(0, &tg, &delta, &mom, 0.7, 0.9).unwrap(),
                );
            },
        );
        report.push("outer_step_rust", n, (5 * n) as f64 * 4.0, &r_os);
        report.push("outer_step_hlo_pjrt", n, (5 * n) as f64 * 4.0, &r_os_hlo);
    }

    let path = HotpathReport::default_path();
    report.write(&path).expect("write BENCH_hotpath.json");
    println!("report -> {}", path.display());
}
