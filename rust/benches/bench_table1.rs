//! Bench: end-to-end steps/second per synchronization method (the system
//! cost behind Table I / Figs. 1-2) plus the coordinator-only overhead of
//! each strategy (post_step with the PJRT step excluded).

use std::path::Path;
use std::time::{Duration, Instant};

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::coordinator::strategy::SyncCtx;
use cocodc::coordinator::{make_strategy, FragmentTable, GlobalState, SyncStats};
use cocodc::network::WanSimulator;
use cocodc::runtime::{Backend, HostBackend, PjrtBackend, WorkerHandle};
use cocodc::simclock::VirtualClock;
use cocodc::util::bench::black_box;
use cocodc::util::pool::BufferPool;
use cocodc::util::Rng;
use cocodc::Trainer;

fn main() {
    println!("== bench_table1: end-to-end method cost ==");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    // (a) full runs on the tiny preset: real steps/sec per method.
    if dir.join("tiny").join("meta.json").exists() {
        let backend = PjrtBackend::load(&dir, "tiny", false).expect("backend");
        for method in MethodKind::all() {
            let mut cfg = RunConfig::paper("tiny", method);
            cfg.workers = 4;
            cfg.h_steps = 10;
            cfg.tau = TauMode::Fixed { tau: 2 };
            cfg.total_steps = 40;
            cfg.eval_every = 40;
            cfg.eval_batches = 1;
            let mut tr = Trainer::new(&backend, cfg).unwrap();
            let t = Instant::now();
            let out = tr.run().unwrap();
            let dt = t.elapsed();
            println!(
                "{:<18} 40 steps x 4 workers in {:>8.2?} = {:>6.1} steps/s  \
                 (virtual wall {:.1}s, {} syncs)",
                out.method,
                dt,
                40.0 / dt.as_secs_f64(),
                out.wall_s,
                out.syncs_completed
            );
        }
    } else {
        println!("SKIP full runs: artifacts/tiny missing (run `make artifacts`)");
    }

    // (b) coordinator-only overhead at exp scale (no PJRT in the loop).
    println!("\ncoordinator-only post_step cost at exp scale (450k params, M=4):");
    for method in MethodKind::all() {
        let frags =
            FragmentTable::from_sizes(&[100_608, 117_056, 116_992, 116_992]);
        let mut cfg = RunConfig::paper("sim", method);
        cfg.h_steps = 100;
        cfg.tau = TauMode::Fixed { tau: 5 };
        let backend = HostBackend::new(frags.clone());
        let init = backend.init_params().unwrap();
        let mut workers: Vec<WorkerHandle> =
            (0..4).map(|_| backend.create_worker().unwrap()).collect();
        let mut global = GlobalState::new(&init);
        let mut net = WanSimulator::new(cfg.network, 4, 1);
        let mut clock = VirtualClock::new();
        let mut stats = SyncStats::new(frags.k());
        let mut pool = BufferPool::new();
        let mut strategy = make_strategy(&cfg, &frags);
        let mut rng = Rng::new(4, 0);
        let steps = 400u32;
        let t = Instant::now();
        for step in 1..=steps {
            for w in workers.iter_mut() {
                // cheap drift so syncs have real data to move
                let r = rng.next_gaussian() as f32 * 0.01;
                for x in backend.state_mut(w).params.iter_mut().step_by(97) {
                    *x += r;
                }
            }
            clock.advance_compute(cfg.network.step_compute_s);
            let mut ctx = SyncCtx {
                workers: &mut workers,
                global: &mut global,
                net: &mut net,
                clock: &mut clock,
                backend: &backend,
                cfg: &cfg,
                frags: &frags,
                stats: &mut stats,
                pool: &mut pool,
                threads: None,
                live: None,
            };
            strategy.post_step(step, &mut ctx).unwrap();
            black_box(&workers);
        }
        let per_step = t.elapsed() / steps;
        println!(
            "{:<18} {:>10.2?}/step  ({} syncs over {steps} steps) -> {:.2}% of a 150 ms train step",
            format!("{}:", strategy.name()),
            per_step,
            stats.syncs_completed,
            100.0 * per_step.as_secs_f64() / 0.150
        );
        let _ = Duration::ZERO;
    }
}
