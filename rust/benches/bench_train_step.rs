//! Bench: train/eval step latency.
//!
//! Native section (always runs, zero artifacts needed): serial vs
//! 2D-sharded `train_step` on 1/2/4/8 pool threads at batch 8 (row-shard
//! dominated) and batch 1 (column-shard only), plus the tiled matmul
//! kernels and the fused softmax–cross-entropy against their seed
//! references — the `train_step_sharded*`, `train_step_b1_*`, `matmul_*`
//! and `softmax_xent_*` perf-trajectory rows of BENCH_hotpath.json.
//!
//! PJRT section (skipped without `make artifacts`): step latency per
//! preset, serial vs 4 parallel workers — the L3-visible cost of the L2+L1
//! artifact (Pallas flash attention + fused AdamW inside the lowered HLO).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use cocodc::runtime::{
    Backend, Engine, ModelMeta, NativeBackend, NativeSpec, TrainMeta, TrainState,
};
use cocodc::util::bench::{bench, black_box, BenchResult, HotpathReport};
use cocodc::util::vecops::{self, reference};
use cocodc::util::{Rng, WorkerPool};

fn batch(model: &ModelMeta, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed, 0);
    let n = model.batch_size * model.seq_len;
    let tokens: Vec<i32> =
        (0..n).map(|_| rng.below(model.vocab_size as u64) as i32).collect();
    let mut targets = tokens.clone();
    targets.rotate_left(1);
    (tokens, targets)
}

/// Exp-family dims with batch 8, so `row_shards` saturates every bench pool
/// size (1/2/4/8) independently of the named presets' batch choices.
fn bench_spec() -> NativeSpec {
    NativeSpec {
        name: "bench8".into(),
        model: ModelMeta {
            vocab_size: 256,
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            d_ff: 128,
            seq_len: 32,
            batch_size: 8,
            use_pallas_attention: false,
        },
        train: TrainMeta {
            lr: 1e-3,
            warmup_steps: 10,
            total_steps: 1_000_000, // never exhausted inside a bench run
            weight_decay: 0.1,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            min_lr_ratio: 0.1,
        },
        n_fragments: 4,
        seed: 0,
    }
}

fn step_row(report: &mut HotpathReport, op: &str, n: usize, r: &BenchResult, serial: &BenchResult) {
    let steps_per_s = 1.0 / r.mean.as_secs_f64();
    let speedup = serial.mean.as_secs_f64() / r.mean.as_secs_f64();
    println!("    -> {steps_per_s:.1} steps/s ({speedup:.2}x vs serial)");
    report.push_custom(
        op,
        n,
        &[
            ("steps_per_s", steps_per_s),
            ("speedup_vs_serial", speedup),
            ("mean_ns", r.mean.as_secs_f64() * 1e9),
        ],
    );
}

fn bench_native(report: &mut HotpathReport, budget: Duration) {
    let be = NativeBackend::new(bench_spec()).expect("native backend");
    let n = be.param_count();
    let (tokens, targets) = batch(be.model(), 1);
    println!("-- native train_step (P={n}, batch 8 -> 8 row shards) --");

    let mut w = be.create_worker().expect("worker");
    let serial = bench("[native] train_step serial", 3, budget, || {
        black_box(be.train_step(&mut w, &tokens, &targets).unwrap());
    });
    step_row(report, "train_step_serial", n, &serial, &serial);

    for threads in [1usize, 2, 4, 8] {
        be.set_compute_pool(Some(Arc::new(WorkerPool::new(threads))));
        let mut w = be.create_worker().expect("worker");
        let r = bench(&format!("[native] train_step sharded x{threads}"), 3, budget, || {
            black_box(be.train_step(&mut w, &tokens, &targets).unwrap());
        });
        step_row(report, &format!("train_step_sharded{threads}"), n, &r, &serial);
    }
    be.set_compute_pool(None);
}

/// Batch-1: row sharding is pinned at one shard, so any scaling here comes
/// purely from the column axis (vocab/d_ff/d_model output-column chunks).
fn bench_native_b1(report: &mut HotpathReport, budget: Duration) {
    let mut spec = bench_spec();
    spec.name = "bench1".into();
    spec.model.batch_size = 1;
    let be = NativeBackend::new(spec).expect("native backend");
    let n = be.param_count();
    let (tokens, targets) = batch(be.model(), 2);
    println!("-- native train_step (P={n}, batch 1 -> column shards only) --");

    let mut w = be.create_worker().expect("worker");
    let serial = bench("[native] train_step b1 serial", 3, budget, || {
        black_box(be.train_step(&mut w, &tokens, &targets).unwrap());
    });
    step_row(report, "train_step_b1_serial", n, &serial, &serial);

    for threads in [1usize, 2, 4, 8] {
        be.set_compute_pool(Some(Arc::new(WorkerPool::new(threads))));
        let mut w = be.create_worker().expect("worker");
        let r = bench(&format!("[native] train_step b1 sharded x{threads}"), 3, budget, || {
            black_box(be.train_step(&mut w, &tokens, &targets).unwrap());
        });
        step_row(report, &format!("train_step_b1_sharded{threads}"), n, &r, &serial);
    }
    be.set_compute_pool(None);
}

/// Fused single-sweep softmax–cross-entropy vs the multi-sweep reference
/// twin at the bench LM-head shape. Both mutate logits in place, so every
/// iteration restores from a pristine copy (same cost in both arms).
fn bench_softmax_xent(report: &mut HotpathReport, budget: Duration) {
    let (rows, v) = (256usize, 256usize);
    let key = rows * v;
    let mut rng = Rng::new(11, 0);
    let pristine: Vec<f32> =
        (0..rows * v).map(|_| (rng.next_f64() * 4.0 - 2.0) as f32).collect();
    let targets: Vec<i32> = (0..rows).map(|_| rng.below(v as u64) as i32).collect();
    let inv_n = 1.0 / rows as f32;
    println!("-- fused softmax-xent vs multi-sweep reference ({rows}x{v}) --");

    let mut logits = pristine.clone();
    let rf = bench("[softmax_xent] fused", 3, budget, || {
        logits.copy_from_slice(&pristine);
        black_box(vecops::softmax_xent(black_box(&mut logits), &targets, v, inv_n, true));
    });
    let rr = bench("[softmax_xent] reference", 3, budget, || {
        logits.copy_from_slice(&pristine);
        black_box(reference::softmax_xent_split(
            black_box(&mut logits),
            &targets,
            v,
            inv_n,
            true,
        ));
    });
    let bytes = (rows * v * 4) as f64;
    report.push("softmax_xent_fused", key, bytes, &rf);
    report.push("softmax_xent_reference", key, bytes, &rr);
    report.push_speedup(
        "softmax_xent_fused_speedup",
        key,
        rr.mean.as_secs_f64() / rf.mean.as_secs_f64(),
    );
}

fn bench_matmuls(report: &mut HotpathReport, budget: Duration) {
    // The LM-head shape of the bench model — the largest matmul in the
    // native step. Rows are keyed by MAC count, so ns_per_elem is ns/MAC.
    let (n, m, p) = (256usize, 64, 256);
    let key = n * m * p;
    let bytes = ((n * m + m * p + n * p) * 4) as f64;
    let mut rng = Rng::new(7, 0);
    let mut fill = |len: usize| -> Vec<f32> {
        (0..len).map(|_| (rng.next_f64() - 0.5) as f32).collect()
    };
    let a = fill(n * m);
    let b = fill(m * p);
    let d = fill(n * p);
    println!("-- tiled matmul kernels vs seed references ({n}x{m}x{p}) --");

    let mut out = vec![0.0f32; n * p];
    let rt = bench("[matmul] tiled", 3, budget, || {
        vecops::matmul(black_box(&mut out), &a, &b, n, m, p);
    });
    let rr = bench("[matmul] reference", 3, budget, || {
        reference::matmul(black_box(&mut out), &a, &b, n, m, p);
    });
    report.push("matmul_tiled", key, bytes, &rt);
    report.push("matmul_reference", key, bytes, &rr);
    report.push_speedup("matmul_tiled_speedup", key, rr.mean.as_secs_f64() / rt.mean.as_secs_f64());

    let mut dx = vec![0.0f32; n * m];
    let rt = bench("[matmul_bt] tiled", 3, budget, || {
        vecops::matmul_bt(black_box(&mut dx), &d, &b, n, m, p);
    });
    let rr = bench("[matmul_bt] reference", 3, budget, || {
        reference::matmul_bt(black_box(&mut dx), &d, &b, n, m, p);
    });
    report.push("matmul_bt_tiled", key, bytes, &rt);
    report.push("matmul_bt_reference", key, bytes, &rr);
    report.push_speedup(
        "matmul_bt_tiled_speedup",
        key,
        rr.mean.as_secs_f64() / rt.mean.as_secs_f64(),
    );

    let mut gb = vec![0.0f32; m * p];
    let rt = bench("[matmul_at_acc] tiled", 3, budget, || {
        vecops::matmul_at_acc(black_box(&mut gb), &a, &d, n, m, p);
    });
    gb.fill(0.0);
    let rr = bench("[matmul_at_acc] reference", 3, budget, || {
        reference::matmul_at_acc(black_box(&mut gb), &a, &d, n, m, p);
    });
    report.push("matmul_at_acc_tiled", key, bytes, &rt);
    report.push("matmul_at_acc_reference", key, bytes, &rr);
    report.push_speedup(
        "matmul_at_acc_tiled_speedup",
        key,
        rr.mean.as_secs_f64() / rt.mean.as_secs_f64(),
    );
}

fn bench_pjrt(budget: Duration) {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    for preset in ["tiny", "exp"] {
        if !dir.join(preset).join("meta.json").exists() {
            println!("SKIP pjrt {preset}: run `make artifacts`");
            continue;
        }
        let engine = Engine::load(&dir, preset).expect("engine");
        let meta = engine.meta();
        let tokens_per_step = meta.batch_elems() as f64;
        let (tokens, targets) = batch(&meta.model, 1);

        let mut st = TrainState::new(engine.init_params().unwrap());
        let r = bench(&format!("[{preset}] train_step x1"), 2, budget, || {
            black_box(engine.train_step(&mut st, &tokens, &targets).unwrap());
        });
        println!(
            "    -> {:.0} tokens/s single worker (P={})",
            r.throughput(tokens_per_step),
            meta.param_count
        );

        // 4 workers in parallel threads (the trainer's lockstep round).
        let mut states: Vec<TrainState> =
            (0..4).map(|_| TrainState::new(engine.init_params().unwrap())).collect();
        let eng = &engine;
        let (tok_ref, tgt_ref) = (&tokens, &targets);
        let r4 = bench(&format!("[{preset}] train_step x4 parallel"), 2, budget, || {
            std::thread::scope(|s| {
                let hs: Vec<_> = states
                    .iter_mut()
                    .map(|st| {
                        s.spawn(move || {
                            black_box(eng.train_step(st, tok_ref, tgt_ref).unwrap())
                        })
                    })
                    .collect();
                for h in hs {
                    h.join().unwrap();
                }
            });
        });
        println!(
            "    -> {:.0} tokens/s across 4 workers ({:.2}x scaling)",
            r4.throughput(4.0 * tokens_per_step),
            r.mean.as_secs_f64() * 4.0 / r4.mean.as_secs_f64() / 4.0 * 4.0
        );

        let params = engine.init_params().unwrap();
        bench(&format!("[{preset}] eval_loss x1"), 2, budget, || {
            black_box(engine.eval_loss(&params, &tokens, &targets).unwrap());
        });
    }
}

fn main() {
    println!("== bench_train_step ==");
    let budget = Duration::from_secs(1);
    let mut report = HotpathReport::new();
    bench_native(&mut report, budget);
    bench_native_b1(&mut report, budget);
    bench_matmuls(&mut report, budget);
    bench_softmax_xent(&mut report, budget);
    bench_pjrt(Duration::from_secs(2));
    let path = HotpathReport::default_path();
    report.write(&path).expect("write BENCH_hotpath.json");
    println!("rows merged into {}", path.display());
}
