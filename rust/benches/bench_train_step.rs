//! Bench: PJRT train/eval step latency per preset, serial vs 4 parallel
//! workers — the L3-visible cost of the L2+L1 artifact (Pallas flash
//! attention + fused AdamW inside the lowered HLO).

use std::path::Path;
use std::time::Duration;

use cocodc::runtime::{Engine, TrainState};
use cocodc::util::bench::{bench, black_box};
use cocodc::util::Rng;

fn batch(engine: &Engine, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let meta = engine.meta();
    let mut rng = Rng::new(seed, 0);
    let n = meta.batch_elems();
    let tokens: Vec<i32> =
        (0..n).map(|_| rng.below(meta.model.vocab_size as u64) as i32).collect();
    let mut targets = tokens.clone();
    targets.rotate_left(1);
    (tokens, targets)
}

fn main() {
    println!("== bench_train_step ==");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let budget = Duration::from_secs(2);

    for preset in ["tiny", "exp"] {
        if !dir.join(preset).join("meta.json").exists() {
            println!("SKIP {preset}: run `make artifacts`");
            continue;
        }
        let engine = Engine::load(&dir, preset).expect("engine");
        let meta = engine.meta();
        let tokens_per_step = meta.batch_elems() as f64;
        let (tokens, targets) = batch(&engine, 1);

        let mut st = TrainState::new(engine.init_params().unwrap());
        let r = bench(&format!("[{preset}] train_step x1"), 2, budget, || {
            black_box(engine.train_step(&mut st, &tokens, &targets).unwrap());
        });
        println!(
            "    -> {:.0} tokens/s single worker (P={})",
            r.throughput(tokens_per_step),
            meta.param_count
        );

        // 4 workers in parallel threads (the trainer's lockstep round).
        let mut states: Vec<TrainState> =
            (0..4).map(|_| TrainState::new(engine.init_params().unwrap())).collect();
        let eng = &engine;
        let (tok_ref, tgt_ref) = (&tokens, &targets);
        let r4 = bench(&format!("[{preset}] train_step x4 parallel"), 2, budget, || {
            std::thread::scope(|s| {
                let hs: Vec<_> = states
                    .iter_mut()
                    .map(|st| {
                        s.spawn(move || {
                            black_box(eng.train_step(st, tok_ref, tgt_ref).unwrap())
                        })
                    })
                    .collect();
                for h in hs {
                    h.join().unwrap();
                }
            });
        });
        println!(
            "    -> {:.0} tokens/s across 4 workers ({:.2}x scaling)",
            r4.throughput(4.0 * tokens_per_step),
            r.mean.as_secs_f64() * 4.0 / r4.mean.as_secs_f64() / 4.0 * 4.0
        );

        let params = engine.init_params().unwrap();
        bench(&format!("[{preset}] eval_loss x1"), 2, budget, || {
            black_box(engine.eval_loss(&params, &tokens, &targets).unwrap());
        });
    }
}
