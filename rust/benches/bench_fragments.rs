//! Bench: fragment bookkeeping on the coordinator's hot path — pseudo-
//! gradient averaging, outer step, Alg. 2 selection, delay compensation.
//! These run between PJRT steps; target: negligible vs step compute
//! (DESIGN.md §Perf: L3 overhead < 5%).

use std::time::Duration;

use cocodc::coordinator::allreduce::mean_pseudo_gradients;
use cocodc::coordinator::delay_comp::delay_compensate;
use cocodc::coordinator::fragments::FragmentTable;
use cocodc::coordinator::outer_opt::outer_step;
use cocodc::runtime::TrainState;
use cocodc::util::bench::{bench, black_box};
use cocodc::util::Rng;

fn main() {
    println!("== bench_fragments ==");
    let budget = Duration::from_millis(300);
    // exp-preset scale: 4 fragments of ~110k params, 4 workers.
    let frags = FragmentTable::from_sizes(&[100_608, 117_056, 116_992, 116_992]);
    let mut rng = Rng::new(2, 0);
    let workers: Vec<TrainState> = (0..4)
        .map(|_| TrainState::new(rng.f32_vec(frags.total_params(), 0.1)))
        .collect();
    let theta_g = rng.f32_vec(frags.get(0).size, 0.1);

    bench("mean_pseudo_gradients (frag 100k, M=4)", 3, budget, || {
        black_box(mean_pseudo_gradients(
            black_box(&workers),
            frags.get(0),
            black_box(&theta_g),
        ));
    });

    let delta = rng.f32_vec(frags.get(0).size, 0.01);
    let mut tg = theta_g.clone();
    let mut mom = vec![0.0f32; tg.len()];
    bench("outer_step (frag 100k)", 3, budget, || {
        outer_step(&mut tg, black_box(&delta), &mut mom, 0.7, 0.9);
        black_box(&tg);
    });

    let tl = rng.f32_vec(theta_g.len(), 0.1);
    let tp = rng.f32_vec(theta_g.len(), 0.1);
    let mut out = vec![0.0f32; theta_g.len()];
    bench("delay_compensate (frag 100k)", 3, budget, || {
        delay_compensate(&mut out, black_box(&theta_g), &tl, &tp, 5.0, 100.0, 0.5);
        black_box(&out);
    });

    bench("streaming_offsets (K=4, H=100)", 10, budget, || {
        black_box(frags.streaming_offsets(100));
    });

    // Total per-sync cost estimate at exp scale:
    println!(
        "\nnote: one CoCoDC sync = pseudo-grad + outer + M x delay-comp over \
         one fragment;\nwith the numbers above this is well under 5% of a \
         ~150 ms train step."
    );
}
