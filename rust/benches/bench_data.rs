//! Bench: synthetic-C4 generation throughput (the data substrate must never
//! bottleneck the lockstep round; target >> tokens consumed per step).

use std::time::Duration;

use cocodc::config::DataConfig;
use cocodc::data::batches::BatchStream;
use cocodc::data::Split;
use cocodc::util::bench::{bench, black_box};

fn main() {
    println!("== bench_data ==");
    let budget = Duration::from_millis(500);
    for &(vocab, batch, seq) in &[(256usize, 8usize, 64usize), (512, 8, 128), (32000, 16, 1024)] {
        let mut s = BatchStream::new(
            vocab,
            DataConfig::default(),
            1,
            Split::Train { worker: 0, workers: 4 },
            batch,
            seq,
        );
        let r = bench(
            &format!("next_batch vocab={vocab} B={batch} T={seq}"),
            3,
            budget,
            || {
                black_box(s.next_batch());
            },
        );
        println!(
            "    -> {:.2} Mtokens/s",
            r.throughput((batch * seq) as f64) / 1e6
        );
    }
}
