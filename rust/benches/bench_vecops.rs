//! Bench: fused/unrolled vecops kernels vs the seed scalar loops, single-
//! threaded and fanned out over the persistent worker pool.
//!
//! Emits machine-readable rows into `BENCH_hotpath.json` (schema
//! `cocodc-bench-hotpath-v1`, see DESIGN.md §Hot path): per-op
//! (n, ns/elem, GB/s) plus `*_speedup` rows of best-fused vs seed-scalar
//! mean time — the numbers the perf acceptance gate tracks across PRs.

use std::time::Duration;

use cocodc::util::bench::{bench, black_box, BenchResult, HotpathReport};
use cocodc::util::vecops::{self, reference};
use cocodc::util::{Rng, ScopedTask, WorkerPool};

/// Workers M, paper §IV-A.
const M: usize = 4;

/// Split `n` into per-thread ranges of this pool.
fn chunk_len(pool: &WorkerPool, n: usize) -> usize {
    n.div_ceil(pool.threads().max(1)).max(1)
}

/// Multi-threaded fused pseudo-gradient mean: contiguous chunks, one task
/// per chunk. Elementwise, so bit-identical to the single-threaded kernel.
fn par_pseudo_mean(pool: &WorkerPool, out: &mut [f32], rows: &[&[f32]], theta_g: &[f32]) {
    let chunk = chunk_len(pool, out.len());
    let tasks: Vec<ScopedTask<'_>> = out
        .chunks_mut(chunk)
        .enumerate()
        .map(|(ci, oc)| {
            let lo = ci * chunk;
            let hi = lo + oc.len();
            Box::new(move || {
                let views: Vec<&[f32]> = rows.iter().map(|r| &r[lo..hi]).collect();
                vecops::fused_pseudo_mean(oc, &views, &theta_g[lo..hi]);
            }) as ScopedTask<'_>
        })
        .collect();
    pool.scoped(tasks);
}

/// Multi-threaded fused delay compensation (out-of-place).
fn par_delay_comp(
    pool: &WorkerPool,
    out: &mut [f32],
    theta_g: &[f32],
    theta_tl: &[f32],
    theta_tp: &[f32],
    tau: f32,
    h: f32,
    lambda: f32,
) {
    let chunk = chunk_len(pool, out.len());
    let tasks: Vec<ScopedTask<'_>> = out
        .chunks_mut(chunk)
        .enumerate()
        .map(|(ci, oc)| {
            let lo = ci * chunk;
            let hi = lo + oc.len();
            Box::new(move || {
                vecops::fused_delay_comp_into(
                    oc,
                    &theta_g[lo..hi],
                    &theta_tl[lo..hi],
                    &theta_tp[lo..hi],
                    tau,
                    h,
                    lambda,
                );
            }) as ScopedTask<'_>
        })
        .collect();
    pool.scoped(tasks);
}

fn speedup(baseline: &BenchResult, fused: &BenchResult) -> f64 {
    baseline.mean.as_secs_f64() / fused.mean.as_secs_f64()
}

fn main() {
    println!("== bench_vecops (fused/unrolled vs seed scalar loops) ==");
    let budget = Duration::from_millis(250);
    let mut report = HotpathReport::new();
    let pool = WorkerPool::with_default_size(8);
    println!("worker pool: {} threads\n", pool.threads());

    for &n in &[1usize << 10, 1 << 16, 1 << 20] {
        let mut rng = Rng::new(7, 0);
        let rows: Vec<Vec<f32>> = (0..M).map(|_| rng.f32_vec(n, 0.5)).collect();
        let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let theta_g = rng.f32_vec(n, 0.5);
        let theta_tl = rng.f32_vec(n, 0.5);
        let theta_tp = rng.f32_vec(n, 0.5);
        let mut out = vec![0.0f32; n];

        // ---- pseudo-gradient mean (M rows + theta_g read, out write) ----
        let bytes_pm = ((M + 2) * n) as f64 * 4.0;
        let r_seed = bench(&format!("pseudo_mean seed-scalar  n={n}"), 3, budget, || {
            reference::mean_pseudo_gradients_seed(
                &mut out,
                black_box(&row_refs),
                black_box(&theta_g),
            );
            black_box(&out);
        });
        let r_fused = bench(&format!("pseudo_mean fused        n={n}"), 3, budget, || {
            vecops::fused_pseudo_mean(&mut out, black_box(&row_refs), black_box(&theta_g));
            black_box(&out);
        });
        let r_mt = bench(&format!("pseudo_mean fused-mt     n={n}"), 3, budget, || {
            par_pseudo_mean(&pool, &mut out, black_box(&row_refs), black_box(&theta_g));
            black_box(&out);
        });
        report.push("pseudo_mean_scalar", n, bytes_pm, &r_seed);
        report.push("pseudo_mean_fused", n, bytes_pm, &r_fused);
        report.push("pseudo_mean_fused_mt", n, bytes_pm, &r_mt);
        let best = if r_mt.mean < r_fused.mean { &r_mt } else { &r_fused };
        report.push_speedup("pseudo_mean_speedup", n, speedup(&r_seed, best));
        println!("    -> pseudo_mean speedup vs seed: {:.2}x\n", speedup(&r_seed, best));

        // ---- delay compensation (3 reads + 1 write) ----
        let bytes_dc = (4 * n) as f64 * 4.0;
        let r_seed = bench(&format!("delay_comp seed-scalar   n={n}"), 3, budget, || {
            reference::delay_compensate(
                &mut out,
                black_box(&theta_g),
                &theta_tl,
                &theta_tp,
                5.0,
                100.0,
                0.5,
            );
            black_box(&out);
        });
        let r_fused = bench(&format!("delay_comp fused         n={n}"), 3, budget, || {
            vecops::fused_delay_comp_into(
                &mut out,
                black_box(&theta_g),
                &theta_tl,
                &theta_tp,
                5.0,
                100.0,
                0.5,
            );
            black_box(&out);
        });
        let r_mt = bench(&format!("delay_comp fused-mt      n={n}"), 3, budget, || {
            par_delay_comp(&pool, &mut out, &theta_g, &theta_tl, &theta_tp, 5.0, 100.0, 0.5);
            black_box(&out);
        });
        report.push("delay_comp_scalar", n, bytes_dc, &r_seed);
        report.push("delay_comp_fused", n, bytes_dc, &r_fused);
        report.push("delay_comp_fused_mt", n, bytes_dc, &r_mt);
        let best = if r_mt.mean < r_fused.mean { &r_mt } else { &r_fused };
        report.push_speedup("delay_comp_speedup", n, speedup(&r_seed, best));
        println!("    -> delay_comp speedup vs seed: {:.2}x\n", speedup(&r_seed, best));

        // ---- outer step (theta+mom read/write, delta read) ----
        let bytes_os = (5 * n) as f64 * 4.0;
        let delta = rng.f32_vec(n, 0.01);
        let mut tg1 = theta_g.clone();
        let mut mom1 = vec![0.0f32; n];
        let r_seed = bench(&format!("outer_step seed-scalar   n={n}"), 3, budget, || {
            reference::outer_step(&mut tg1, black_box(&delta), &mut mom1, 0.7, 0.9);
            black_box(&tg1);
        });
        let mut tg2 = theta_g.clone();
        let mut mom2 = vec![0.0f32; n];
        let r_fused = bench(&format!("outer_step fused         n={n}"), 3, budget, || {
            vecops::fused_outer_step(&mut tg2, black_box(&delta), &mut mom2, 0.7, 0.9);
            black_box(&tg2);
        });
        report.push("outer_step_scalar", n, bytes_os, &r_seed);
        report.push("outer_step_fused", n, bytes_os, &r_fused);
        report.push_speedup("outer_step_speedup", n, speedup(&r_seed, &r_fused));

        // ---- alpha blend (x read/write, g read) ----
        let bytes_ab = (3 * n) as f64 * 4.0;
        let mut x = theta_tl.clone();
        let r_seed = bench(&format!("alpha_blend seed-scalar  n={n}"), 3, budget, || {
            reference::alpha_blend(&mut x, black_box(&theta_g), 0.5);
            black_box(&x);
        });
        let r_fused = bench(&format!("alpha_blend fused        n={n}"), 3, budget, || {
            vecops::fused_alpha_blend(&mut x, black_box(&theta_g), 0.5);
            black_box(&x);
        });
        report.push("alpha_blend_scalar", n, bytes_ab, &r_seed);
        report.push("alpha_blend_fused", n, bytes_ab, &r_fused);
        report.push_speedup("alpha_blend_speedup", n, speedup(&r_seed, &r_fused));
        println!();
    }

    let path = HotpathReport::default_path();
    report.write(&path).expect("write BENCH_hotpath.json");
    println!("report -> {}", path.display());
}
