//! Bench: ring all-reduce data path + cost model (the WAN substrate under
//! every synchronization in Figs. 1-2 / Table I).

use std::time::Duration;

use cocodc::network::ring::{ring_allreduce_mean, ring_allreduce_time};
use cocodc::util::bench::{bench, black_box};
use cocodc::util::Rng;

fn main() {
    println!("== bench_allreduce ==");
    let budget = Duration::from_millis(400);
    for &(m, n) in &[(4usize, 100_608usize), (4, 1_000_000), (8, 100_608), (2, 100_608)] {
        let mut rng = Rng::new(1, 0);
        let bufs: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..n).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let r = bench(
            &format!("ring_allreduce_mean m={m} n={n}"),
            2,
            budget,
            || {
                let mut b = bufs.clone();
                ring_allreduce_mean(&mut b);
                black_box(&b);
            },
        );
        // Effective reduced bandwidth (element-visits per second).
        println!(
            "    -> {:.2} Gelem/s effective",
            r.throughput((m * n) as f64) / 1e9
        );
    }
    // Cost model sanity table (matches DESIGN.md §WAN).
    println!("\nanalytic ring time (M=4, 1 Gbps, 50 ms): bytes -> seconds");
    for bytes in [4e5, 4e6, 4e7] {
        println!(
            "  {:>10.0}B  {:.4}s",
            bytes,
            ring_allreduce_time(bytes, 4, 0.05, 125e6)
        );
    }
    let t = bench("ring_allreduce_time (cost model eval)", 10, budget, || {
        black_box(ring_allreduce_time(black_box(4e6), 4, 0.05, 125e6));
    });
    assert!(t.mean < Duration::from_micros(1));
}
