//! End-to-end training-loop benchmark on the native backend: whole-run
//! `steps_per_s` plus the sync path's share of wall time
//! (`sync_overhead_pct`), serial and worker-pool modes — the training-loop
//! perf trajectory rows of BENCH_hotpath.json.
//!
//! The sync overhead is measured against a sync-free baseline (DiLoCo with
//! its first sync scheduled past the end of the run), so it captures
//! exactly what the coordinator adds on top of pure local compute.
//!
//! ```text
//! cargo bench --bench bench_train_loop            # default 200 steps
//! cargo bench --bench bench_train_loop -- --steps 60 --preset tiny  # smoke
//! cargo bench --bench bench_train_loop -- --threads 8   # pin the pool size
//! ```

use cocodc::config::{MethodKind, RunConfig, TauMode};
use cocodc::runtime::NativeBackend;
use cocodc::util::bench::HotpathReport;
use cocodc::util::cli::Args;
use cocodc::Trainer;

fn cfg(
    preset: &str,
    method: MethodKind,
    steps: u32,
    h: u32,
    parallel: bool,
    threads: usize,
) -> RunConfig {
    let mut cfg = RunConfig::paper(preset, method);
    cfg.workers = 4;
    cfg.h_steps = h;
    cfg.tau = TauMode::Fixed { tau: 2 };
    cfg.total_steps = steps;
    cfg.eval_every = steps; // time the loop, not the evaluation cadence
    cfg.eval_batches = 2;
    cfg.parallel_workers = parallel;
    cfg.threads = threads; // 0 = auto budget (workers x row shards, host-capped)
    cfg
}

fn timed_run(backend: &NativeBackend, cfg: RunConfig) -> (f64, f64) {
    let mut tr = Trainer::new(backend, cfg).unwrap();
    let out = tr.run().unwrap();
    (out.real_s, out.curve.final_loss().unwrap_or(f64::NAN))
}

fn main() {
    // Cargo appends `--bench` to every bench target's argv (harness=false
    // included); accept and ignore it.
    let args = Args::from_env(&["bench"]).expect("args");
    let _ = args.switch("bench");
    let preset = args.get("preset").unwrap_or("tiny").to_string();
    let steps: u32 = args.get_or("steps", 200).expect("--steps");
    let threads: usize = args.get_or("threads", 0).expect("--threads");
    args.finish().expect("flags");

    println!("== bench_train_loop: native backend, preset '{preset}', {steps} steps ==");
    let backend = NativeBackend::preset(&preset).expect("native preset");
    let n = {
        use cocodc::runtime::Backend;
        backend.param_count()
    };
    let mut report = HotpathReport::new();

    for (mode, parallel) in [("serial", false), ("pool", true)] {
        let t = if parallel { threads } else { 1 };
        // Warm-up run so first-touch costs don't pollute the measurement.
        let _ =
            timed_run(&backend, cfg(&preset, MethodKind::Cocodc, steps.min(20), 10, parallel, t));

        let (t_sync_free, _) =
            timed_run(&backend, cfg(&preset, MethodKind::Diloco, steps, steps + 1, parallel, t));
        let (t_cocodc, loss) =
            timed_run(&backend, cfg(&preset, MethodKind::Cocodc, steps, 10, parallel, t));

        let steps_per_s = steps as f64 / t_cocodc;
        let sync_overhead_pct = ((t_cocodc - t_sync_free) / t_cocodc * 100.0).max(0.0);
        println!(
            "train_loop[{mode:>6}]  {steps_per_s:>8.1} steps/s  \
             sync_overhead {sync_overhead_pct:>5.1}%  (cocodc {t_cocodc:.3}s vs \
             sync-free {t_sync_free:.3}s, final loss {loss:.3})"
        );
        report.push_custom(
            &format!("train_loop_{mode}"),
            n,
            &[
                ("steps_per_s", steps_per_s),
                ("sync_overhead_pct", sync_overhead_pct),
                ("steps", steps as f64),
            ],
        );
    }

    let path = HotpathReport::default_path();
    report.write(&path).expect("write BENCH_hotpath.json");
    println!("rows merged into {}", path.display());
}
