//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! This build environment is fully offline (no crates.io registry), so the
//! workspace vendors the small subset of `anyhow` the cocodc crate actually
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros
//! and the blanket `From<E: std::error::Error + Send + Sync>` conversion
//! that makes `?` work on io/parse/ffi errors. Swap the path dependency in
//! `rust/Cargo.toml` for the real crate when a registry is available — no
//! source changes required.

use std::error::Error as StdError;
use std::fmt;

/// Drop-in subset of `anyhow::Error`: a message plus an optional source
/// chain, cheap to construct from any std error.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message (what the `anyhow!` macro uses).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Walk the source chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            self.source.as_ref().map(|e| e.as_ref() as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// std::error::Error — that is what keeps the blanket conversion below
// coherent with the identity `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...", args)` — format a new [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// `bail!("...", args)` — early-return an error from a `Result` function.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...", args)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/cocodc")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
        assert!(err.chain().count() >= 1);
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(0).unwrap_err().to_string(), "zero is not allowed");
        let e = anyhow!("plain {}", 42);
        assert_eq!(e.to_string(), "plain 42");
    }

    #[test]
    fn debug_includes_cause_chain() {
        let err = io_fail().unwrap_err();
        assert!(format!("{err:?}").contains("Caused by"));
    }
}
