//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links libxla/PJRT via FFI; this container images neither,
//! so the stub keeps the cocodc runtime layer compiling against the same
//! API. [`Literal`] is implemented for real (host-side typed buffers —
//! useful on its own and required so argument marshalling type-checks);
//! [`PjRtClient::cpu`] returns an error, which makes every execution path
//! unreachable. The trainer's PJRT-backed tests and benches already skip
//! when `artifacts/<preset>/meta.json` is absent, so a build against this
//! stub runs the full pure-simulation tier.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs: displayable and a std error, so `?`
/// converts it into `anyhow::Error` at call sites.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn unavailable() -> Error {
        Error::new(
            "PJRT runtime unavailable: this build uses the vendored xla stub \
             (rust/vendor/xla); link the real xla-rs crate to execute HLO artifacts",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element kind of a [`Literal`] buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemKind {
    F32,
    I32,
}

/// Types storable in a [`Literal`].
pub trait NativeType: Copy + 'static {
    const KIND: ElemKind;
    fn write_le(data: &[Self], out: &mut Vec<u8>);
    /// Serialize straight into an existing byte slice (`out.len()` must be
    /// `4 * data.len()`) — the allocation-free sub-buffer update path.
    fn write_le_into(data: &[Self], out: &mut [u8]);
    fn read_le(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const KIND: ElemKind = ElemKind::F32;
    fn write_le(data: &[Self], out: &mut Vec<u8>) {
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn write_le_into(data: &[Self], out: &mut [u8]) {
        for (v, chunk) in data.iter().zip(out.chunks_exact_mut(4)) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
    }
    fn read_le(bytes: &[u8]) -> Vec<Self> {
        bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
    }
}

impl NativeType for i32 {
    const KIND: ElemKind = ElemKind::I32;
    fn write_le(data: &[Self], out: &mut Vec<u8>) {
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn write_le_into(data: &[Self], out: &mut [u8]) {
        for (v, chunk) in data.iter().zip(out.chunks_exact_mut(4)) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
    }
    fn read_le(bytes: &[u8]) -> Vec<Self> {
        bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
    }
}

/// A host-side typed tensor (or tuple of tensors), mirroring xla::Literal.
#[derive(Debug, Clone)]
pub struct Literal {
    kind: ElemKind,
    bytes: Vec<u8>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * 4);
        T::write_le(data, &mut bytes);
        Literal { kind: T::KIND, bytes, dims: vec![data.len() as i64], tuple: None }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut bytes = Vec::with_capacity(4);
        T::write_le(&[v], &mut bytes);
        Literal { kind: T::KIND, bytes, dims: vec![], tuple: None }
    }

    /// Tuple literal (what executables with `return_tuple=True` produce).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { kind: ElemKind::F32, bytes: Vec::new(), dims: vec![], tuple: Some(elems) }
    }

    pub fn element_count(&self) -> usize {
        self.bytes.len() / 4
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape: {} elements cannot view as {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal {
            kind: self.kind,
            bytes: self.bytes.clone(),
            dims: dims.to_vec(),
            tuple: None,
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    fn check_kind<T: NativeType>(&self) -> Result<()> {
        if self.tuple.is_some() {
            return Err(Error::new("literal is a tuple, not a dense buffer"));
        }
        if self.kind != T::KIND {
            return Err(Error::new(format!(
                "element kind mismatch: literal is {:?}",
                self.kind
            )));
        }
        Ok(())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        self.check_kind::<T>()?;
        Ok(T::read_le(&self.bytes))
    }

    /// Copy the raw buffer into `dst` (lengths must match) — the
    /// zero-extra-allocation read-out path.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        self.check_kind::<T>()?;
        if dst.len() != self.element_count() {
            return Err(Error::new(format!(
                "copy_raw_to: literal has {} elements, destination {}",
                self.element_count(),
                dst.len()
            )));
        }
        let data = T::read_le(&self.bytes);
        dst.copy_from_slice(&data);
        Ok(())
    }

    /// Overwrite elements `[offset, offset + data.len())` in place — the
    /// sub-buffer update the dirty-fragment marshalling path uses to refresh
    /// a cached argument literal without rebuilding it (serialized straight
    /// into the backing buffer, no temporary). The real PJRT equivalent is
    /// host-buffer semantics / buffer donation; see ROADMAP.
    pub fn write_raw_at<T: NativeType>(&mut self, offset: usize, data: &[T]) -> Result<()> {
        self.check_kind::<T>()?;
        if offset + data.len() > self.element_count() {
            return Err(Error::new(format!(
                "write_raw_at: range {}..{} exceeds {} elements",
                offset,
                offset + data.len(),
                self.element_count()
            )));
        }
        T::write_le_into(data, &mut self.bytes[offset * 4..(offset + data.len()) * 4]);
        Ok(())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.check_kind::<T>()?;
        T::read_le(&self.bytes)
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal has no first element"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple.ok_or_else(|| Error::new("literal is not a tuple"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        let mut t = self.to_tuple()?;
        if t.len() != 1 {
            return Err(Error::new(format!("expected 1-tuple, got {}", t.len())));
        }
        Ok(t.pop().expect("len checked"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        let mut t = self.to_tuple()?;
        if t.len() != 2 {
            return Err(Error::new(format!("expected 2-tuple, got {}", t.len())));
        }
        let b = t.pop().expect("len checked");
        let a = t.pop().expect("len checked");
        Ok((a, b))
    }
}

/// Parsed HLO module text. The stub cannot parse HLO; construction fails.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::new(format!(
            "cannot parse HLO text {} with the vendored xla stub",
            path.as_ref().display()
        )))
    }
}

/// Computation wrapper (constructible; compilation requires a client).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. The stub has no runtime: `cpu()` always errors.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle (unreachable without a client).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Arguments are borrowed so callers can pass long-lived cached
    /// literals (the dirty-fragment marshalling path) without cloning.
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// Device buffer handle (unreachable without a client).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32_and_i32() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.0]);
        assert_eq!(l.element_count(), 3);
        let t = Literal::vec1(&[1i32, 2, 3, 4]).reshape(&[2, 2]).unwrap();
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(Literal::vec1(&[1.0f32]).reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn copy_raw_to_and_first_element() {
        let l = Literal::vec1(&[5.0f32, 6.0]);
        let mut dst = [0.0f32; 2];
        l.copy_raw_to(&mut dst).unwrap();
        assert_eq!(dst, [5.0, 6.0]);
        let s: f32 = Literal::scalar(9.5f32).get_first_element().unwrap();
        assert_eq!(s, 9.5);
    }

    #[test]
    fn write_raw_at_patches_sub_range() {
        let mut l = Literal::vec1(&[0.0f32; 6]);
        l.write_raw_at(2, &[7.0f32, 8.0]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![0.0, 0.0, 7.0, 8.0, 0.0, 0.0]);
        assert!(l.write_raw_at(5, &[1.0f32, 2.0]).is_err());
        assert!(l.write_raw_at::<i32>(0, &[1]).is_err());
    }

    #[test]
    fn tuples_unpack() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2.0f32])]);
        let (a, b) = t.to_tuple2().unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), vec![1.0]);
        assert_eq!(b.to_vec::<f32>().unwrap(), vec![2.0]);
    }

    #[test]
    fn client_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
