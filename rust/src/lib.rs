//! # CoCoDC — cross-region model training with communication-computation
//! overlapping and delay compensation
//!
//! Rust reproduction of *"Cross-region Model Training with
//! Communication-Computation Overlapping and Delay Compensation"*
//! (Zhu et al., CS.DC 2025) on a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: M simulated
//!   datacenter workers, a WAN simulator with a ring all-reduce cost model,
//!   fragment-wise synchronization strategies (DiLoCo, Streaming DiLoCo,
//!   CoCoDC), Taylor-based delay compensation (Alg. 1) and adaptive fragment
//!   transmission (Alg. 2), plus the Nesterov outer optimizer.
//! * **L2/L1 (build time)** — a LLaMA-style transformer train step written in
//!   JAX calling Pallas kernels, AOT-lowered to HLO text under
//!   `artifacts/<preset>/` by `make artifacts`. Python never runs at
//!   training time: this crate loads the artifacts through the PJRT C API
//!   (`xla` crate) and drives them from the hot loop.
//!
//! Entry points: [`trainer::Trainer`] (library), `cocodc` (CLI binary) and
//! `experiments` (paper table/figure regeneration).

// The fragment-op signatures intentionally mirror the paper's notation
// (θ_g, θ_tl, θ_tp, τ, H, λ, ...); folding them into parameter structs
// would obscure the Alg. 1/Eq. 2 correspondence the code is documented by.
#![allow(clippy::too_many_arguments)]

pub mod checkpoint;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod network;
pub mod runtime;
pub mod simclock;
pub mod trainer;
pub mod util;

pub use config::{MethodKind, RunConfig};
pub use trainer::{TrainOutcome, Trainer};
