//! Metrics: validation curves, steps-to-threshold (Table I's convergence
//! criterion), CSV/JSONL emission, and run summaries.

use std::io::Write;
use std::path::Path;

/// A streaming scalar distribution (count/sum/min/max), `Copy` so hot-path
/// recording never allocates. Used for the τ and queue-delay distributions
/// in `SyncStats`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dist {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for Dist {
    fn default() -> Self {
        Dist { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
}

impl Dist {
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Min with empty-distribution reporting as 0 (for CSV emission).
    pub fn min_or_zero(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max_or_zero(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// One validation measurement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalPoint {
    /// Local training step at which the evaluation ran.
    pub step: u32,
    /// Virtual wall-clock seconds (WAN-accounted).
    pub wall_s: f64,
    pub loss: f64,
    pub ppl: f64,
}

/// A full validation curve for one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Curve {
    pub method: String,
    pub points: Vec<EvalPoint>,
}

impl Curve {
    pub fn new(method: &str) -> Self {
        Curve { method: method.into(), points: Vec::new() }
    }

    pub fn push(&mut self, step: u32, wall_s: f64, loss: f64) {
        self.points.push(EvalPoint { step, wall_s, loss, ppl: loss.exp() });
    }

    /// First step at which PPL <= thr, linearly interpolated between the
    /// two bracketing eval points (paper Table I: "Steps (PPL <= 20)").
    pub fn steps_to_ppl(&self, thr: f64) -> Option<f64> {
        let pts = &self.points;
        for i in 0..pts.len() {
            if pts[i].ppl <= thr {
                if i == 0 {
                    return Some(pts[0].step as f64);
                }
                let (a, b) = (&pts[i - 1], &pts[i]);
                let f = (a.ppl - thr) / (a.ppl - b.ppl);
                return Some(a.step as f64 + f * (b.step - a.step) as f64);
            }
        }
        None
    }

    /// Same criterion against the virtual wall clock.
    pub fn wall_to_ppl(&self, thr: f64) -> Option<f64> {
        let pts = &self.points;
        for i in 0..pts.len() {
            if pts[i].ppl <= thr {
                if i == 0 {
                    return Some(pts[0].wall_s);
                }
                let (a, b) = (&pts[i - 1], &pts[i]);
                let f = (a.ppl - thr) / (a.ppl - b.ppl);
                return Some(a.wall_s + f * (b.wall_s - a.wall_s));
            }
        }
        None
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.points.last().map(|p| p.loss)
    }

    pub fn final_ppl(&self) -> Option<f64> {
        self.points.last().map(|p| p.ppl)
    }

    /// Minimum PPL seen over the run (robust to end-of-run noise).
    pub fn best_ppl(&self) -> Option<f64> {
        self.points.iter().map(|p| p.ppl).min_by(|a, b| a.total_cmp(b))
    }
}

/// Largest absolute validation-loss gap between two curves over the steps
/// they share (exact step matches only). None when the curves share no
/// step. Used by the recovery experiments to bound how far a faulted run
/// strays from its fault-free twin.
pub fn max_loss_gap(a: &Curve, b: &Curve) -> Option<f64> {
    let mut gap: Option<f64> = None;
    for pa in &a.points {
        for pb in &b.points {
            if pa.step == pb.step {
                let d = (pa.loss - pb.loss).abs();
                gap = Some(gap.map_or(d, |g: f64| g.max(d)));
            }
        }
    }
    gap
}

/// Write multiple curves as a long-format CSV:
/// `method,step,wall_s,loss,ppl` (one row per eval point).
pub fn write_curves_csv<P: AsRef<Path>>(path: P, curves: &[Curve]) -> anyhow::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "method,step,wall_s,loss,ppl")?;
    for c in curves {
        for p in &c.points {
            writeln!(f, "{},{},{:.6},{:.6},{:.6}", c.method, p.step, p.wall_s,
                     p.loss, p.ppl)?;
        }
    }
    Ok(())
}

/// Load curves back from the long-format CSV (used by the report generator).
pub fn read_curves_csv<P: AsRef<Path>>(path: P) -> anyhow::Result<Vec<Curve>> {
    let text = std::fs::read_to_string(path)?;
    let mut curves: Vec<Curve> = Vec::new();
    for line in text.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        if cols.len() != 5 {
            continue;
        }
        let method = cols[0];
        if curves.last().map(|c| c.method.as_str()) != Some(method) {
            if let Some(c) = curves.iter_mut().find(|c| c.method == method) {
                c.points.push(EvalPoint {
                    step: cols[1].parse()?,
                    wall_s: cols[2].parse()?,
                    loss: cols[3].parse()?,
                    ppl: cols[4].parse()?,
                });
                continue;
            }
            curves.push(Curve::new(method));
        }
        curves.last_mut().unwrap().points.push(EvalPoint {
            step: cols[1].parse()?,
            wall_s: cols[2].parse()?,
            loss: cols[3].parse()?,
            ppl: cols[4].parse()?,
        });
    }
    Ok(curves)
}

/// Render a Table-I-style comparison from curves.
pub fn table1(curves: &[Curve], ppl_thr: f64) -> String {
    let mut out = String::new();
    let steps_hdr = format!("Steps(PPL<={ppl_thr})");
    out.push_str(&format!(
        "{:<18} {:>8} {:>9} {:>16} {:>14}\n",
        "Method", "Loss", "PPL", steps_hdr, "Wall-clock(s)"
    ));
    for c in curves {
        let steps = c
            .steps_to_ppl(ppl_thr)
            .map(|s| format!("{s:.0}"))
            .unwrap_or_else(|| "-".into());
        let wall = c
            .wall_to_ppl(ppl_thr)
            .map(|s| format!("{s:.1}"))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:<18} {:>8.4} {:>9.4} {:>16} {:>14}\n",
            c.method,
            c.final_loss().unwrap_or(f64::NAN),
            c.final_ppl().unwrap_or(f64::NAN),
            steps,
            wall,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(vals: &[(u32, f64)]) -> Curve {
        let mut c = Curve::new("test");
        for &(s, loss) in vals {
            c.push(s, s as f64 * 0.1, loss);
        }
        c
    }

    #[test]
    fn dist_tracks_count_sum_min_max() {
        let mut d = Dist::default();
        assert_eq!(d.mean(), 0.0);
        assert_eq!(d.min_or_zero(), 0.0);
        assert_eq!(d.max_or_zero(), 0.0);
        for x in [3.0, 1.0, 2.0] {
            d.record(x);
        }
        assert_eq!(d.count, 3);
        assert!((d.mean() - 2.0).abs() < 1e-12);
        assert_eq!(d.min, 1.0);
        assert_eq!(d.max, 3.0);
    }

    #[test]
    fn steps_to_ppl_interpolates() {
        // loss ln(30)≈3.401 at step 0, ln(10)≈2.303 at step 100.
        let c = curve(&[(0, 30f64.ln()), (100, 10f64.ln())]);
        let s = c.steps_to_ppl(20.0).unwrap();
        assert!(s > 0.0 && s < 100.0);
        // PPL=20 is crossed halfway in PPL-space: (30-20)/(30-10)=0.5.
        assert!((s - 50.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn steps_to_ppl_none_if_never_reached() {
        let c = curve(&[(0, 30f64.ln()), (100, 25f64.ln())]);
        assert!(c.steps_to_ppl(20.0).is_none());
    }

    #[test]
    fn immediate_crossing_returns_first_step() {
        let c = curve(&[(0, 5f64.ln())]);
        assert_eq!(c.steps_to_ppl(20.0), Some(0.0));
    }

    #[test]
    fn max_loss_gap_over_shared_steps() {
        let a = curve(&[(0, 3.0), (10, 2.5), (20, 2.0)]);
        let b = curve(&[(0, 3.2), (20, 1.6), (30, 1.5)]);
        // Shared steps 0 and 20; gaps 0.2 and 0.4.
        let g = max_loss_gap(&a, &b).unwrap();
        assert!((g - 0.4).abs() < 1e-12, "g={g}");
        let empty = Curve::new("x");
        assert!(max_loss_gap(&a, &empty).is_none());
    }

    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("cocodc_metrics_test");
        let path = dir.join("curves.csv");
        let mut a = curve(&[(0, 3.0), (10, 2.5)]);
        a.method = "diloco".into();
        let mut b = curve(&[(0, 3.1), (10, 2.4)]);
        b.method = "cocodc".into();
        write_curves_csv(&path, &[a.clone(), b.clone()]).unwrap();
        let back = read_curves_csv(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].method, "diloco");
        assert_eq!(back[1].points.len(), 2);
        assert!((back[1].points[1].loss - 2.4).abs() < 1e-6);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn table_renders_all_methods() {
        let mut a = curve(&[(0, 3.0), (10, 2.5)]);
        a.method = "diloco".into();
        let t = table1(&[a], 20.0);
        assert!(t.contains("diloco"));
        assert!(t.contains("Steps(PPL<=20)"));
    }
}
