//! Nesterov-momentum outer optimizer over pseudo-gradients (paper Eq. 2,
//! DiLoCo's OuterOptim with the standard lr=0.7, momentum=0.9).
//!
//! `delta` is the *averaged pseudo-gradient* Δθ^g = mean_m(θ^m − θ^g); the
//! outer gradient is its negation, and the update matches
//! `torch.optim.SGD(nesterov=True)`. The Pallas/HLO twin is
//! `Engine::outer_step_hlo`; `tests/integration.rs` and `bench_delay_comp`
//! check the two agree.

use crate::util::vecops;

/// In-place Nesterov outer step on one fragment.
///
/// theta_g <- theta_g - lr * (grad + mu * mom'),  mom' = mu * mom + grad,
/// with grad = -delta.
///
/// Thin wrapper over the 8-lane unrolled [`vecops::fused_outer_step`]
/// kernel (bit-identical to the historical scalar loop, which lives on as
/// `vecops::reference::outer_step`).
pub fn outer_step(
    theta_g: &mut [f32],
    delta: &[f32],
    momentum_buf: &mut [f32],
    lr: f32,
    momentum: f32,
) {
    debug_assert_eq!(theta_g.len(), delta.len());
    debug_assert_eq!(theta_g.len(), momentum_buf.len());
    vecops::fused_outer_step(theta_g, delta, momentum_buf, lr, momentum);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_momentum_lr_one_adopts_average() {
        let mut theta = vec![1.0f32, 2.0];
        let delta = vec![0.5f32, -1.0]; // mean(theta^m) - theta^g
        let mut mom = vec![0.0f32; 2];
        outer_step(&mut theta, &delta, &mut mom, 1.0, 0.0);
        assert_eq!(theta, vec![1.5, 1.0]); // theta + delta
        assert_eq!(mom, vec![-0.5, 1.0]);
    }

    #[test]
    fn momentum_accumulates_across_rounds() {
        let mut theta = vec![0.0f32];
        let mut mom = vec![0.0f32];
        // Repeated identical deltas: with Nesterov the effective step grows
        // toward delta * lr * (1+mu)/(1-mu) asymptotically per round.
        let mut last_move = 0.0f32;
        let mut prev = 0.0f32;
        for _ in 0..20 {
            outer_step(&mut theta, &[1.0], &mut mom, 0.7, 0.9);
            let mv = theta[0] - prev;
            prev = theta[0];
            assert!(mv > last_move * 0.99, "movement should not shrink");
            last_move = mv;
        }
        assert!(theta[0] > 0.7 * 20.0); // momentum amplifies past plain SGD
    }

    #[test]
    fn matches_torch_sgd_nesterov_reference() {
        // Hand-computed: grad g, v' = mu*v + g, step = lr*(g + mu*v').
        // Round 1: g=-1, v'=-1, step=0.7*(-1+0.9*-1)=-1.33 -> theta=+1.33
        let mut theta = vec![0.0f32];
        let mut mom = vec![0.0f32];
        outer_step(&mut theta, &[1.0], &mut mom, 0.7, 0.9);
        assert!((theta[0] - 1.33).abs() < 1e-6, "{}", theta[0]);
        // Round 2: v'=0.9*-1-1=-1.9, step=0.7*(-1+0.9*-1.9)=-1.897
        outer_step(&mut theta, &[1.0], &mut mom, 0.7, 0.9);
        assert!((theta[0] - (1.33 + 1.897)).abs() < 1e-5, "{}", theta[0]);
    }
}
