//! CoCoDC — the paper's contribution (§III): Streaming DiLoCo's overlapped
//! fragment synchronization, plus
//!
//! 1. **Delay compensation** (Alg. 1): on completion, instead of α-blending
//!    the stale global state, each worker's fragment is set to the
//!    Taylor-extrapolated target `θ_g + g_corr·τ` (see
//!    [`super::delay_comp`]).
//! 2. **Adaptive transmission** (Alg. 2): instead of the rigid round-robin
//!    schedule, syncs are initiated every `h = ⌊H/N⌋` steps with
//!    `N = max(K, ⌊γ·H·T_c/T_s⌋)` (Eq. 9), and the fragment chosen is the
//!    one violating the staleness guard (not synced for ≥ H steps) or,
//!    failing that, the one with the largest global change rate
//!    `R_p = ‖Δθ_p^g‖₂ / I_p` (Eq. 11). Selection is a pure function of
//!    globally replicated history, so all workers agree without extra
//!    coordination messages.

use crate::config::RunConfig;
use crate::coordinator::fragments::FragmentTable;
use crate::util::threadpool::ScopedTask;
use crate::util::vecops;

use super::streaming::{Pending, StreamingDiloco};
use super::strategy::{SyncCtx, SyncStrategy};

/// Fan the per-worker delay-compensation out to the worker pool only when
/// the fragment is big enough that the memory pass dominates the handoff.
const PAR_FRAGMENT_MIN: usize = 1 << 13;

pub struct Cocodc {
    pending: Vec<Pending>,
    /// R_p (Eq. 11); ∞ until the first sync completes so untouched
    /// fragments win the argmax.
    change_rate: Vec<f64>,
    /// t_{p,b}: step at which fragment p's last sync *completed*.
    last_completed: Vec<u32>,
    /// Step at which fragment p's last sync was *initiated* (staleness
    /// guard + in-flight exclusion).
    last_initiated: Vec<u32>,
    /// Initiation interval h = ⌊H/N⌋ (recomputed from live T_c/T_s
    /// estimates at each initiation opportunity).
    next_init: u32,
}

impl Cocodc {
    pub fn new(_cfg: &RunConfig, frags: &FragmentTable) -> Self {
        let k = frags.k();
        Cocodc {
            pending: Vec::new(),
            change_rate: vec![f64::INFINITY; k],
            last_completed: vec![0; k],
            last_initiated: vec![0; k],
            next_init: 1,
        }
    }

    /// Eq. 9/10: target syncs per H window and the resulting interval.
    pub fn schedule_params(cfg: &RunConfig, frags: &FragmentTable, t_sync: f64) -> (u32, u32) {
        let k = frags.k() as u32;
        let h_steps = cfg.h_steps as f64;
        let t_c = cfg.network.step_compute_s;
        let n = ((cfg.gamma * h_steps * t_c / t_sync).floor() as u32).max(k);
        let h = (cfg.h_steps / n).max(1);
        (n, h)
    }

    /// Alg. 2: deterministic fragment selection at step `t`.
    /// Returns None when every candidate is already in flight.
    fn select_fragment(&self, t: u32, h_steps: u32) -> Option<usize> {
        let k = self.change_rate.len();
        let in_flight =
            |p: usize| self.pending.iter().any(|q| q.frag == p);
        // Staleness guard: any fragment not synchronized for >= H steps.
        for p in 0..k {
            if t.saturating_sub(self.last_initiated[p]) >= h_steps && !in_flight(p) {
                return Some(p);
            }
        }
        // Otherwise the largest change rate R_p.
        (0..k)
            .filter(|&p| !in_flight(p))
            .max_by(|&a, &b| {
                self.change_rate[a]
                    .total_cmp(&self.change_rate[b])
                    // Deterministic tie-break on index (all workers agree).
                    .then(b.cmp(&a))
            })
    }

    /// Drain due syncs in place (stable order, no queue rebuild) and apply
    /// Alg. 1 per worker — fanned out on the persistent worker pool when a
    /// pool is attached and the fragment is large enough to pay for it
    /// (elementwise per-worker work, so serial and parallel results are
    /// bit-identical).
    fn complete_due(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].apply_step > step {
                i += 1;
                continue;
            }
            let pend = self.pending.remove(i);
            if pend.finish_time > ctx.clock.now() {
                ctx.clock.stall_until(pend.finish_time);
                ctx.stats.apply_stalls += 1;
            }
            let p = pend.frag;
            let frag = ctx.frags.get(p);
            ctx.outer_step(p, &pend.delta_avg)?;
            ctx.stats.syncs_completed += 1;
            ctx.stats.per_fragment[p] += 1;

            // Eq. 11: update the change-rate metric from the *globally
            // averaged* pseudo-gradient over the completed interval.
            let i_p = step.saturating_sub(self.last_completed[p]).max(1) as f64;
            self.change_rate[p] = vecops::l2_norm(&pend.delta_avg) / i_p;
            self.last_completed[p] = step;

            // Alg. 1 per worker: delay-compensated adoption applied on the
            // backend's resident fragment, straight from the (disjointly
            // borrowed) global fragment slice.
            let tau = (step - pend.t_init).max(1) as f32;
            let h = ctx.cfg.h_steps as f32;
            let lambda = ctx.cfg.lambda;
            let snaps = pend
                .snapshots
                .as_ref()
                .expect("CoCoDC pendings always carry snapshots");
            let backend = ctx.backend;
            {
                let new_g: &[f32] = &ctx.global.theta_g[frag.range()];
                let workers = &mut *ctx.workers;
                match ctx.threads {
                    Some(tp) if workers.len() > 1 && frag.size >= PAR_FRAGMENT_MIN => {
                        let mut results: Vec<Option<anyhow::Result<()>>> =
                            workers.iter().map(|_| None).collect();
                        let tasks: Vec<ScopedTask<'_>> = workers
                            .iter_mut()
                            .zip(snaps.iter())
                            .zip(results.iter_mut())
                            .map(|((w, snap), slot)| {
                                Box::new(move || {
                                    *slot = Some(backend.delay_comp_fragment(
                                        w, frag, new_g, snap, tau, h, lambda,
                                    ));
                                }) as ScopedTask<'_>
                            })
                            .collect();
                        tp.scoped(tasks);
                        for r in results {
                            r.expect("pool ran every task")?;
                        }
                    }
                    _ => {
                        for (w, snap) in workers.iter_mut().zip(snaps.iter()) {
                            backend
                                .delay_comp_fragment(w, frag, new_g, snap, tau, h, lambda)?;
                        }
                    }
                }
            }
            pend.recycle(ctx.pool);
        }
        Ok(())
    }
}

impl SyncStrategy for Cocodc {
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        self.complete_due(step, ctx)?;
        if step == 0 || step < self.next_init {
            return Ok(());
        }
        // Recompute Eq. 9/10 from the current T_s estimate (mean fragment).
        let t_sync = ctx.net.t_sync(ctx.frags.mean_bytes());
        let (_n, h) = Self::schedule_params(ctx.cfg, ctx.frags, t_sync);
        if let Some(p) = self.select_fragment(step, ctx.cfg.h_steps) {
            let guard = step.saturating_sub(self.last_initiated[p]) >= ctx.cfg.h_steps;
            if guard && self.change_rate[p].is_finite() {
                ctx.stats.staleness_guard_hits += 1;
            }
            let pend = StreamingDiloco::initiate(p, step, true, ctx)?;
            self.last_initiated[p] = step;
            self.pending.push(pend);
        }
        self.next_init = step + h;
        Ok(())
    }

    fn pending(&self) -> usize {
        self.pending.len()
    }

    fn name(&self) -> &'static str {
        "cocodc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn frags() -> FragmentTable {
        FragmentTable::from_sizes(&[100, 100, 100, 100])
    }

    #[test]
    fn schedule_params_respects_gamma_and_floor() {
        let mut cfg = RunConfig::default(); // H=100, gamma=0.4, T_c=0.15
        // Paper §IV-A: parameters chosen so N=8 syncs per H -> h=12.
        // gamma*H*T_c/T_s = 0.4*100*0.15/T_s; with T_s=0.75 -> N=8.
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), 0.75);
        assert_eq!(n, 8);
        assert_eq!(h, 12);
        // Very slow network: floor at K.
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), 1e9);
        assert_eq!(n, 4);
        assert_eq!(h, 25);
        // gamma=1, fast network: many syncs, h floors at 1.
        cfg.gamma = 1.0;
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), 1e-6);
        assert!(n >= 100);
        assert_eq!(h, 1);
    }

    #[test]
    fn selection_prefers_stale_then_max_rate() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        // All rates finite; fragment 2 hottest.
        c.change_rate = vec![1.0, 2.0, 5.0, 0.5];
        c.last_initiated = vec![90, 90, 90, 90];
        assert_eq!(c.select_fragment(100, 100), Some(2));
        // Fragment 3 violates the staleness guard -> wins regardless of R.
        c.last_initiated[3] = 0;
        assert_eq!(c.select_fragment(100, 100), Some(3));
    }

    #[test]
    fn selection_skips_in_flight() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        c.change_rate = vec![5.0, 1.0, 0.5, 0.2];
        c.last_initiated = vec![95; 4];
        c.pending.push(Pending {
            frag: 0,
            t_init: 99,
            apply_step: 104,
            finish_time: 0.0,
            delta_avg: vec![],
            snapshots: None,
        });
        assert_eq!(c.select_fragment(100, 100), Some(1));
    }

    #[test]
    fn infinite_rate_gives_initial_priority() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        // Nothing synced yet: all ∞; deterministic tie-break -> fragment 0.
        c.last_initiated = vec![1; 4];
        assert_eq!(c.select_fragment(2, 100), Some(0));
        c.change_rate[0] = 3.0; // fragment 0 done once, others still ∞
        c.change_rate[1] = 2.0;
        assert!(matches!(c.select_fragment(2, 100), Some(2)));
    }
}
