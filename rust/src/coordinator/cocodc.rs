//! CoCoDC — the paper's contribution (§III): Streaming DiLoCo's overlapped
//! fragment synchronization, plus
//!
//! 1. **Delay compensation** (Alg. 1): on completion, instead of α-blending
//!    the stale global state, each worker's fragment is set to the
//!    Taylor-extrapolated target `θ_g + g_corr·τ` (see
//!    [`super::delay_comp`]).
//! 2. **Adaptive transmission** (Alg. 2): instead of the rigid round-robin
//!    schedule, syncs are initiated every `h = ⌊H/N⌋` steps with
//!    `N = max(K, ⌊γ·H·T_c/T_s⌋)` (Eq. 9), and the fragment chosen is the
//!    one violating the staleness guard (not synced for ≥ H steps) or,
//!    failing that, the one with the largest global change rate
//!    `R_p = ‖Δθ_p^g‖₂ / I_p` (Eq. 11). Selection is a pure function of
//!    globally replicated history, so all workers agree without extra
//!    coordination messages.
//!
//! Under faults, the T_s term in Eq. 9 is a *live* estimate: an EWMA over
//! observed transfer resolutions (byte-normalized to the mean fragment)
//! replaces the static ring-time model, so the adaptive schedule backs off
//! automatically when the link degrades — transfers observed through an
//! outage stretch the estimate, N collapses toward its K floor — and
//! catches up once post-outage observations shrink it again.
//!
//! CoCoDC also never blocks a worker on an overdue fragment: with a fixed
//! overlap depth the apply is *deferred* to the transfer's actual arrival
//! (τ_eff = max(τ, arrival steps)) instead of stalling at t+τ the way
//! Streaming's α-blend must — Alg. 1 compensates for the realized
//! staleness `step − t_init`, so a later apply is corrected, not stale.
//! On a healthy link arrival ≤ τ and the schedule is unchanged; under an
//! outage this converts Streaming's stall seconds into compensated lag.
//!
//! With a multi-region topology attached (DESIGN.md §Topology), adaptive
//! transmission extends per link: CoCoDC keeps an EWMA seconds-per-byte
//! estimate for every WAN link (folded from the simulator's per-link
//! observations) and, before each initiation or retransmission, builds the
//! inter-region cycle greedily — each hop extends to the unvisited region
//! whose link has the lowest queue-wait + latency + estimated transfer
//! cost, skipping links severed by a regional outage. When no full cycle
//! of direct live links exists it falls back to the canonical region ring.

use crate::checkpoint::{checksum_f32, pack_f64s, pack_u64s, unpack_f64s, unpack_u64s, Checkpoint};
use crate::config::{RunConfig, TauMode};
use crate::coordinator::fragments::FragmentTable;
use crate::util::pool::BufferPool;
use crate::util::saturating_f64_to_u32;
use crate::util::threadpool::ScopedTask;
use crate::util::vecops;

use super::streaming::{load_pendings, save_pendings, Pending, StreamingDiloco};
use super::strategy::{SyncCtx, SyncStrategy};

/// Fan the per-worker delay-compensation out to the worker pool only when
/// the fragment is big enough that the memory pass dominates the handoff.
const PAR_FRAGMENT_MIN: usize = 1 << 13;

/// EWMA smoothing for the live T_s estimate: heavy enough on fresh
/// observations to react to an outage within a couple of syncs, damped
/// enough that a single jittered transfer doesn't whipsaw the schedule.
const TS_BETA: f64 = 0.3;

/// Why [`Cocodc::select_fragment`] picked its fragment — returned alongside
/// the index so guard-hit accounting reflects the *actual* selection path
/// instead of re-deriving (and possibly disagreeing with) the condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectReason {
    /// Alg. 2 line 2: the fragment exceeded H steps without a sync.
    StalenessGuard,
    /// Largest change rate R_p (Eq. 11).
    MaxRate,
}

pub struct Cocodc {
    pending: Vec<Pending>,
    /// R_p (Eq. 11); ∞ until the first sync completes so untouched
    /// fragments win the argmax.
    change_rate: Vec<f64>,
    /// t_{p,b}: step at which fragment p's last sync *completed*.
    last_completed: Vec<u32>,
    /// Step at which fragment p's last sync was *initiated* (staleness
    /// guard + in-flight exclusion).
    last_initiated: Vec<u32>,
    /// Initiation interval h = ⌊H/N⌋ (recomputed from live T_c/T_s
    /// estimates at each initiation opportunity).
    next_init: u32,
    /// Live T_s estimate: EWMA over observed transfer resolutions,
    /// normalized to the mean fragment's wire bytes. None until the first
    /// observation (falls back to the static ring-time model).
    ts_ewma: Option<f64>,
    /// Per-WAN-link EWMA of observed seconds-per-byte beyond the nominal
    /// latency (topology mode; empty on flat runs). Seeded from the nominal
    /// bandwidth at first use, then folded from per-link observations.
    link_est: Vec<f64>,
    /// Observations folded into each link's estimate (0 = still nominal).
    link_obs_count: Vec<u64>,
    /// Scratch: the adaptive route (cycle of link ids) under construction.
    route_buf: Vec<usize>,
    /// Scratch: participating regions for the current route.
    parts_buf: Vec<usize>,
}

impl Cocodc {
    pub fn new(_cfg: &RunConfig, frags: &FragmentTable) -> Self {
        let k = frags.k();
        Cocodc {
            pending: Vec::new(),
            change_rate: vec![f64::INFINITY; k],
            last_completed: vec![0; k],
            last_initiated: vec![0; k],
            next_init: 1,
            ts_ewma: None,
            link_est: Vec::new(),
            link_obs_count: Vec::new(),
            route_buf: Vec::new(),
            parts_buf: Vec::new(),
        }
    }

    /// Eq. 9/10: target syncs per H window and the resulting interval.
    /// The division saturates explicitly: a degraded T_s near zero (or a
    /// NaN from degenerate inputs) must clamp, not wrap.
    pub fn schedule_params(cfg: &RunConfig, frags: &FragmentTable, t_sync: f64) -> (u32, u32) {
        let k = frags.k() as u32;
        let h_steps = cfg.h_steps as f64;
        let t_c = cfg.network.step_compute_s;
        let n = saturating_f64_to_u32((cfg.gamma * h_steps * t_c / t_sync).floor()).max(k);
        let h = (cfg.h_steps / n).max(1);
        (n, h)
    }

    /// Fold one observed transfer resolution (seconds, already normalized
    /// to mean-fragment bytes) into the live T_s estimate.
    fn observe_ts(&mut self, obs: f64) {
        if !obs.is_finite() || obs <= 0.0 {
            return;
        }
        self.ts_ewma = Some(match self.ts_ewma {
            Some(prev) => TS_BETA * obs + (1.0 - TS_BETA) * prev,
            None => obs,
        });
    }

    /// Lazily size the per-link estimator to the attached topology, seeding
    /// every link at its nominal 1/bandwidth (so the scheduler is sensible
    /// before the first observation). No-op on flat runs.
    fn ensure_link_state(&mut self, ctx: &SyncCtx) {
        let Some(topo) = ctx.net.topology() else {
            return;
        };
        if self.link_est.len() == topo.n_links() {
            return;
        }
        self.link_est = (0..topo.n_links())
            .map(|l| 1.0 / topo.link_spec(l).bandwidth_bps)
            .collect();
        self.link_obs_count = vec![0; topo.n_links()];
    }

    /// Fold the simulator's per-link observations from the most recent
    /// hierarchical schedule into the EWMA seconds-per-byte estimates. The
    /// first observation on a link replaces the nominal seed outright;
    /// later ones blend with [`TS_BETA`].
    fn fold_link_obs(&mut self, ctx: &SyncCtx) {
        if ctx.net.link_observations().is_empty() {
            return;
        }
        self.ensure_link_state(ctx);
        let Some(topo) = ctx.net.topology() else {
            return;
        };
        for obs in ctx.net.link_observations() {
            let lat = topo.link_spec(obs.link).latency_s;
            let per_byte = (obs.hop_s - lat).max(0.0) / obs.chunk_bytes.max(1.0);
            if !per_byte.is_finite() {
                continue;
            }
            self.link_est[obs.link] = if self.link_obs_count[obs.link] == 0 {
                per_byte
            } else {
                TS_BETA * per_byte + (1.0 - TS_BETA) * self.link_est[obs.link]
            };
            self.link_obs_count[obs.link] += 1;
        }
    }

    /// Adaptive per-link scheduling: build the inter-region cycle for a
    /// transfer of `wire_bytes`, greedily extending from the current region
    /// to the unvisited one whose connecting link is cheapest under
    /// queue-wait + nominal latency + chunk × EWMA-seconds-per-byte —
    /// i.e. each fragment is steered onto the least-loaded feasible links.
    /// Links severed by a regional outage are infeasible. Returns true with
    /// the cycle in `route_buf`; false (fall back to the canonical ring)
    /// when no topology is attached, fewer than two regions participate, or
    /// no full cycle of direct live links exists.
    fn build_route(&mut self, wire_bytes: f64, ctx: &SyncCtx) -> bool {
        if ctx.net.topology().is_none() {
            return false;
        }
        self.ensure_link_state(ctx);
        let topo = ctx.net.topology().expect("checked above");
        topo.participating_into(ctx.live, &mut self.parts_buf);
        let k = self.parts_buf.len();
        if k < 2 {
            return false;
        }
        let now = ctx.clock.now();
        let chunk = wire_bytes / k as f64;
        self.route_buf.clear();
        // Small per-call allocation is fine here: this path only runs in
        // topology mode, outside the flat hot-path allocation contract.
        let mut visited = vec![false; k];
        visited[0] = true;
        let mut cur = 0usize;
        for _ in 1..k {
            let mut best: Option<(usize, usize, f64)> = None;
            for (j, seen) in visited.iter().enumerate() {
                if *seen {
                    continue;
                }
                let Some(l) = topo.link_between(self.parts_buf[cur], self.parts_buf[j]) else {
                    continue;
                };
                if topo.severed(l, ctx.net.faults(), now) {
                    continue;
                }
                let spec = topo.link_spec(l);
                let wait = (topo.link_busy(l) - now).max(0.0);
                let cost = wait + spec.latency_s + chunk * self.link_est[l];
                // Strict `<` keeps the lowest-index candidate on ties, so
                // every worker derives the same route deterministically.
                if best.map_or(true, |(_, _, c)| cost < c) {
                    best = Some((j, l, cost));
                }
            }
            let Some((j, l, _)) = best else {
                return false;
            };
            self.route_buf.push(l);
            visited[j] = true;
            cur = j;
        }
        let Some(l) = topo.link_between(self.parts_buf[cur], self.parts_buf[0]) else {
            return false;
        };
        if topo.severed(l, ctx.net.faults(), now) {
            return false;
        }
        self.route_buf.push(l);
        true
    }

    /// T_s observation for a pending whose transfer just resolved:
    /// elapsed virtual time from request to resolution, scaled to the mean
    /// fragment's wire size (the latency term doesn't scale with bytes,
    /// but for a schedule estimator the byte-normalization is what keeps
    /// differently-sized fragments comparable). Undelivered resolutions
    /// observe the timeout budget — a conservative floor that still pushes
    /// the schedule toward its K floor during an outage.
    fn ts_observation(pend: &Pending, requested_at: f64, delivered: bool, ctx: &SyncCtx) -> f64 {
        if delivered {
            (pend.finish_time - requested_at).max(1e-9) * ctx.frags.mean_bytes()
                / pend.wire_bytes.max(1.0)
        } else {
            ctx.net.faults().retry().timeout_budget_s
        }
    }

    /// Defer a delivered pending's apply to the transfer's actual arrival
    /// when a fixed τ would make it stall: τ_eff = max(τ, arrival). A pure
    /// function of the deterministic transfer timeline, so all workers
    /// agree. TauMode::Network already schedules applies at arrival.
    fn defer_apply_to_arrival(pend: &mut Pending, step: u32, requested_at: f64, ctx: &SyncCtx) {
        if !pend.delivered {
            return;
        }
        if let TauMode::Fixed { tau } = ctx.cfg.tau {
            let arrival = ctx.net.tau_steps(
                requested_at,
                pend.finish_time,
                ctx.cfg.network.step_compute_s,
            );
            pend.apply_step = step.saturating_add(arrival.max(tau));
        }
    }

    /// Alg. 2: deterministic fragment selection at step `t`, with the
    /// reason it was selected. Returns None when every candidate is
    /// already in flight.
    fn select_fragment(&self, t: u32, h_steps: u32) -> Option<(usize, SelectReason)> {
        let k = self.change_rate.len();
        let in_flight =
            |p: usize| self.pending.iter().any(|q| q.frag == p);
        // Staleness guard: any fragment not synchronized for >= H steps.
        for p in 0..k {
            if t.saturating_sub(self.last_initiated[p]) >= h_steps && !in_flight(p) {
                return Some((p, SelectReason::StalenessGuard));
            }
        }
        // Otherwise the largest change rate R_p.
        (0..k)
            .filter(|&p| !in_flight(p))
            .max_by(|&a, &b| {
                self.change_rate[a]
                    .total_cmp(&self.change_rate[b])
                    // Deterministic tie-break on index (all workers agree).
                    .then(b.cmp(&a))
            })
            .map(|p| (p, SelectReason::MaxRate))
    }

    /// Drain due syncs in place (stable order, no queue rebuild) and apply
    /// Alg. 1 per worker — fanned out on the persistent worker pool when a
    /// pool is attached and the fragment is large enough to pay for it
    /// (elementwise per-worker work, so serial and parallel results are
    /// bit-identical). While a worker is crashed the fan-out falls back to
    /// a serial loop that skips it.
    fn complete_due(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].apply_step > step {
                i += 1;
                continue;
            }
            // Deferred-apply re-verification: CoCoDC holds payloads for
            // τ_eff steps before applying, so the integrity check runs
            // again here — a mismatching payload is quarantined and
            // retransmitted, never delay-compensated into worker state.
            if checksum_f32(&self.pending[i].delta_avg) != self.pending[i].checksum {
                let pend = &mut self.pending[i];
                ctx.stats.corrupt_fragments += 1;
                ctx.stats.quarantined += 1;
                pend.delivered = false;
                pend.apply_step = u32::MAX;
                pend.finish_time = ctx.clock.now();
                i += 1;
                continue;
            }
            let pend = self.pending.remove(i);
            if pend.finish_time > ctx.clock.now() {
                ctx.clock.stall_until(pend.finish_time);
                ctx.stats.apply_stalls += 1;
            }
            let p = pend.frag;
            let frag = ctx.frags.get(p);
            ctx.outer_step(p, &pend.delta_avg)?;
            ctx.stats.syncs_completed += 1;
            ctx.stats.per_fragment[p] += 1;

            // Eq. 11: update the change-rate metric from the *globally
            // averaged* pseudo-gradient over the completed interval.
            let i_p = step.saturating_sub(self.last_completed[p]).max(1) as f64;
            self.change_rate[p] = vecops::l2_norm(&pend.delta_avg) / i_p;
            self.last_completed[p] = step;

            // Alg. 1 per worker: delay-compensated adoption applied on the
            // backend's resident fragment, straight from the (disjointly
            // borrowed) global fragment slice.
            let tau = (step.saturating_sub(pend.t_init)).max(1) as f32;
            let h = ctx.cfg.h_steps as f32;
            let lambda = ctx.cfg.lambda;
            let all_live = ctx.all_live();
            let live = ctx.live;
            let snaps = pend
                .snapshots
                .as_ref()
                .expect("CoCoDC pendings always carry snapshots");
            let backend = ctx.backend;
            {
                let new_g: &[f32] = &ctx.global.theta_g[frag.range()];
                let workers = &mut *ctx.workers;
                match ctx.threads {
                    Some(tp)
                        if all_live
                            && workers.len() > 1
                            && frag.size >= PAR_FRAGMENT_MIN =>
                    {
                        let mut results: Vec<Option<anyhow::Result<()>>> =
                            workers.iter().map(|_| None).collect();
                        let tasks: Vec<ScopedTask<'_>> = workers
                            .iter_mut()
                            .zip(snaps.iter())
                            .zip(results.iter_mut())
                            .map(|((w, snap), slot)| {
                                Box::new(move || {
                                    *slot = Some(backend.delay_comp_fragment(
                                        w, frag, new_g, snap, tau, h, lambda,
                                    ));
                                }) as ScopedTask<'_>
                            })
                            .collect();
                        tp.scoped(tasks);
                        for r in results {
                            r.expect("pool ran every task")?;
                        }
                    }
                    _ => {
                        for (m, (w, snap)) in
                            workers.iter_mut().zip(snaps.iter()).enumerate()
                        {
                            // Crashed workers adopt the global fragment
                            // state when they rejoin; skip them here.
                            if live.map_or(true, |l| l[m]) {
                                backend
                                    .delay_comp_fragment(w, frag, new_g, snap, tau, h, lambda)?;
                            }
                        }
                    }
                }
            }
            pend.recycle(ctx.pool);
        }
        Ok(())
    }
}

impl SyncStrategy for Cocodc {
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        // Retransmit requeued fragments first; their resolutions feed the
        // live T_s estimate (a timed-out transfer is exactly the evidence
        // the schedule should back off on).
        for i in 0..self.pending.len() {
            let requested_at = ctx.clock.now();
            // Mirror retransmit's own guard so adaptive routes are only
            // built for pendings that actually retransmit now.
            if self.pending[i].delivered || self.pending[i].finish_time > requested_at {
                continue;
            }
            let routed = self.build_route(self.pending[i].wire_bytes, ctx);
            let route = if routed { Some(self.route_buf.as_slice()) } else { None };
            if let Some(delivered) =
                StreamingDiloco::retransmit(&mut self.pending[i], step, route, ctx)
            {
                self.fold_link_obs(ctx);
                Self::defer_apply_to_arrival(&mut self.pending[i], step, requested_at, ctx);
                let obs = Self::ts_observation(&self.pending[i], requested_at, delivered, ctx);
                self.observe_ts(obs);
            }
        }
        self.complete_due(step, ctx)?;
        if step == 0 || step < self.next_init {
            return Ok(());
        }
        // Eq. 9/10 from the live T_s estimate (EWMA over observed
        // transfers), falling back to the static ring-time model until the
        // first observation.
        let t_sync = self
            .ts_ewma
            .unwrap_or_else(|| ctx.net.t_sync(ctx.frags.mean_bytes()));
        let (_n, h) = Self::schedule_params(ctx.cfg, ctx.frags, t_sync);
        if let Some((p, reason)) = self.select_fragment(step, ctx.cfg.h_steps) {
            // Guard-hit accounting uses the selection's own reason; the
            // is_finite filter keeps cold-start picks (never-synced
            // fragments with R_p = ∞) out of the counter.
            if reason == SelectReason::StalenessGuard && self.change_rate[p].is_finite() {
                ctx.stats.staleness_guard_hits += 1;
            }
            let requested_at = ctx.clock.now();
            let wire = ctx.cfg.compression.wire_bytes(ctx.frags.get(p).size);
            let routed = self.build_route(wire, ctx);
            let route = if routed { Some(self.route_buf.as_slice()) } else { None };
            let mut pend = StreamingDiloco::initiate(p, step, true, route, ctx)?;
            self.fold_link_obs(ctx);
            Self::defer_apply_to_arrival(&mut pend, step, requested_at, ctx);
            let obs = Self::ts_observation(&pend, requested_at, pend.delivered, ctx);
            self.observe_ts(obs);
            self.last_initiated[p] = step;
            self.pending.push(pend);
        }
        self.next_init = step.saturating_add(h);
        Ok(())
    }

    fn pending(&self) -> usize {
        self.pending.len()
    }

    fn name(&self) -> &'static str {
        "cocodc"
    }

    fn save_state(&self, ck: &mut Checkpoint) {
        save_pendings(ck, &self.pending);
        let k = self.change_rate.len();
        let mut sched = Vec::with_capacity(6 * k + 6);
        pack_f64s(&mut sched, &self.change_rate);
        let as_u64: Vec<u64> = self.last_completed.iter().map(|&x| x as u64).collect();
        pack_u64s(&mut sched, &as_u64);
        let as_u64: Vec<u64> = self.last_initiated.iter().map(|&x| x as u64).collect();
        pack_u64s(&mut sched, &as_u64);
        pack_u64s(
            &mut sched,
            &[self.next_init as u64, self.ts_ewma.is_some() as u64],
        );
        pack_f64s(&mut sched, &[self.ts_ewma.unwrap_or(0.0)]);
        ck.insert("strategy/sched", sched);
        // Per-link EWMA estimates exist only in topology mode; the section
        // is omitted on flat runs so their checkpoint bytes are unchanged.
        if !self.link_est.is_empty() {
            let n = self.link_est.len();
            let mut links = Vec::with_capacity(2 + 4 * n);
            pack_u64s(&mut links, &[n as u64]);
            pack_f64s(&mut links, &self.link_est);
            pack_u64s(&mut links, &self.link_obs_count);
            ck.insert("strategy/links", links);
        }
    }

    fn load_state(&mut self, ck: &Checkpoint, pool: &mut BufferPool) -> anyhow::Result<()> {
        for p in std::mem::take(&mut self.pending) {
            p.recycle(pool);
        }
        self.pending = load_pendings(ck, pool)?;
        if let Some(s) = ck.get("strategy/sched") {
            let k = self.change_rate.len();
            anyhow::ensure!(s.len() == 6 * k + 6, "strategy/sched malformed");
            self.change_rate = unpack_f64s(&s[0..2 * k]);
            self.last_completed = unpack_u64s(&s[2 * k..4 * k])
                .iter()
                .map(|&x| x as u32)
                .collect();
            self.last_initiated = unpack_u64s(&s[4 * k..6 * k])
                .iter()
                .map(|&x| x as u32)
                .collect();
            let tail = unpack_u64s(&s[6 * k..6 * k + 4]);
            self.next_init = tail[0] as u32;
            let ewma = unpack_f64s(&s[6 * k + 4..6 * k + 6])[0];
            self.ts_ewma = if tail[1] != 0 { Some(ewma) } else { None };
        }
        if let Some(s) = ck.get("strategy/links") {
            anyhow::ensure!(s.len() >= 2, "strategy/links malformed");
            let n = unpack_u64s(&s[0..2])[0] as usize;
            anyhow::ensure!(s.len() == 2 + 4 * n, "strategy/links malformed");
            self.link_est = unpack_f64s(&s[2..2 + 2 * n]);
            self.link_obs_count = unpack_u64s(&s[2 + 2 * n..]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn frags() -> FragmentTable {
        FragmentTable::from_sizes(&[100, 100, 100, 100])
    }

    #[test]
    fn schedule_params_respects_gamma_and_floor() {
        let mut cfg = RunConfig::default(); // H=100, gamma=0.4, T_c=0.15
        // Paper §IV-A: parameters chosen so N=8 syncs per H -> h=12.
        // gamma*H*T_c/T_s = 0.4*100*0.15/T_s; with T_s=0.75 -> N=8.
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), 0.75);
        assert_eq!(n, 8);
        assert_eq!(h, 12);
        // Very slow network: floor at K.
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), 1e9);
        assert_eq!(n, 4);
        assert_eq!(h, 25);
        // gamma=1, fast network: many syncs, h floors at 1.
        cfg.gamma = 1.0;
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), 1e-6);
        assert!(n >= 100);
        assert_eq!(h, 1);
    }

    #[test]
    fn schedule_params_saturates_on_degenerate_t_sync() {
        let cfg = RunConfig::default();
        // T_s → 0: the ratio explodes to +inf; N clamps at u32::MAX
        // (h floors at 1) instead of wrapping.
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), 0.0);
        assert_eq!(n, u32::MAX);
        assert_eq!(h, 1);
        // NaN T_s (0/0-style degenerate estimate): falls to the K floor.
        let (n, h) = Cocodc::schedule_params(&cfg, &frags(), f64::NAN);
        assert_eq!(n, 4);
        assert_eq!(h, 25);
        // Negative T_s (clock skew artifact): ratio is negative, K floor.
        let (n, _) = Cocodc::schedule_params(&cfg, &frags(), -1.0);
        assert_eq!(n, 4);
    }

    #[test]
    fn selection_prefers_stale_then_max_rate() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        // All rates finite; fragment 2 hottest.
        c.change_rate = vec![1.0, 2.0, 5.0, 0.5];
        c.last_initiated = vec![90, 90, 90, 90];
        assert_eq!(c.select_fragment(100, 100), Some((2, SelectReason::MaxRate)));
        // Fragment 3 violates the staleness guard -> wins regardless of R.
        c.last_initiated[3] = 0;
        assert_eq!(
            c.select_fragment(100, 100),
            Some((3, SelectReason::StalenessGuard))
        );
    }

    #[test]
    fn selection_skips_in_flight() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        c.change_rate = vec![5.0, 1.0, 0.5, 0.2];
        c.last_initiated = vec![95; 4];
        c.pending.push(Pending {
            frag: 0,
            t_init: 99,
            apply_step: 104,
            finish_time: 0.0,
            wire_bytes: 0.0,
            delivered: true,
            delta_avg: vec![],
            snapshots: None,
            participants: None,
            checksum: checksum_f32(&[]),
        });
        assert_eq!(c.select_fragment(100, 100), Some((1, SelectReason::MaxRate)));
    }

    #[test]
    fn infinite_rate_gives_initial_priority() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        // Nothing synced yet: all ∞; deterministic tie-break -> fragment 0.
        c.last_initiated = vec![1; 4];
        assert_eq!(c.select_fragment(2, 100), Some((0, SelectReason::MaxRate)));
        c.change_rate[0] = 3.0; // fragment 0 done once, others still ∞
        c.change_rate[1] = 2.0;
        assert!(matches!(
            c.select_fragment(2, 100),
            Some((2, SelectReason::MaxRate))
        ));
    }

    #[test]
    fn link_estimates_round_trip_through_checkpoint() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        c.link_est = vec![1e-8, 2e-8, 3e-8];
        c.link_obs_count = vec![4, 0, 6];
        let mut ck = Checkpoint::new(0);
        c.save_state(&mut ck);
        assert!(ck.get("strategy/links").is_some());
        let mut d = Cocodc::new(&cfg, &frags());
        let mut pool = BufferPool::new();
        d.load_state(&ck, &mut pool).unwrap();
        assert_eq!(d.link_est, c.link_est);
        assert_eq!(d.link_obs_count, c.link_obs_count);
        // Flat runs never grow link state and never write the section.
        let flat = Cocodc::new(&cfg, &frags());
        let mut ck2 = Checkpoint::new(0);
        flat.save_state(&mut ck2);
        assert!(ck2.get("strategy/links").is_none());
    }

    #[test]
    fn ts_ewma_blends_observations() {
        let cfg = RunConfig::default();
        let mut c = Cocodc::new(&cfg, &frags());
        assert_eq!(c.ts_ewma, None);
        c.observe_ts(1.0);
        assert_eq!(c.ts_ewma, Some(1.0));
        c.observe_ts(11.0); // outage-stretched observation pulls it up...
        let after = c.ts_ewma.unwrap();
        assert!((after - (0.3 * 11.0 + 0.7)).abs() < 1e-12);
        c.observe_ts(1.0); // ...and recovery pulls it back down.
        assert!(c.ts_ewma.unwrap() < after);
        // Degenerate observations are ignored.
        c.observe_ts(f64::NAN);
        c.observe_ts(-5.0);
        assert!(c.ts_ewma.unwrap().is_finite());
    }
}
