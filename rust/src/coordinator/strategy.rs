//! The `SyncStrategy` trait: how a cross-region method reacts after each
//! lockstep local training step, plus the state shared by all methods.

use crate::config::{MethodKind, RunConfig};
use crate::coordinator::fragments::FragmentTable;
use crate::coordinator::{cocodc::Cocodc, diloco::Diloco, streaming::StreamingDiloco};
use crate::network::WanSimulator;
use crate::runtime::{Engine, TrainState};
use crate::simclock::VirtualClock;
use crate::util::pool::BufferPool;
use crate::util::threadpool::WorkerPool;

/// Consensus state shared (deterministically replicated) by all workers:
/// the last-synchronized global fragment states θ_p^g and the outer
/// optimizer's momentum buffers.
#[derive(Debug, Clone)]
pub struct GlobalState {
    /// θ^g as one flat vector (fragment-major, same layout as params).
    pub theta_g: Vec<f32>,
    /// Nesterov momentum, same layout.
    pub outer_momentum: Vec<f32>,
}

impl GlobalState {
    pub fn new(init_params: &[f32]) -> Self {
        GlobalState {
            theta_g: init_params.to_vec(),
            outer_momentum: vec![0.0; init_params.len()],
        }
    }
}

/// Counters every strategy maintains (reported in run summaries and used by
/// the γ-ablation).
#[derive(Debug, Clone, Default)]
pub struct SyncStats {
    pub syncs_initiated: usize,
    pub syncs_completed: usize,
    /// Per-fragment completed-sync counts.
    pub per_fragment: Vec<usize>,
    /// Total bytes charged to the WAN (per worker, one direction).
    pub bytes: f64,
    /// Times the staleness guard (Alg. 2 line 2) fired.
    pub staleness_guard_hits: usize,
    /// Times a worker stalled waiting for an overdue fragment.
    pub apply_stalls: usize,
}

impl SyncStats {
    pub fn new(k: usize) -> Self {
        SyncStats { per_fragment: vec![0; k], ..Default::default() }
    }
}

/// Everything a strategy can see/touch after a step. Borrows are split so
/// strategies can mutate workers and global state independently.
pub struct SyncCtx<'a> {
    pub workers: &'a mut [TrainState],
    pub global: &'a mut GlobalState,
    pub net: &'a mut WanSimulator,
    pub clock: &'a mut VirtualClock,
    /// Engine for the HLO fragment-op path (None in pure-simulation tests).
    pub engine: Option<&'a Engine>,
    pub cfg: &'a RunConfig,
    pub frags: &'a FragmentTable,
    pub stats: &'a mut SyncStats,
    /// Recycled fragment-sized buffers — snapshots, pseudo-gradients and
    /// HLO scratch come from here, so steady-state syncs never allocate.
    pub pool: &'a mut BufferPool,
    /// Persistent worker threads for per-worker fan-out (None = serial;
    /// results are bit-identical either way, fan-out is elementwise).
    pub threads: Option<&'a WorkerPool>,
}

impl<'a> SyncCtx<'a> {
    /// Nesterov outer step on fragment `p` with averaged pseudo-gradient
    /// `delta`, via the HLO artifact or the native rust twin. The HLO path
    /// reads results back into pooled scratch instead of fresh vectors.
    pub fn outer_step(&mut self, p: usize, delta: &[f32]) -> anyhow::Result<()> {
        let frag = self.frags.get(p);
        let (lr, mu) = (self.cfg.outer_lr, self.cfg.outer_momentum);
        if self.cfg.use_hlo_fragment_ops {
            if let Some(engine) = self.engine {
                let mut t2 = self.pool.take(frag.size);
                let mut m2 = self.pool.take(frag.size);
                {
                    let tg = self.frags.slice(&self.global.theta_g, p);
                    let mom = self.frags.slice(&self.global.outer_momentum, p);
                    engine.outer_step_hlo_into(p, tg, delta, mom, lr, mu, &mut t2, &mut m2)?;
                }
                self.global.theta_g[frag.range()].copy_from_slice(&t2);
                self.global.outer_momentum[frag.range()].copy_from_slice(&m2);
                self.pool.put(t2);
                self.pool.put(m2);
                return Ok(());
            }
        }
        let tg = &mut self.global.theta_g[frag.range()];
        let mom = &mut self.global.outer_momentum[frag.range()];
        super::outer_opt::outer_step(tg, delta, mom, lr, mu);
        Ok(())
    }
}

/// A cross-region synchronization method (one of the paper's three).
pub trait SyncStrategy: Send {
    /// Called after every lockstep local step; `step` is the number of
    /// completed local steps (1-based).
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()>;

    /// Number of in-flight fragment synchronizations.
    fn pending(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Instantiate the configured method.
pub fn make_strategy(cfg: &RunConfig, frags: &FragmentTable) -> Box<dyn SyncStrategy> {
    match cfg.method {
        MethodKind::Diloco => Box::new(Diloco::new()),
        MethodKind::StreamingDiloco => Box::new(StreamingDiloco::new(cfg, frags)),
        MethodKind::Cocodc => Box::new(Cocodc::new(cfg, frags)),
    }
}
