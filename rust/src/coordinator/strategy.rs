//! The `SyncStrategy` trait: how a cross-region method reacts after each
//! lockstep local training step, plus the state shared by all methods.

use crate::checkpoint::Checkpoint;
use crate::config::{MethodKind, RunConfig};
use crate::coordinator::fragments::FragmentTable;
use crate::coordinator::{cocodc::Cocodc, diloco::Diloco, streaming::StreamingDiloco};
use crate::metrics::Dist;
use crate::network::topology::LinkUtil;
use crate::network::WanSimulator;
use crate::runtime::{Backend, WorkerHandle};
use crate::simclock::VirtualClock;
use crate::util::pool::BufferPool;
use crate::util::threadpool::WorkerPool;
use crate::util::vecops;

/// Consensus state shared (deterministically replicated) by all workers:
/// the last-synchronized global fragment states θ_p^g and the outer
/// optimizer's momentum buffers.
#[derive(Debug, Clone)]
pub struct GlobalState {
    /// θ^g as one flat vector (fragment-major, same layout as params).
    pub theta_g: Vec<f32>,
    /// Nesterov momentum, same layout.
    pub outer_momentum: Vec<f32>,
}

impl GlobalState {
    pub fn new(init_params: &[f32]) -> Self {
        GlobalState {
            theta_g: init_params.to_vec(),
            outer_momentum: vec![0.0; init_params.len()],
        }
    }
}

/// Counters every strategy maintains (reported in run summaries and used by
/// the γ-ablation).
#[derive(Debug, Clone, Default)]
pub struct SyncStats {
    pub syncs_initiated: usize,
    pub syncs_completed: usize,
    /// Per-fragment completed-sync counts.
    pub per_fragment: Vec<usize>,
    /// Total bytes charged to the WAN (per worker, one direction).
    pub bytes: f64,
    /// Times the staleness guard (Alg. 2 line 2) fired.
    pub staleness_guard_hits: usize,
    /// Times a worker stalled waiting for an overdue fragment.
    pub apply_stalls: usize,
    /// Retransmission attempts after in-flight losses (fault plan).
    pub retries: usize,
    /// Transfer attempts lost in flight.
    pub drops: usize,
    /// Logical transfers that exhausted their retry/timeout budget.
    pub timeouts: usize,
    /// Timed-out fragments re-entered into the pending queue for later
    /// retransmission.
    pub requeues: usize,
    /// Fragment payloads that arrived with a checksum mismatch (in-flight
    /// bit flips from the corruption fault class).
    pub corrupt_fragments: usize,
    /// Corrupt fragments quarantined instead of applied; each is requeued
    /// for retransmission, so this must always equal `corrupt_fragments` —
    /// a corrupt payload is never applied.
    pub quarantined: usize,
    /// Distribution of effective overlap depths τ over delivered syncs.
    pub tau_dist: Dist,
    /// Distribution of transfer queue delays (seconds) over delivered syncs.
    pub queue_delay_dist: Dist,
    /// Per-WAN-link utilization (bytes moved, busy seconds, transfers),
    /// filled from the topology layer at end of run; empty on flat runs.
    pub link_util: Vec<LinkUtil>,
}

impl SyncStats {
    pub fn new(k: usize) -> Self {
        SyncStats { per_fragment: vec![0; k], ..Default::default() }
    }
}

/// Everything a strategy can see/touch after a step. Borrows are split so
/// strategies can mutate workers and global state independently.
///
/// Worker training state is *resident in the backend* behind opaque
/// [`WorkerHandle`]s: strategies move parameter data exclusively through
/// the backend's fragment API (`read_fragment`/`write_fragment` into pooled
/// buffers, delay-comp/α-blend applied backend-side), so only synchronized
/// fragments ever cross the runtime boundary.
pub struct SyncCtx<'a> {
    pub workers: &'a mut [WorkerHandle],
    pub global: &'a mut GlobalState,
    pub net: &'a mut WanSimulator,
    pub clock: &'a mut VirtualClock,
    /// The execution backend owning all resident worker state.
    pub backend: &'a dyn Backend,
    pub cfg: &'a RunConfig,
    pub frags: &'a FragmentTable,
    pub stats: &'a mut SyncStats,
    /// Recycled fragment-sized buffers — snapshots, pseudo-gradients and
    /// read-back scratch come from here, so steady-state syncs never
    /// allocate.
    pub pool: &'a mut BufferPool,
    /// Persistent worker threads for per-worker fan-out (None = serial;
    /// results are bit-identical either way, fan-out is elementwise).
    pub threads: Option<&'a WorkerPool>,
    /// Per-worker liveness mask maintained by the trainer's fault plan
    /// (None = everyone live, the common case). Crashed workers keep their
    /// frozen resident state but are excluded from pseudo-gradient means
    /// and from sync result application until they rejoin.
    pub live: Option<&'a [bool]>,
}

impl<'a> SyncCtx<'a> {
    /// Nesterov outer step on fragment `p` with averaged pseudo-gradient
    /// `delta`. Dispatches through the backend so the PJRT implementation
    /// can route it to the Pallas/HLO artifact; the native/host twins run
    /// the fused kernel in place on the global slices.
    pub fn outer_step(&mut self, p: usize, delta: &[f32]) -> anyhow::Result<()> {
        let frag = self.frags.get(p);
        let (lr, mu) = (self.cfg.outer_lr, self.cfg.outer_momentum);
        let tg = &mut self.global.theta_g[frag.range()];
        let mom = &mut self.global.outer_momentum[frag.range()];
        self.backend.outer_step_fragment(frag, tg, delta, mom, lr, mu)
    }

    pub fn is_live(&self, m: usize) -> bool {
        self.live.map_or(true, |l| l.get(m).copied().unwrap_or(true))
    }

    pub fn all_live(&self) -> bool {
        self.live.map_or(true, |l| l.iter().all(|&x| x))
    }

    pub fn live_count(&self) -> usize {
        self.live
            .map_or(self.workers.len(), |l| l.iter().filter(|&&x| x).count())
    }

    /// Averaged pseudo-gradient of fragment `p` over the *surviving*
    /// workers (quorum semantics: the mean renormalizes over live workers,
    /// so a crashed worker's frozen replica never dilutes the consensus).
    /// With everyone live this is the backend's zero-copy resident-state
    /// path — bit-identical to the pre-fault builds; the degraded path
    /// copies live rows into pooled buffers (allocation there is fine: it
    /// only runs while a worker is down).
    pub fn pseudo_mean_live(&mut self, p: usize, out: &mut [f32]) -> anyhow::Result<()> {
        let frag = self.frags.get(p);
        if self.all_live() {
            let theta_g = self.frags.slice(&self.global.theta_g, p);
            return self.backend.pseudo_mean_fragment(self.workers, frag, theta_g, out);
        }
        anyhow::ensure!(self.live_count() > 0, "no live workers to average");
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for (m, w) in self.workers.iter().enumerate() {
            if self.live.map_or(true, |l| l[m]) {
                let mut buf = self.pool.take(frag.size);
                self.backend.read_fragment(w, frag, &mut buf)?;
                rows.push(buf);
            }
        }
        let theta_g = self.frags.slice(&self.global.theta_g, p);
        vecops::fused_pseudo_mean(out, &rows, theta_g);
        for r in rows {
            self.pool.put(r);
        }
        Ok(())
    }
}

/// A cross-region synchronization method (one of the paper's three).
pub trait SyncStrategy: Send {
    /// Called after every lockstep local step; `step` is the number of
    /// completed local steps (1-based).
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()>;

    /// Number of in-flight fragment synchronizations.
    fn pending(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Serialize strategy-internal state (in-flight syncs, schedule
    /// history) into `strategy/*` checkpoint sections so a resumed run
    /// replays identically even with transfers in flight — including across
    /// an active fault window.
    fn save_state(&self, ck: &mut Checkpoint) {
        let _ = ck;
    }

    /// Inverse of [`SyncStrategy::save_state`]; pre-existing in-flight
    /// state is recycled into `pool`. Checkpoints without `strategy/*`
    /// sections (older format) restore to an empty schedule.
    fn load_state(&mut self, ck: &Checkpoint, pool: &mut BufferPool) -> anyhow::Result<()> {
        let _ = (ck, pool);
        Ok(())
    }
}

/// Instantiate the configured method.
pub fn make_strategy(cfg: &RunConfig, frags: &FragmentTable) -> Box<dyn SyncStrategy> {
    match cfg.method {
        MethodKind::Diloco => Box::new(Diloco::new()),
        MethodKind::StreamingDiloco => Box::new(StreamingDiloco::new(cfg, frags)),
        MethodKind::Cocodc => Box::new(Cocodc::new(cfg, frags)),
    }
}
