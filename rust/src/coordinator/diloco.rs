//! DiLoCo baseline (Douillard et al.): every H local steps, a *blocking*
//! all-reduce of the full pseudo-gradient, outer Nesterov step, and adoption
//! of the new global state by every worker. Compute and communication are
//! strictly serialized — the resource underutilization the paper's §I
//! motivates against — which the virtual clock charges as a stall.

use super::strategy::{SyncCtx, SyncStrategy};

#[derive(Debug, Default)]
pub struct Diloco {
    /// Completed blocking outer rounds.
    pub rounds: usize,
}

impl Diloco {
    pub fn new() -> Self {
        Diloco { rounds: 0 }
    }
}

impl SyncStrategy for Diloco {
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        if step == 0 || step % ctx.cfg.h_steps != 0 {
            return Ok(());
        }
        self.rounds += 1;
        // Blocking full-model ring all-reduce: charge the WAN and stall.
        let now = ctx.clock.now();
        let bytes = ctx.cfg.compression.wire_bytes(ctx.frags.total_params());
        let transfer = ctx.net.schedule_allreduce(now, bytes);
        ctx.clock.stall_until(transfer.finish);
        ctx.stats.bytes += bytes;
        ctx.stats.syncs_initiated += ctx.frags.k();
        ctx.stats.syncs_completed += ctx.frags.k();

        // Per fragment: Δ^g = mean(θ^m − θ^g); outer step; adopt. The
        // pseudo-gradient is averaged backend-side straight over resident
        // worker state (no per-worker fragment copies); `delta` lives in a
        // pooled buffer and the refreshed global is written back through
        // the fragment API — no steady-state allocations.
        for p in 0..ctx.frags.k() {
            let frag = ctx.frags.get(p);
            let mut delta = ctx.pool.take(frag.size);
            {
                let theta_g = ctx.frags.slice(&ctx.global.theta_g, p);
                ctx.backend.pseudo_mean_fragment(ctx.workers, frag, theta_g, &mut delta)?;
            }
            ctx.cfg.compression.round_trip(&mut delta);
            ctx.outer_step(p, &delta)?;
            ctx.stats.per_fragment[p] += 1;
            {
                let new_g = &ctx.global.theta_g[frag.range()];
                for w in ctx.workers.iter_mut() {
                    ctx.backend.write_fragment(w, frag, new_g)?;
                }
            }
            ctx.pool.put(delta);
        }
        Ok(())
    }

    fn pending(&self) -> usize {
        0 // blocking: nothing is ever in flight after post_step returns
    }

    fn name(&self) -> &'static str {
        "diloco"
    }
}
