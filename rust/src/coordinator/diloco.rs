//! DiLoCo baseline (Douillard et al.): every H local steps, a *blocking*
//! all-reduce of the full pseudo-gradient, outer Nesterov step, and adoption
//! of the new global state by every worker. Compute and communication are
//! strictly serialized — the resource underutilization the paper's §I
//! motivates against — which the virtual clock charges as a stall.
//!
//! Under a fault plan the blocking design has no overlap to hide behind:
//! every dropped attempt, backoff wait and timeout is a dead stall on the
//! critical path (the measured baseline the resilience experiments compare
//! against). The strategy never gives up — a timed-out budget just starts a
//! fresh one from the later virtual time, since there is no pending queue
//! to park the round in.
//!
//! With a multi-region topology attached the blocking round is still one
//! `schedule_with_retries` call — the WAN simulator's dispatch routes it
//! through the hierarchical two-level model (intra all-reduce, leader ring
//! over the canonical region cycle, intra broadcast), so DiLoCo benefits
//! from regional aggregation without any strategy-side changes.

use crate::checkpoint::{pack_u64s, unpack_u64s, Checkpoint};
use crate::util::pool::BufferPool;

use super::strategy::{SyncCtx, SyncStrategy};

#[derive(Debug, Default)]
pub struct Diloco {
    /// Completed blocking outer rounds.
    pub rounds: usize,
}

impl Diloco {
    pub fn new() -> Self {
        Diloco { rounds: 0 }
    }
}

impl SyncStrategy for Diloco {
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        if step == 0 || step % ctx.cfg.h_steps != 0 {
            return Ok(());
        }
        self.rounds += 1;
        // Blocking full-model ring all-reduce: charge the WAN and stall.
        // Losses retry inside the budget; an exhausted budget stalls to its
        // resolution time and starts over (each round strictly advances the
        // clock, so this terminates).
        let bytes = ctx.cfg.compression.wire_bytes(ctx.frags.total_params());
        ctx.stats.syncs_initiated += ctx.frags.k();
        let transfer = loop {
            let now = ctx.clock.now();
            let sched = ctx.net.schedule_with_retries(now, bytes);
            ctx.stats.retries += sched.retries() as usize;
            ctx.stats.drops += sched.drops as usize;
            ctx.stats.bytes += bytes * sched.attempts as f64;
            match sched.transfer {
                Some(t) => {
                    if sched.corruption.is_some() {
                        // Checksum mismatch on arrival. The blocking
                        // baseline has no pending queue to park a corrupt
                        // payload in, so the whole round is quarantined
                        // (never applied) and retransmitted from the later
                        // virtual time — one more dead stall on the
                        // critical path.
                        ctx.stats.corrupt_fragments += ctx.frags.k();
                        ctx.stats.quarantined += ctx.frags.k();
                        ctx.clock.stall_until(t.finish);
                        continue;
                    }
                    break t;
                }
                None => {
                    ctx.stats.timeouts += 1;
                    ctx.clock.stall_until(sched.resolved_at);
                }
            }
        };
        ctx.stats.queue_delay_dist.record(transfer.queue_delay());
        ctx.clock.stall_until(transfer.finish);
        ctx.stats.syncs_completed += ctx.frags.k();

        // Per fragment: Δ^g = mean(θ^m − θ^g); outer step; adopt. The
        // pseudo-gradient is averaged backend-side straight over resident
        // worker state (no per-worker fragment copies); `delta` lives in a
        // pooled buffer and the refreshed global is written back through
        // the fragment API — no steady-state allocations. While a worker is
        // crashed the mean renormalizes over survivors and the adoption
        // write skips it (it adopts θ^g wholesale on rejoin).
        let live = ctx.live;
        for p in 0..ctx.frags.k() {
            let frag = ctx.frags.get(p);
            let mut delta = ctx.pool.take(frag.size);
            ctx.pseudo_mean_live(p, &mut delta)?;
            ctx.cfg.compression.round_trip(&mut delta);
            ctx.outer_step(p, &delta)?;
            ctx.stats.per_fragment[p] += 1;
            {
                let new_g = &ctx.global.theta_g[frag.range()];
                for (m, w) in ctx.workers.iter_mut().enumerate() {
                    if live.map_or(true, |l| l[m]) {
                        ctx.backend.write_fragment(w, frag, new_g)?;
                    }
                }
            }
            ctx.pool.put(delta);
        }
        Ok(())
    }

    fn pending(&self) -> usize {
        0 // blocking: nothing is ever in flight after post_step returns
    }

    fn name(&self) -> &'static str {
        "diloco"
    }

    fn save_state(&self, ck: &mut Checkpoint) {
        let mut s = Vec::with_capacity(2);
        pack_u64s(&mut s, &[self.rounds as u64]);
        ck.insert("strategy/diloco", s);
    }

    fn load_state(&mut self, ck: &Checkpoint, _pool: &mut BufferPool) -> anyhow::Result<()> {
        if let Some(s) = ck.get("strategy/diloco") {
            anyhow::ensure!(s.len() == 2, "strategy/diloco malformed");
            self.rounds = unpack_u64s(s)[0] as usize;
        }
        Ok(())
    }
}
