//! Fragment table: resolves the strided depth shards (Streaming DiLoCo's
//! partitioning, shared by CoCoDC) into contiguous ranges of the flat
//! parameter vector, as laid out by python/compile/config.flat_layout.

use crate::runtime::Meta;

/// One fragment's contiguous range in the flat vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fragment {
    pub index: usize,
    pub offset: usize,
    pub size: usize,
}

impl Fragment {
    #[inline]
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.size
    }
    pub fn bytes(&self) -> f64 {
        self.size as f64 * 4.0
    }
}

/// All K fragments of a model.
#[derive(Debug, Clone)]
pub struct FragmentTable {
    frags: Vec<Fragment>,
    total: usize,
}

impl FragmentTable {
    pub fn from_meta(meta: &Meta) -> Self {
        let frags = meta
            .fragments
            .iter()
            .map(|f| Fragment { index: f.index, offset: f.offset, size: f.size })
            .collect();
        FragmentTable { frags, total: meta.param_count }
    }

    /// Build directly from sizes (tests / benches without artifacts).
    pub fn from_sizes(sizes: &[usize]) -> Self {
        let mut frags = Vec::with_capacity(sizes.len());
        let mut off = 0;
        for (i, &s) in sizes.iter().enumerate() {
            assert!(s > 0, "fragments must be non-empty");
            frags.push(Fragment { index: i, offset: off, size: s });
            off += s;
        }
        FragmentTable { frags, total: off }
    }

    pub fn k(&self) -> usize {
        self.frags.len()
    }

    pub fn total_params(&self) -> usize {
        self.total
    }

    pub fn get(&self, index: usize) -> Fragment {
        self.frags[index]
    }

    pub fn iter(&self) -> impl Iterator<Item = Fragment> + '_ {
        self.frags.iter().copied()
    }

    /// Slice a flat vector to fragment `index`.
    pub fn slice<'a>(&self, flat: &'a [f32], index: usize) -> &'a [f32] {
        &flat[self.frags[index].range()]
    }

    pub fn slice_mut<'a>(&self, flat: &'a mut [f32], index: usize) -> &'a mut [f32] {
        &mut flat[self.frags[index].range()]
    }

    /// Mean fragment size (drives the adaptive scheduler's T_s estimate).
    pub fn mean_bytes(&self) -> f64 {
        self.frags.iter().map(|f| f.bytes()).sum::<f64>() / self.k() as f64
    }

    /// The evenly-spread round-robin initiation offsets Streaming DiLoCo
    /// uses within each H-step period: fragment p fires at local step
    /// `t > 0` with `t % H == offset(p)`, offsets `floor((p+1)*H/K)` (mod H).
    pub fn streaming_offsets(&self, h: u32) -> Vec<u32> {
        let k = self.k() as u32;
        (0..k).map(|p| ((p + 1) * h / k) % h).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_sizes_tiles_the_vector() {
        let t = FragmentTable::from_sizes(&[5, 3, 8]);
        assert_eq!(t.k(), 3);
        assert_eq!(t.total_params(), 16);
        assert_eq!(t.get(1), Fragment { index: 1, offset: 5, size: 3 });
        let flat: Vec<f32> = (0..16).map(|x| x as f32).collect();
        assert_eq!(t.slice(&flat, 1), &[5.0, 6.0, 7.0]);
    }

    #[test]
    fn slice_mut_edits_only_fragment() {
        let t = FragmentTable::from_sizes(&[2, 2]);
        let mut flat = vec![0.0f32; 4];
        t.slice_mut(&mut flat, 1).fill(9.0);
        assert_eq!(flat, vec![0.0, 0.0, 9.0, 9.0]);
    }

    #[test]
    fn streaming_offsets_spread_within_h() {
        let t = FragmentTable::from_sizes(&[1, 1, 1, 1]);
        assert_eq!(t.streaming_offsets(100), vec![25, 50, 75, 0]);
        // K=3, H=100 -> uneven but within [0, H)
        let t3 = FragmentTable::from_sizes(&[1, 1, 1]);
        for off in t3.streaming_offsets(100) {
            assert!(off < 100);
        }
    }

    #[test]
    fn mean_bytes() {
        let t = FragmentTable::from_sizes(&[10, 30]);
        assert_eq!(t.mean_bytes(), 80.0);
    }
}
