//! Streaming DiLoCo (Douillard et al. 2025): fragment-wise round-robin
//! synchronization overlapped with continued local training.
//!
//! At its scheduled step t_p, fragment p's pseudo-gradient
//! Δθ_p^m = θ_{p,t_p}^m − θ_p^g is captured and a non-blocking ring
//! all-reduce starts; training continues. τ steps later (fixed, or derived
//! from the WAN simulator) the averaged Δθ_p^g is applied through the outer
//! optimizer and the refreshed global fragment is *blended* into each
//! worker's live parameters with mixing factor α (Eq. 3):
//!
//!   θ_{p,t_l}^m ← (1−α)·θ_{p,t_l}^m + α·θ_{p,t_p}^g
//!
//! This is precisely where staleness (τ-step-old consensus) and
//! inconsistency (only fragment p refreshed) enter — the effects CoCoDC
//! compensates for.
//!
//! Hot-path discipline (see DESIGN.md §Hot path): snapshots and the
//! averaged pseudo-gradient live in pooled buffers recycled across syncs,
//! the averaging itself is the fused one-pass-per-worker kernel, the blend
//! is the fused α-kernel over a borrowed θ_g slice (no fragment copy), and
//! due entries drain from the pending queue in place — steady state does
//! zero heap allocations per initiate/complete cycle.

use crate::config::RunConfig;
use crate::config::TauMode;
use crate::coordinator::fragments::FragmentTable;
use crate::util::pool::BufferPool;
use crate::util::vecops;

use super::strategy::{SyncCtx, SyncStrategy};

/// An in-flight fragment synchronization. `delta_avg` and `snapshots` are
/// checked out of the [`BufferPool`] at initiation and must be returned
/// via [`Pending::recycle`] on completion.
#[derive(Debug)]
pub(crate) struct Pending {
    pub frag: usize,
    /// Initiation step t_p.
    pub t_init: u32,
    /// Local step t_l at which the result is applied (t_p + τ).
    pub apply_step: u32,
    /// Virtual time the all-reduce finishes (for stall accounting).
    pub finish_time: f64,
    /// Averaged pseudo-gradient Δθ_p^g (computed at initiation: the data is
    /// fixed once the transfer starts).
    pub delta_avg: Vec<f32>,
    /// Per-worker parameter snapshots θ_{p,t_p}^m (needed by CoCoDC's
    /// delay compensation; None for plain streaming to save memory).
    pub snapshots: Option<Vec<Vec<f32>>>,
}

impl Pending {
    /// Hand every buffer back to the pool.
    pub(crate) fn recycle(self, pool: &mut BufferPool) {
        pool.put(self.delta_avg);
        if let Some(snaps) = self.snapshots {
            pool.put_shell(snaps);
        }
    }
}

pub struct StreamingDiloco {
    offsets: Vec<u32>,
    pending: Vec<Pending>,
}

impl StreamingDiloco {
    pub fn new(cfg: &RunConfig, frags: &FragmentTable) -> Self {
        StreamingDiloco {
            offsets: frags.streaming_offsets(cfg.h_steps),
            pending: Vec::new(),
        }
    }

    /// Shared by CoCoDC: start a sync of fragment `p` at step `t`. All
    /// buffers come from (and eventually return to) `ctx.pool`. When the
    /// caller needs per-worker snapshots (CoCoDC's delay compensation),
    /// worker fragments are read out of the backend's resident state —
    /// the only parameter data that crosses the runtime boundary per sync;
    /// plain streaming averages backend-side with zero fragment copies.
    pub(crate) fn initiate(
        p: usize,
        t: u32,
        keep_snapshots: bool,
        ctx: &mut SyncCtx,
    ) -> anyhow::Result<Pending> {
        let frag = ctx.frags.get(p);
        let mut delta_avg = ctx.pool.take(frag.size);
        let snaps = if keep_snapshots {
            let mut snaps = ctx.pool.take_shell();
            for w in ctx.workers.iter() {
                let mut buf = ctx.pool.take(frag.size);
                ctx.backend.read_fragment(w, frag, &mut buf)?;
                snaps.push(buf);
            }
            let theta_g = ctx.frags.slice(&ctx.global.theta_g, p);
            // Average from the snapshots (bit-identical to the resident
            // rows they were copied from — same kernel, same order).
            vecops::fused_pseudo_mean(&mut delta_avg, &snaps, theta_g);
            Some(snaps)
        } else {
            let theta_g = ctx.frags.slice(&ctx.global.theta_g, p);
            ctx.backend.pseudo_mean_fragment(ctx.workers, frag, theta_g, &mut delta_avg)?;
            None
        };
        // What the wire would carry: round-trip through the codec and pay
        // for the compressed size (Streaming DiLoCo ships quantized
        // pseudo-gradients; the optimizer sees the dequantized values).
        ctx.cfg.compression.round_trip(&mut delta_avg);
        let wire = ctx.cfg.compression.wire_bytes(frag.size);
        let transfer = ctx.net.schedule_allreduce(ctx.clock.now(), wire);
        ctx.stats.bytes += wire;
        ctx.stats.syncs_initiated += 1;
        let tau = match ctx.cfg.tau {
            TauMode::Fixed { tau } => tau,
            TauMode::Network => ctx.net.tau_steps(
                ctx.clock.now(),
                transfer.finish,
                ctx.cfg.network.step_compute_s,
            ),
        };
        Ok(Pending {
            frag: p,
            t_init: t,
            apply_step: t + tau,
            finish_time: transfer.finish,
            delta_avg,
            snapshots: snaps,
        })
    }

    /// Complete every pending sync due at `step`: outer step + α-blend.
    /// Due entries are extracted in place (stable order) — the pending
    /// queue is never rebuilt.
    fn complete_due(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].apply_step > step {
                i += 1;
                continue;
            }
            let pend = self.pending.remove(i);
            // If the simulated transfer has not actually finished by now,
            // the apply blocks on it (honest wall-clock accounting).
            if pend.finish_time > ctx.clock.now() {
                ctx.clock.stall_until(pend.finish_time);
                ctx.stats.apply_stalls += 1;
            }
            let p = pend.frag;
            let frag = ctx.frags.get(p);
            ctx.outer_step(p, &pend.delta_avg)?;
            ctx.stats.syncs_completed += 1;
            ctx.stats.per_fragment[p] += 1;
            let alpha = ctx.cfg.alpha;
            {
                // θ_g and worker handles are disjoint SyncCtx fields: the
                // backend blends its resident fragment straight from the
                // borrowed global slice, no fragment copy.
                let new_g = &ctx.global.theta_g[frag.range()];
                for w in ctx.workers.iter_mut() {
                    ctx.backend.alpha_blend_fragment(w, frag, new_g, alpha)?;
                }
            }
            pend.recycle(ctx.pool);
        }
        Ok(())
    }
}

impl SyncStrategy for StreamingDiloco {
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        self.complete_due(step, ctx)?;
        if step == 0 {
            return Ok(());
        }
        let h = ctx.cfg.h_steps;
        for p in 0..ctx.frags.k() {
            if step % h == self.offsets[p]
                && !self.pending.iter().any(|q| q.frag == p)
            {
                let pend = Self::initiate(p, step, false, ctx)?;
                self.pending.push(pend);
            }
        }
        Ok(())
    }

    fn pending(&self) -> usize {
        self.pending.len()
    }

    fn name(&self) -> &'static str {
        "streaming_diloco"
    }
}
