//! Streaming DiLoCo (Douillard et al. 2025): fragment-wise round-robin
//! synchronization overlapped with continued local training.
//!
//! At its scheduled step t_p, fragment p's pseudo-gradient
//! Δθ_p^m = θ_{p,t_p}^m − θ_p^g is captured and a non-blocking ring
//! all-reduce starts; training continues. τ steps later (fixed, or derived
//! from the WAN simulator) the averaged Δθ_p^g is applied through the outer
//! optimizer and the refreshed global fragment is *blended* into each
//! worker's live parameters with mixing factor α (Eq. 3):
//!
//!   θ_{p,t_l}^m ← (1−α)·θ_{p,t_l}^m + α·θ_{p,t_p}^g
//!
//! This is precisely where staleness (τ-step-old consensus) and
//! inconsistency (only fragment p refreshed) enter — the effects CoCoDC
//! compensates for.

use crate::config::TauMode;
use crate::config::RunConfig;
use crate::coordinator::fragments::FragmentTable;

use super::allreduce::mean_pseudo_gradients_from_snapshots;
use super::strategy::{SyncCtx, SyncStrategy};

/// An in-flight fragment synchronization.
#[derive(Debug)]
pub(crate) struct Pending {
    pub frag: usize,
    /// Initiation step t_p.
    pub t_init: u32,
    /// Local step t_l at which the result is applied (t_p + τ).
    pub apply_step: u32,
    /// Virtual time the all-reduce finishes (for stall accounting).
    pub finish_time: f64,
    /// Averaged pseudo-gradient Δθ_p^g (computed at initiation: the data is
    /// fixed once the transfer starts).
    pub delta_avg: Vec<f32>,
    /// Per-worker parameter snapshots θ_{p,t_p}^m (needed by CoCoDC's
    /// delay compensation; None for plain streaming to save memory).
    pub snapshots: Option<Vec<Vec<f32>>>,
}

pub struct StreamingDiloco {
    offsets: Vec<u32>,
    pending: Vec<Pending>,
}

impl StreamingDiloco {
    pub fn new(cfg: &RunConfig, frags: &FragmentTable) -> Self {
        StreamingDiloco {
            offsets: frags.streaming_offsets(cfg.h_steps),
            pending: Vec::new(),
        }
    }

    /// Shared by CoCoDC: start a sync of fragment `p` at step `t`.
    pub(crate) fn initiate(
        p: usize,
        t: u32,
        keep_snapshots: bool,
        ctx: &mut SyncCtx,
    ) -> Pending {
        let frag = ctx.frags.get(p);
        let theta_g = ctx.frags.slice(&ctx.global.theta_g, p);
        let snaps: Vec<Vec<f32>> = ctx
            .workers
            .iter()
            .map(|w| w.params[frag.range()].to_vec())
            .collect();
        let mut delta_avg = mean_pseudo_gradients_from_snapshots(&snaps, theta_g);
        // What the wire would carry: round-trip through the codec and pay
        // for the compressed size (Streaming DiLoCo ships quantized
        // pseudo-gradients; the optimizer sees the dequantized values).
        ctx.cfg.compression.round_trip(&mut delta_avg);
        let wire = ctx.cfg.compression.wire_bytes(frag.size);
        let transfer = ctx.net.schedule_allreduce(ctx.clock.now(), wire);
        ctx.stats.bytes += wire;
        ctx.stats.syncs_initiated += 1;
        let tau = match ctx.cfg.tau {
            TauMode::Fixed { tau } => tau,
            TauMode::Network => ctx.net.tau_steps(
                ctx.clock.now(),
                transfer.finish,
                ctx.cfg.network.step_compute_s,
            ),
        };
        Pending {
            frag: p,
            t_init: t,
            apply_step: t + tau,
            finish_time: transfer.finish,
            delta_avg,
            snapshots: if keep_snapshots { Some(snaps) } else { None },
        }
    }

    /// Complete every pending sync due at `step`: outer step + α-blend.
    fn complete_due(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        let due: Vec<Pending> = {
            let mut rest = Vec::new();
            let mut due = Vec::new();
            for p in self.pending.drain(..) {
                if p.apply_step <= step {
                    due.push(p);
                } else {
                    rest.push(p);
                }
            }
            self.pending = rest;
            due
        };
        for pend in due {
            // If the simulated transfer has not actually finished by now,
            // the apply blocks on it (honest wall-clock accounting).
            if pend.finish_time > ctx.clock.now() {
                ctx.clock.stall_until(pend.finish_time);
                ctx.stats.apply_stalls += 1;
            }
            let p = pend.frag;
            let frag = ctx.frags.get(p);
            ctx.outer_step(p, &pend.delta_avg)?;
            ctx.stats.syncs_completed += 1;
            ctx.stats.per_fragment[p] += 1;
            let new_g = ctx.frags.slice(&ctx.global.theta_g, p).to_vec();
            let alpha = ctx.cfg.alpha;
            for w in ctx.workers.iter_mut() {
                for (x, &g) in w.params[frag.range()].iter_mut().zip(&new_g) {
                    *x = (1.0 - alpha) * *x + alpha * g;
                }
            }
        }
        Ok(())
    }
}

impl SyncStrategy for StreamingDiloco {
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        self.complete_due(step, ctx)?;
        if step == 0 {
            return Ok(());
        }
        let h = ctx.cfg.h_steps;
        for p in 0..ctx.frags.k() {
            if step % h == self.offsets[p]
                && !self.pending.iter().any(|q| q.frag == p)
            {
                let pend = Self::initiate(p, step, false, ctx);
                self.pending.push(pend);
            }
        }
        Ok(())
    }

    fn pending(&self) -> usize {
        self.pending.len()
    }

    fn name(&self) -> &'static str {
        "streaming_diloco"
    }
}
