//! Streaming DiLoCo (Douillard et al. 2025): fragment-wise round-robin
//! synchronization overlapped with continued local training.
//!
//! At its scheduled step t_p, fragment p's pseudo-gradient
//! Δθ_p^m = θ_{p,t_p}^m − θ_p^g is captured and a non-blocking ring
//! all-reduce starts; training continues. τ steps later (fixed, or derived
//! from the WAN simulator) the averaged Δθ_p^g is applied through the outer
//! optimizer and the refreshed global fragment is *blended* into each
//! worker's live parameters with mixing factor α (Eq. 3):
//!
//!   θ_{p,t_l}^m ← (1−α)·θ_{p,t_l}^m + α·θ_{p,t_p}^g
//!
//! This is precisely where staleness (τ-step-old consensus) and
//! inconsistency (only fragment p refreshed) enter — the effects CoCoDC
//! compensates for.
//!
//! Degraded-mode semantics (DESIGN.md §Faults): transfers are driven
//! through the WAN's retry/backoff path; a logical transfer that exhausts
//! its budget leaves its `Pending` in the queue *undelivered* and the data
//! (captured at initiation) is retransmitted at the next post-step — a
//! requeue, not a new sync. While workers are crashed the pseudo-gradient
//! mean renormalizes over survivors and results are applied only to live
//! workers.
//!
//! Hot-path discipline (see DESIGN.md §Hot path): snapshots and the
//! averaged pseudo-gradient live in pooled buffers recycled across syncs,
//! the averaging itself is the fused one-pass-per-worker kernel, the blend
//! is the fused α-kernel over a borrowed θ_g slice (no fragment copy), and
//! due entries drain from the pending queue in place — steady state does
//! zero heap allocations per initiate/complete cycle on the fault-free
//! path (the degraded paths may allocate; they only run during faults).

use crate::checkpoint::{
    checksum_f32, pack_f64s, pack_u64s, unpack_f64s, unpack_u64, unpack_u64s, Checkpoint,
};
use crate::config::RunConfig;
use crate::config::TauMode;
use crate::coordinator::fragments::FragmentTable;
use crate::util::pool::BufferPool;
use crate::util::vecops;

use super::strategy::{SyncCtx, SyncStrategy};

/// An in-flight fragment synchronization. `delta_avg` and `snapshots` are
/// checked out of the [`BufferPool`] at initiation and must be returned
/// via [`Pending::recycle`] on completion.
#[derive(Debug)]
pub(crate) struct Pending {
    pub frag: usize,
    /// Initiation step t_p.
    pub t_init: u32,
    /// Local step t_l at which the result is applied (t_p + τ);
    /// `u32::MAX` while undelivered (timed out, awaiting retransmission).
    pub apply_step: u32,
    /// Virtual time the all-reduce finishes (for stall accounting). For an
    /// undelivered entry: the time the timeout was detected (no
    /// retransmission before then).
    pub finish_time: f64,
    /// Bytes one transmission attempt puts on the wire (retransmissions
    /// re-charge it).
    pub wire_bytes: f64,
    /// False when the retry budget was exhausted: the fragment sits in the
    /// queue awaiting retransmission of the already-captured data.
    pub delivered: bool,
    /// Averaged pseudo-gradient Δθ_p^g (computed at initiation: the data is
    /// fixed once the transfer starts).
    pub delta_avg: Vec<f32>,
    /// Per-worker parameter snapshots θ_{p,t_p}^m (needed by CoCoDC's
    /// delay compensation; None for plain streaming to save memory).
    pub snapshots: Option<Vec<Vec<f32>>>,
    /// Live mask at initiation when some worker was crashed (None = all
    /// workers participated — the fast, allocation-free case).
    pub participants: Option<Vec<bool>>,
    /// FNV checksum of `delta_avg` (post-codec) carried with the payload
    /// over the WAN. The receiver verifies it at arrival and again at apply
    /// time — a mismatching payload is quarantined, never applied.
    pub checksum: u64,
}

impl Pending {
    /// Hand every buffer back to the pool.
    pub(crate) fn recycle(self, pool: &mut BufferPool) {
        pool.put(self.delta_avg);
        if let Some(snaps) = self.snapshots {
            pool.put_shell(snaps);
        }
    }
}

/// Simulate the in-flight bit flip a corruption draw encodes and check it
/// against the carried checksum: flip the seeded bit in `payload`, compare
/// the FNV hash, then restore the original word — the retained sender-side
/// copy stays intact for retransmission. Returns true when the mismatch is
/// detected (always, barring an FNV collision).
pub(crate) fn corrupt_payload_detected(payload: &mut [f32], checksum: u64, draw: u64) -> bool {
    if payload.is_empty() {
        return false;
    }
    let bit = (draw as usize) % (payload.len() * 32);
    let (idx, shift) = (bit / 32, bit % 32);
    let orig = payload[idx];
    payload[idx] = f32::from_bits(orig.to_bits() ^ (1u32 << shift));
    let detected = checksum_f32(payload) != checksum;
    payload[idx] = orig;
    detected
}

/// Receiver-side integrity check at arrival: when the WAN flagged this
/// delivery as corrupted, verify the payload against its checksum and — on
/// mismatch — quarantine the pending (mark undelivered, to be retransmitted
/// by the existing retry path) instead of ever applying it. Returns true
/// when the pending was quarantined.
pub(crate) fn quarantine_if_corrupt(
    pend: &mut Pending,
    draw: Option<u64>,
    detected_at: f64,
    ctx: &mut SyncCtx,
) -> bool {
    let Some(draw) = draw else {
        return false;
    };
    if !corrupt_payload_detected(&mut pend.delta_avg, pend.checksum, draw) {
        return false;
    }
    ctx.stats.corrupt_fragments += 1;
    ctx.stats.quarantined += 1;
    pend.delivered = false;
    pend.apply_step = u32::MAX;
    pend.finish_time = detected_at;
    true
}

/// Serialize the pending queue into `strategy/*` sections so in-flight
/// syncs survive checkpoint/restore (including mid fault window).
pub(crate) fn save_pendings(ck: &mut Checkpoint, pending: &[Pending]) {
    let mut count = Vec::new();
    pack_u64s(&mut count, &[pending.len() as u64]);
    ck.insert("strategy/pending_count", count);
    for (i, p) in pending.iter().enumerate() {
        let mut meta = Vec::new();
        pack_u64s(
            &mut meta,
            &[
                p.frag as u64,
                p.t_init as u64,
                p.apply_step as u64,
                p.delivered as u64,
                p.snapshots.as_ref().map_or(0, |s| s.len() as u64),
                p.participants.as_ref().map_or(0, |l| l.len() as u64),
            ],
        );
        pack_f64s(&mut meta, &[p.finish_time, p.wire_bytes]);
        pack_u64s(&mut meta, &[p.checksum]);
        if let Some(l) = &p.participants {
            meta.extend(l.iter().map(|&b| if b { 1.0f32 } else { 0.0 }));
        }
        ck.insert(&format!("strategy/p{i}/meta"), meta);
        ck.insert(&format!("strategy/p{i}/delta"), p.delta_avg.clone());
        if let Some(snaps) = &p.snapshots {
            for (j, s) in snaps.iter().enumerate() {
                ck.insert(&format!("strategy/p{i}/snap{j}"), s.clone());
            }
        }
    }
}

/// Inverse of [`save_pendings`]; buffers come from `pool`. Returns an
/// empty queue for checkpoints without `strategy/*` sections (older
/// format: in-flight syncs were simply not captured).
pub(crate) fn load_pendings(
    ck: &Checkpoint,
    pool: &mut BufferPool,
) -> anyhow::Result<Vec<Pending>> {
    let Some(cnt) = ck.get("strategy/pending_count") else {
        return Ok(Vec::new());
    };
    anyhow::ensure!(cnt.len() == 2, "strategy/pending_count malformed");
    let n = unpack_u64s(cnt)[0] as usize;
    anyhow::ensure!(n <= 4096, "implausible pending count {n}");
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let need = |name: String| {
            ck.get(&name)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing section {name}"))
        };
        let meta = need(format!("strategy/p{i}/meta"))?;
        anyhow::ensure!(meta.len() >= 16, "strategy/p{i}/meta malformed");
        let u = unpack_u64s(&meta[0..12]);
        let f = unpack_f64s(&meta[12..16]);
        let (n_snap, n_part) = (u[4] as usize, u[5] as usize);
        // Current layout carries the payload checksum at [16..18]; legacy
        // (pre-integrity) checkpoints lack it and we recompute from the
        // delta below. `n_part` disambiguates the two lengths.
        let (checksum, part_off) = if meta.len() == 18 + n_part {
            (Some(unpack_u64(meta[16], meta[17])), 18)
        } else if meta.len() == 16 + n_part {
            (None, 16)
        } else {
            anyhow::bail!("strategy/p{i}/meta malformed");
        };
        let participants = if n_part == 0 {
            None
        } else {
            Some(meta[part_off..].iter().map(|&x| x != 0.0).collect())
        };
        let delta_src = need(format!("strategy/p{i}/delta"))?;
        let mut delta_avg = pool.take(delta_src.len());
        delta_avg.copy_from_slice(delta_src);
        let snapshots = if n_snap == 0 {
            None
        } else {
            let mut shell = pool.take_shell();
            for j in 0..n_snap {
                let src = need(format!("strategy/p{i}/snap{j}"))?;
                let mut buf = pool.take(src.len());
                buf.copy_from_slice(src);
                shell.push(buf);
            }
            Some(shell)
        };
        let checksum = checksum.unwrap_or_else(|| checksum_f32(&delta_avg));
        out.push(Pending {
            frag: u[0] as usize,
            t_init: u[1] as u32,
            apply_step: u[2] as u32,
            finish_time: f[0],
            wire_bytes: f[1],
            delivered: u[3] != 0,
            delta_avg,
            snapshots,
            participants,
            checksum,
        });
    }
    Ok(out)
}

pub struct StreamingDiloco {
    offsets: Vec<u32>,
    pending: Vec<Pending>,
}

impl StreamingDiloco {
    pub fn new(cfg: &RunConfig, frags: &FragmentTable) -> Self {
        StreamingDiloco {
            offsets: frags.streaming_offsets(cfg.h_steps),
            pending: Vec::new(),
        }
    }

    /// Shared by CoCoDC: start a sync of fragment `p` at step `t`. All
    /// buffers come from (and eventually return to) `ctx.pool`. When the
    /// caller needs per-worker snapshots (CoCoDC's delay compensation),
    /// worker fragments are read out of the backend's resident state —
    /// the only parameter data that crosses the runtime boundary per sync;
    /// plain streaming averages backend-side with zero fragment copies.
    ///
    /// The transfer runs through the WAN's retry/backoff path; on budget
    /// exhaustion the returned entry is undelivered (requeued) and will be
    /// retransmitted by [`StreamingDiloco::retransmit`].
    ///
    /// `route` pins the topology-mode inter-region phase to an explicit
    /// cycle of link ids (CoCoDC's adaptive per-link scheduler builds one);
    /// `None` uses the canonical region ring and is a no-op on flat runs.
    pub(crate) fn initiate(
        p: usize,
        t: u32,
        keep_snapshots: bool,
        route: Option<&[usize]>,
        ctx: &mut SyncCtx,
    ) -> anyhow::Result<Pending> {
        let frag = ctx.frags.get(p);
        let mut delta_avg = ctx.pool.take(frag.size);
        let all_live = ctx.all_live();
        let snaps = if keep_snapshots {
            let mut snaps = ctx.pool.take_shell();
            for w in ctx.workers.iter() {
                let mut buf = ctx.pool.take(frag.size);
                ctx.backend.read_fragment(w, frag, &mut buf)?;
                snaps.push(buf);
            }
            let theta_g = ctx.frags.slice(&ctx.global.theta_g, p);
            if all_live {
                // Average from the snapshots (bit-identical to the resident
                // rows they were copied from — same kernel, same order).
                vecops::fused_pseudo_mean(&mut delta_avg, &snaps, theta_g);
            } else {
                // Quorum: the mean renormalizes over surviving workers so a
                // crashed worker's frozen replica never dilutes consensus.
                anyhow::ensure!(ctx.live_count() > 0, "no live workers to average");
                let rows: Vec<&[f32]> = snaps
                    .iter()
                    .enumerate()
                    .filter(|(m, _)| ctx.is_live(*m))
                    .map(|(_, r)| r.as_slice())
                    .collect();
                vecops::fused_pseudo_mean(&mut delta_avg, &rows, theta_g);
            }
            Some(snaps)
        } else {
            ctx.pseudo_mean_live(p, &mut delta_avg)?;
            None
        };
        let participants = if all_live { None } else { ctx.live.map(|l| l.to_vec()) };
        // What the wire would carry: round-trip through the codec and pay
        // for the compressed size (Streaming DiLoCo ships quantized
        // pseudo-gradients; the optimizer sees the dequantized values).
        ctx.cfg.compression.round_trip(&mut delta_avg);
        // Payload checksum travels with the fragment; the receiver verifies
        // it at arrival and the apply path re-verifies before the outer step.
        let checksum = checksum_f32(&delta_avg);
        let wire = ctx.cfg.compression.wire_bytes(frag.size);
        let now = ctx.clock.now();
        let sched = ctx.net.schedule_with_retries_routed(now, wire, route);
        ctx.stats.syncs_initiated += 1;
        ctx.stats.retries += sched.retries() as usize;
        ctx.stats.drops += sched.drops as usize;
        // Lost attempts consumed the wire too.
        ctx.stats.bytes += wire * sched.attempts as f64;
        match sched.transfer {
            Some(transfer) => {
                let tau = match ctx.cfg.tau {
                    TauMode::Fixed { tau } => tau,
                    TauMode::Network => ctx.net.tau_steps(
                        now,
                        transfer.finish,
                        ctx.cfg.network.step_compute_s,
                    ),
                };
                ctx.stats.tau_dist.record(tau as f64);
                ctx.stats.queue_delay_dist.record(transfer.queue_delay());
                let mut pend = Pending {
                    frag: p,
                    t_init: t,
                    apply_step: t.saturating_add(tau),
                    finish_time: transfer.finish,
                    wire_bytes: wire,
                    delivered: true,
                    delta_avg,
                    snapshots: snaps,
                    participants,
                    checksum,
                };
                // Arrival integrity check: a corrupt payload re-enters the
                // queue undelivered and is retransmitted, never applied.
                quarantine_if_corrupt(&mut pend, sched.corruption, transfer.finish, ctx);
                Ok(pend)
            }
            None => {
                // Budget exhausted: keep the captured data queued and
                // retransmit once the failure is detected.
                ctx.stats.timeouts += 1;
                ctx.stats.requeues += 1;
                Ok(Pending {
                    frag: p,
                    t_init: t,
                    apply_step: u32::MAX,
                    finish_time: sched.resolved_at,
                    wire_bytes: wire,
                    delivered: false,
                    delta_avg,
                    snapshots: snaps,
                    participants,
                    checksum,
                })
            }
        }
    }

    /// Retransmit an undelivered (timed-out) pending once its failure is
    /// known on the virtual clock. Returns None when there was nothing to
    /// do, `Some(delivered)` after a retransmission round. The fragment
    /// data is NOT re-captured — the sync semantically belongs to `t_init`
    /// and its staleness keeps growing, which the delay-compensated apply
    /// sees through `apply_step − t_init`.
    pub(crate) fn retransmit(
        pend: &mut Pending,
        step: u32,
        route: Option<&[usize]>,
        ctx: &mut SyncCtx,
    ) -> Option<bool> {
        if pend.delivered || pend.finish_time > ctx.clock.now() {
            return None;
        }
        let now = ctx.clock.now();
        let sched = ctx.net.schedule_with_retries_routed(now, pend.wire_bytes, route);
        // Every attempt here retransmits the original logical transfer.
        ctx.stats.retries += sched.attempts as usize;
        ctx.stats.drops += sched.drops as usize;
        ctx.stats.bytes += pend.wire_bytes * sched.attempts as f64;
        match sched.transfer {
            Some(t) => {
                let tau = match ctx.cfg.tau {
                    TauMode::Fixed { tau } => tau,
                    TauMode::Network => {
                        ctx.net.tau_steps(now, t.finish, ctx.cfg.network.step_compute_s)
                    }
                };
                ctx.stats.tau_dist.record(tau as f64);
                ctx.stats.queue_delay_dist.record(t.queue_delay());
                pend.delivered = true;
                pend.finish_time = t.finish;
                pend.apply_step = step.saturating_add(tau);
                if quarantine_if_corrupt(pend, sched.corruption, t.finish, ctx) {
                    // Corrupted again in flight: back to the queue for the
                    // next retransmission round.
                    return Some(false);
                }
                Some(true)
            }
            None => {
                ctx.stats.timeouts += 1;
                ctx.stats.requeues += 1;
                pend.finish_time = sched.resolved_at;
                Some(false)
            }
        }
    }

    /// Complete every pending sync due at `step`: outer step + α-blend.
    /// Due entries are extracted in place (stable order) — the pending
    /// queue is never rebuilt. Undelivered entries (`apply_step ==
    /// u32::MAX`) are never due.
    fn complete_due(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].apply_step > step {
                i += 1;
                continue;
            }
            // Apply-time re-verification (defense in depth): a payload that
            // no longer matches its checksum is quarantined here too —
            // nothing corrupt ever reaches the outer step.
            if checksum_f32(&self.pending[i].delta_avg) != self.pending[i].checksum {
                let pend = &mut self.pending[i];
                ctx.stats.corrupt_fragments += 1;
                ctx.stats.quarantined += 1;
                pend.delivered = false;
                pend.apply_step = u32::MAX;
                pend.finish_time = ctx.clock.now();
                i += 1;
                continue;
            }
            let pend = self.pending.remove(i);
            // If the simulated transfer has not actually finished by now,
            // the apply blocks on it (honest wall-clock accounting).
            if pend.finish_time > ctx.clock.now() {
                ctx.clock.stall_until(pend.finish_time);
                ctx.stats.apply_stalls += 1;
            }
            let p = pend.frag;
            let frag = ctx.frags.get(p);
            ctx.outer_step(p, &pend.delta_avg)?;
            ctx.stats.syncs_completed += 1;
            ctx.stats.per_fragment[p] += 1;
            let alpha = ctx.cfg.alpha;
            let live = ctx.live;
            {
                // θ_g and worker handles are disjoint SyncCtx fields: the
                // backend blends its resident fragment straight from the
                // borrowed global slice, no fragment copy. Workers crashed
                // *right now* are skipped — they adopt the full global
                // fragment state when they rejoin.
                let new_g = &ctx.global.theta_g[frag.range()];
                for (m, w) in ctx.workers.iter_mut().enumerate() {
                    if live.map_or(true, |l| l[m]) {
                        ctx.backend.alpha_blend_fragment(w, frag, new_g, alpha)?;
                    }
                }
            }
            pend.recycle(ctx.pool);
        }
        Ok(())
    }
}

impl SyncStrategy for StreamingDiloco {
    fn post_step(&mut self, step: u32, ctx: &mut SyncCtx) -> anyhow::Result<()> {
        // Requeued fragments first: retransmission precedes new initiations
        // so a stale fragment cannot starve behind fresh traffic.
        for pend in self.pending.iter_mut() {
            let _ = Self::retransmit(pend, step, None, ctx);
        }
        self.complete_due(step, ctx)?;
        if step == 0 {
            return Ok(());
        }
        let h = ctx.cfg.h_steps;
        for p in 0..ctx.frags.k() {
            if step % h == self.offsets[p]
                && !self.pending.iter().any(|q| q.frag == p)
            {
                let pend = Self::initiate(p, step, false, None, ctx)?;
                self.pending.push(pend);
            }
        }
        Ok(())
    }

    fn pending(&self) -> usize {
        self.pending.len()
    }

    fn name(&self) -> &'static str {
        "streaming_diloco"
    }

    fn save_state(&self, ck: &mut Checkpoint) {
        save_pendings(ck, &self.pending);
    }

    fn load_state(&mut self, ck: &Checkpoint, pool: &mut BufferPool) -> anyhow::Result<()> {
        for p in std::mem::take(&mut self.pending) {
            p.recycle(pool);
        }
        self.pending = load_pendings(ck, pool)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corrupt_payload_detection_flips_checks_and_restores() {
        let mut payload = vec![1.0f32, -2.5, 3.25, 0.0];
        let original = payload.clone();
        let checksum = checksum_f32(&payload);
        for draw in [0u64, 1, 31, 32, 127, u64::MAX, 0xDEAD_BEEF] {
            assert!(
                corrupt_payload_detected(&mut payload, checksum, draw),
                "single-bit flip (draw {draw}) must mismatch the checksum"
            );
            assert_eq!(payload, original, "sender-side copy must be restored");
        }
        // An empty payload has no bit to flip.
        assert!(!corrupt_payload_detected(&mut [], checksum_f32(&[]), 7));
    }
}
