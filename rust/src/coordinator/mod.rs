//! The paper's L3 contribution: fragment-wise cross-region synchronization.
//!
//! * [`fragments`] — strided depth partition of the flat parameter vector.
//! * [`allreduce`] — pseudo-gradient averaging across simulated DCs.
//! * [`outer_opt`] — Nesterov outer optimizer (Eq. 2).
//! * [`delay_comp`] — Taylor delay compensation (Alg. 1, Eqs. 4/7/8).
//! * [`strategy`] — the `SyncStrategy` trait + shared sync context.
//! * [`diloco`] / [`streaming`] / [`cocodc`] — the three methods compared in
//!   the paper's evaluation (Figs. 1-2, Table I).

pub mod allreduce;
pub mod cocodc;
pub mod delay_comp;
pub mod diloco;
pub mod fragments;
pub mod outer_opt;
pub mod streaming;
pub mod strategy;

pub use cocodc::Cocodc;
pub use diloco::Diloco;
pub use fragments::FragmentTable;
pub use strategy::{GlobalState, SyncStats, SyncStrategy, make_strategy};
pub use streaming::StreamingDiloco;
