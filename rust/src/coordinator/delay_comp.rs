//! CoCoDC's Taylor-expansion delay compensation (paper Alg. 1).
//!
//! When the all-reduce for fragment p completes at local step t_l, the
//! received consensus reflects step t_p = t_l − τ. Instead of blending the
//! stale global state (Streaming DiLoCo, Eq. 3), CoCoDC extrapolates it to
//! the current step:
//!
//!   g      = (θ_tl − θ_tp) / τ                        (Eq. 4, local rate)
//!   g_corr = g + λ · g⊙g ⊙ (θ_g − θ_tp) / H           (Eq. 7, Hessian term
//!            approximated by the gradient outer product / Fisher diagonal)
//!   θ'     = θ_g + g_corr · τ                          (Eq. 8)
//!
//! Sign convention: the paper's Eq. 4 writes g = (θ_tp − θ_tl)/τ yet applies
//! θ_g + g·τ in Eq. 8, which would extrapolate *backwards* along the local
//! trajectory; we implement the internally consistent forward reading
//! (DESIGN.md §"Delay compensation"). With λ=0 the update reduces to
//! "adopt the new global state plus the local progress made during overlap";
//! with τ→0 it reduces to plain adoption of θ_g.
//!
//! The Pallas/HLO twin (`Engine::delay_comp_hlo`) implements the identical
//! math; integration tests assert agreement to f32 rounding.

use crate::util::vecops;

/// Compensated target state, written into `out` (Alg. 1 line 3 output).
/// Thin wrapper over the unrolled [`vecops::fused_delay_comp_into`] kernel
/// (bit-identical to the historical scalar loop, preserved as
/// `vecops::reference::delay_compensate`).
pub fn delay_compensate(
    out: &mut [f32],
    theta_g: &[f32],
    theta_tl: &[f32],
    theta_tp: &[f32],
    tau: f32,
    h: f32,
    lambda: f32,
) {
    debug_assert_eq!(out.len(), theta_g.len());
    debug_assert_eq!(out.len(), theta_tl.len());
    debug_assert_eq!(out.len(), theta_tp.len());
    debug_assert!(tau > 0.0 && h > 0.0);
    vecops::fused_delay_comp_into(out, theta_g, theta_tl, theta_tp, tau, h, lambda);
}

/// Convenience: apply in place on a worker's fragment slice.
pub fn delay_compensate_inplace(
    theta_local: &mut [f32],
    theta_g: &[f32],
    theta_tp: &[f32],
    tau: f32,
    h: f32,
    lambda: f32,
) {
    vecops::fused_delay_comp(theta_local, theta_g, theta_tp, tau, h, lambda);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed, 0);
        (0..n).map(|_| r.next_gaussian() as f32 * 0.1).collect()
    }

    #[test]
    fn lambda_zero_is_linear_extrapolation() {
        let (g, tl, tp) = (randv(64, 1), randv(64, 2), randv(64, 3));
        let mut out = vec![0.0; 64];
        delay_compensate(&mut out, &g, &tl, &tp, 5.0, 100.0, 0.0);
        for i in 0..64 {
            let want = g[i] + (tl[i] - tp[i]);
            assert!((out[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn no_local_movement_adopts_global() {
        let g = randv(32, 4);
        let tl = randv(32, 5);
        let mut out = vec![0.0; 32];
        delay_compensate(&mut out, &g, &tl, &tl, 5.0, 100.0, 0.5);
        assert_eq!(out, g);
    }

    #[test]
    fn correction_pulls_toward_global_divergence() {
        // One coordinate, local rate g=1, global ahead of snapshot by d:
        // out = theta_g + tau*(g + lam*g^2*d/H).
        let theta_g = [2.0f32];
        let theta_tp = [0.0f32];
        let theta_tl = [5.0f32]; // g = 1.0 over tau=5
        let mut out = [0.0f32];
        delay_compensate(&mut out, &theta_g, &theta_tl, &theta_tp, 5.0, 100.0, 0.5);
        let g = 1.0f32;
        let want = 2.0 + 5.0 * (g + 0.5 * g * g * (2.0 - 0.0) / 100.0);
        assert!((out[0] - want).abs() < 1e-6, "{} vs {want}", out[0]);
    }

    #[test]
    fn inplace_matches_out_of_place() {
        let (g, tl, tp) = (randv(128, 7), randv(128, 8), randv(128, 9));
        let mut out = vec![0.0; 128];
        delay_compensate(&mut out, &g, &tl, &tp, 3.0, 50.0, 0.7);
        let mut local = tl.clone();
        delay_compensate_inplace(&mut local, &g, &tp, 3.0, 50.0, 0.7);
        assert_eq!(out, local);
    }

    #[test]
    fn reduces_to_simple_cases_from_paper() {
        // tau=1, H=1 is the classic DC-ASGD single-step compensation regime
        // (paper §III-A: "prior methods ... specialized cases").
        let (g, tl, tp) = (randv(16, 10), randv(16, 11), randv(16, 12));
        let mut out = vec![0.0; 16];
        delay_compensate(&mut out, &g, &tl, &tp, 1.0, 1.0, 1.0);
        for i in 0..16 {
            let gr = tl[i] - tp[i];
            let want = g[i] + gr + gr * gr * (g[i] - tp[i]);
            assert!((out[i] - want).abs() < 1e-5);
        }
    }
}
