//! Pseudo-gradient all-reduce across the simulated datacenters.
//!
//! Data path: the strategies call [`mean_pseudo_gradients`] — the exact
//! element-wise mean the ring all-reduce converges to (equivalence proven
//! against `network::ring::ring_allreduce_mean` in tests). Timing: the
//! strategies separately charge the WAN simulator for the transfer, so the
//! data path stays fast while the clock stays honest.

use crate::coordinator::fragments::Fragment;
use crate::runtime::TrainState;
use crate::util::vecops;

/// Δθ^g = mean_m(θ_p^m − θ_p^g) over one fragment (paper Eq. 1), written
/// into a caller-provided (typically pooled) buffer — the zero-allocation
/// hot-path entry. One fused memory pass per worker row
/// ([`vecops::fused_pseudo_mean_iter`]): the mean is accumulated as
/// `(Σ_m θ_m)·M⁻¹ − θ_g`, a ≤ 1-ulp-per-op reassociation of the historical
/// per-worker subtraction order (see DESIGN.md §Hot path).
pub fn mean_pseudo_gradients_into(
    out: &mut [f32],
    workers: &[TrainState],
    frag: Fragment,
    theta_g: &[f32],
) {
    assert!(!workers.is_empty());
    assert_eq!(theta_g.len(), frag.size);
    assert_eq!(out.len(), frag.size);
    vecops::fused_pseudo_mean_iter(
        out,
        workers.iter().map(|w| &w.params[frag.range()]),
        theta_g,
    );
}

/// Allocating convenience wrapper around [`mean_pseudo_gradients_into`].
pub fn mean_pseudo_gradients(
    workers: &[TrainState],
    frag: Fragment,
    theta_g: &[f32],
) -> Vec<f32> {
    let mut acc = vec![0.0f32; frag.size];
    mean_pseudo_gradients_into(&mut acc, workers, frag, theta_g);
    acc
}

/// Same, but from explicit per-worker snapshots (used when the pseudo-
/// gradient must be computed from parameters captured at initiation time
/// t_p, not the live parameters at completion time t_l).
pub fn mean_pseudo_gradients_from_snapshots(
    snapshots: &[Vec<f32>],
    theta_g: &[f32],
) -> Vec<f32> {
    assert!(!snapshots.is_empty());
    let n = theta_g.len();
    for snap in snapshots {
        assert_eq!(snap.len(), n);
    }
    let mut acc = vec![0.0f32; n];
    vecops::fused_pseudo_mean(&mut acc, snapshots, theta_g);
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::ring::ring_allreduce_mean;
    use crate::util::Rng;

    fn mk_workers(m: usize, n: usize, seed: u64) -> Vec<TrainState> {
        let mut rng = Rng::new(seed, 0);
        (0..m)
            .map(|_| {
                TrainState::new((0..n).map(|_| rng.next_gaussian() as f32).collect())
            })
            .collect()
    }

    #[test]
    fn matches_ring_allreduce_of_deltas() {
        let m = 4;
        let frag = Fragment { index: 0, offset: 2, size: 6 };
        let workers = mk_workers(m, 10, 3);
        let theta_g: Vec<f32> = vec![0.5; 6];
        let mean = mean_pseudo_gradients(&workers, frag, &theta_g);

        let mut bufs: Vec<Vec<f32>> = workers
            .iter()
            .map(|w| {
                w.params[frag.range()]
                    .iter()
                    .zip(&theta_g)
                    .map(|(&l, &g)| l - g)
                    .collect()
            })
            .collect();
        ring_allreduce_mean(&mut bufs);
        for b in &bufs {
            for (x, y) in b.iter().zip(&mean) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn snapshot_variant_agrees_with_live_when_unchanged() {
        let frag = Fragment { index: 0, offset: 0, size: 8 };
        let workers = mk_workers(3, 8, 7);
        let theta_g = vec![0.0f32; 8];
        let live = mean_pseudo_gradients(&workers, frag, &theta_g);
        let snaps: Vec<Vec<f32>> =
            workers.iter().map(|w| w.params[frag.range()].to_vec()).collect();
        let snap = mean_pseudo_gradients_from_snapshots(&snaps, &theta_g);
        assert_eq!(live, snap);
    }

    #[test]
    fn permutation_invariant() {
        let frag = Fragment { index: 0, offset: 0, size: 5 };
        let mut workers = mk_workers(4, 5, 9);
        let theta_g = vec![0.1f32; 5];
        let a = mean_pseudo_gradients(&workers, frag, &theta_g);
        workers.reverse();
        let b = mean_pseudo_gradients(&workers, frag, &theta_g);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn identical_workers_give_exact_delta() {
        let frag = Fragment { index: 0, offset: 0, size: 4 };
        let w = TrainState::new(vec![1.0, 2.0, 3.0, 4.0]);
        let workers = vec![w.clone(), w.clone(), w];
        let theta_g = vec![1.0f32; 4];
        let d = mean_pseudo_gradients(&workers, frag, &theta_g);
        assert_eq!(d, vec![0.0, 1.0, 2.0, 3.0]);
    }
}
