//! Batch iterators over [`Corpus`] streams: packs documents into (tokens,
//! targets) pairs shaped `[batch, seq_len]` with next-token targets, exactly
//! the `s32[B,T]` inputs of the train_step/eval_step artifacts.

use super::{Corpus, Split};
use crate::config::DataConfig;

/// One training batch (row-major `[batch, seq]`).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    /// An empty batch shell for [`BatchStream::next_batch_into`].
    pub fn empty(batch: usize, seq: usize) -> Batch {
        Batch { tokens: Vec::new(), targets: Vec::new(), batch, seq }
    }
}

/// Infinite deterministic batch stream.
#[derive(Debug, Clone)]
pub struct BatchStream {
    corpus: Corpus,
    batch: usize,
    seq: usize,
    /// Reusable document buffer (seq+1 tokens) — the batch hot path does
    /// zero heap allocations in steady state.
    doc: Vec<i32>,
}

impl BatchStream {
    pub fn new(vocab: usize, cfg: DataConfig, seed: u64, split: Split,
               batch: usize, seq: usize) -> Self {
        BatchStream {
            corpus: Corpus::new(vocab, cfg, seed, split),
            batch,
            seq,
            doc: Vec::new(),
        }
    }

    /// Produce the next batch. Targets are the next-token shift; each row is
    /// one generated document of seq+1 tokens.
    pub fn next_batch(&mut self) -> Batch {
        let mut b = Batch::empty(self.batch, self.seq);
        self.next_batch_into(&mut b);
        b
    }

    /// Refill `out` with the next batch, reusing its buffers (and the
    /// stream's document buffer): zero steady-state allocations per round.
    pub fn next_batch_into(&mut self, out: &mut Batch) {
        let (b, t) = (self.batch, self.seq);
        out.batch = b;
        out.seq = t;
        out.tokens.clear();
        out.targets.clear();
        out.tokens.reserve(b * t);
        out.targets.reserve(b * t);
        for _ in 0..b {
            self.corpus.sequence_into(t + 1, &mut self.doc);
            out.tokens.extend_from_slice(&self.doc[..t]);
            out.targets.extend_from_slice(&self.doc[1..]);
        }
    }

    /// Materialize `n` batches up front (used for the fixed validation set).
    pub fn take_batches(&mut self, n: usize) -> Vec<Batch> {
        (0..n).map(|_| self.next_batch()).collect()
    }

    /// Checkpointable stream position (see [`Corpus::cursor`]).
    pub fn cursor(&self) -> [u64; 4] {
        self.corpus.cursor()
    }

    pub fn set_cursor(&mut self, cursor: [u64; 4]) {
        self.corpus.set_cursor(cursor);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(worker: usize) -> BatchStream {
        BatchStream::new(
            128,
            DataConfig::default(),
            9,
            Split::Train { worker, workers: 4 },
            4,
            16,
        )
    }

    #[test]
    fn shapes_and_shift_property() {
        let mut s = stream(0);
        let b = s.next_batch();
        assert_eq!(b.tokens.len(), 4 * 16);
        assert_eq!(b.targets.len(), 4 * 16);
        // target[i] == token[i+1] within each row
        for row in 0..4 {
            for i in 0..15 {
                assert_eq!(b.targets[row * 16 + i], b.tokens[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn stream_is_deterministic_but_advances() {
        let b1 = stream(2).next_batch();
        let b2 = stream(2).next_batch();
        assert_eq!(b1, b2);
        let mut s = stream(2);
        let x = s.next_batch();
        let y = s.next_batch();
        assert_ne!(x, y);
    }

    #[test]
    fn validation_differs_from_training() {
        let mut v = BatchStream::new(128, DataConfig::default(), 9,
                                     Split::Validation, 4, 16);
        let b_train = stream(0).next_batch();
        let b_val = v.next_batch();
        assert_ne!(b_train, b_val);
    }

    #[test]
    fn take_batches_counts() {
        let mut v = BatchStream::new(64, DataConfig::default(), 1,
                                     Split::Validation, 2, 8);
        assert_eq!(v.take_batches(5).len(), 5);
    }

    #[test]
    fn next_batch_into_matches_next_batch() {
        let mut a = stream(1);
        let mut b = stream(1);
        let mut reused = Batch::empty(4, 16);
        for _ in 0..5 {
            b.next_batch_into(&mut reused);
            assert_eq!(a.next_batch(), reused);
        }
    }

    #[test]
    fn cursor_round_trip_resumes_stream() {
        let mut s = stream(3);
        s.next_batch();
        let cur = s.cursor();
        let want = s.next_batch();
        s.set_cursor(cur);
        assert_eq!(s.next_batch(), want);
    }
}
