//! Batch iterators over [`Corpus`] streams: packs documents into (tokens,
//! targets) pairs shaped `[batch, seq_len]` with next-token targets, exactly
//! the `s32[B,T]` inputs of the train_step/eval_step artifacts.

use super::{Corpus, Split};
use crate::config::DataConfig;

/// One training batch (row-major `[batch, seq]`).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Infinite deterministic batch stream.
#[derive(Debug, Clone)]
pub struct BatchStream {
    corpus: Corpus,
    batch: usize,
    seq: usize,
}

impl BatchStream {
    pub fn new(vocab: usize, cfg: DataConfig, seed: u64, split: Split,
               batch: usize, seq: usize) -> Self {
        BatchStream { corpus: Corpus::new(vocab, cfg, seed, split), batch, seq }
    }

    /// Produce the next batch. Targets are the next-token shift; each row is
    /// one generated document of seq+1 tokens.
    pub fn next_batch(&mut self) -> Batch {
        let (b, t) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * t);
        let mut targets = Vec::with_capacity(b * t);
        for _ in 0..b {
            let doc = self.corpus.sequence(t + 1);
            tokens.extend_from_slice(&doc[..t]);
            targets.extend_from_slice(&doc[1..]);
        }
        Batch { tokens, targets, batch: b, seq: t }
    }

    /// Materialize `n` batches up front (used for the fixed validation set).
    pub fn take_batches(&mut self, n: usize) -> Vec<Batch> {
        (0..n).map(|_| self.next_batch()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(worker: usize) -> BatchStream {
        BatchStream::new(
            128,
            DataConfig::default(),
            9,
            Split::Train { worker, workers: 4 },
            4,
            16,
        )
    }

    #[test]
    fn shapes_and_shift_property() {
        let mut s = stream(0);
        let b = s.next_batch();
        assert_eq!(b.tokens.len(), 4 * 16);
        assert_eq!(b.targets.len(), 4 * 16);
        // target[i] == token[i+1] within each row
        for row in 0..4 {
            for i in 0..15 {
                assert_eq!(b.targets[row * 16 + i], b.tokens[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn stream_is_deterministic_but_advances() {
        let b1 = stream(2).next_batch();
        let b2 = stream(2).next_batch();
        assert_eq!(b1, b2);
        let mut s = stream(2);
        let x = s.next_batch();
        let y = s.next_batch();
        assert_ne!(x, y);
    }

    #[test]
    fn validation_differs_from_training() {
        let mut v = BatchStream::new(128, DataConfig::default(), 9,
                                     Split::Validation, 4, 16);
        let b_train = stream(0).next_batch();
        let b_val = v.next_batch();
        assert_ne!(b_train, b_val);
    }

    #[test]
    fn take_batches_counts() {
        let mut v = BatchStream::new(64, DataConfig::default(), 1,
                                     Split::Validation, 2, 8);
        assert_eq!(v.take_batches(5).len(), 5);
    }
}
