//! Synthetic-C4: a deterministic, learnable language-modeling corpus.
//!
//! The paper trains on the English C4 split, which we cannot ship; DESIGN.md
//! §2 substitutes a generated corpus that preserves what the algorithms
//! actually interact with: (a) a smoothly learnable next-token structure so
//! validation PPL decays like a real LM curve, and (b) **non-IID shards**
//! across datacenters (the paper's federated setting) so that local models
//! genuinely diverge between synchronizations — the source of the staleness/
//! inconsistency effects CoCoDC targets.
//!
//! Generative process per sequence:
//!   topic z ~ worker-specific mixture (heterogeneity-controlled);
//!   t_0 ~ Zipf(s);  t_{i+1} = pattern_z(t_i) w.p. `pattern_prob`,
//!   else ~ Zipf(s), where pattern_z is a topic-specific affine map over the
//!   vocabulary. The entropy floor is controlled by `pattern_prob`.

pub mod batches;

use crate::config::DataConfig;
use crate::util::Rng;

/// Token sequence generator for one (worker, split) stream.
#[derive(Debug, Clone)]
pub struct Corpus {
    vocab: usize,
    cfg: DataConfig,
    /// Topic mixture weights for this stream.
    mixture: Vec<f64>,
    /// Per-topic affine successor parameters (a, b): next = (a*t + b) % V.
    patterns: Vec<(u64, u64)>,
    /// Zipf CDF over the vocabulary.
    zipf_cdf: Vec<f64>,
    rng: Rng,
}

/// Which stream a corpus draws from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    /// Training shard of worker m (non-IID topic mixture).
    Train { worker: usize, workers: usize },
    /// Held-out validation stream: uniform topic mixture, disjoint RNG.
    Validation,
}

impl Corpus {
    pub fn new(vocab: usize, cfg: DataConfig, seed: u64, split: Split) -> Self {
        assert!(vocab >= 4);
        assert!(cfg.n_topics >= 1);
        // Patterns and Zipf table depend only on (seed, vocab): all workers
        // and the validation split share the same underlying language.
        let mut lang_rng = Rng::new(seed, 0x1A46);
        let patterns: Vec<(u64, u64)> = (0..cfg.n_topics)
            .map(|_| {
                // Odd multiplier => bijective affine map over Z_V for even V,
                // and well-spread regardless.
                let a = 2 * lang_rng.below(vocab as u64 / 2).max(1) + 1;
                let b = lang_rng.below(vocab as u64);
                (a, b)
            })
            .collect();
        let zipf_cdf = zipf_cdf(vocab, cfg.zipf_exponent);

        let (mixture, stream) = match split {
            Split::Train { worker, workers } => {
                (worker_mixture(&cfg, worker, workers), 2 + worker as u64)
            }
            Split::Validation => {
                (vec![1.0 / cfg.n_topics as f64; cfg.n_topics], 1)
            }
        };
        Corpus {
            vocab,
            cfg,
            mixture,
            patterns,
            zipf_cdf,
            rng: Rng::new(seed, 0xDA7A_0000 + stream),
        }
    }

    fn zipf(&mut self) -> i32 {
        let u = self.rng.next_f64();
        // Binary search the CDF.
        let mut lo = 0usize;
        let mut hi = self.vocab - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.zipf_cdf[mid] < u {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as i32
    }

    /// Generate the next sequence of `len` tokens (one document).
    pub fn sequence(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        self.sequence_into(len, &mut out);
        out
    }

    /// Allocation-free variant of [`Corpus::sequence`]: clears and refills
    /// `out` (capacity is retained across calls — the batch hot path).
    pub fn sequence_into(&mut self, len: usize, out: &mut Vec<i32>) {
        out.clear();
        let z = self.rng.weighted(&self.mixture);
        let (a, b) = self.patterns[z];
        let mut cur = self.zipf();
        out.push(cur);
        for _ in 1..len {
            cur = if self.rng.next_f64() < self.cfg.pattern_prob {
                ((a.wrapping_mul(cur as u64).wrapping_add(b)) % self.vocab as u64) as i32
            } else {
                self.zipf()
            };
            out.push(cur);
        }
    }

    /// Stream position (the generator state) — lets checkpoints resume the
    /// data stream exactly where it left off.
    pub fn cursor(&self) -> [u64; 4] {
        self.rng.state()
    }

    pub fn set_cursor(&mut self, cursor: [u64; 4]) {
        self.rng = Rng::from_state(cursor);
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn mixture(&self) -> &[f64] {
        &self.mixture
    }
}

/// Zipf CDF over ranks 0..v with exponent s.
fn zipf_cdf(v: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=v).map(|r| (r as f64).powf(-s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w[v - 1] = 1.0;
    w
}

/// Worker m's topic mixture: home topics are {z : z % workers == m};
/// heterogeneity h interpolates between uniform (0) and home-only (1).
fn worker_mixture(cfg: &DataConfig, worker: usize, workers: usize) -> Vec<f64> {
    let t = cfg.n_topics;
    let home: Vec<usize> = (0..t).filter(|z| z % workers == worker).collect();
    let h = cfg.heterogeneity;
    let mut w = vec![(1.0 - h) / t as f64; t];
    if home.is_empty() {
        return vec![1.0 / t as f64; t];
    }
    for z in home.iter() {
        w[*z] += h / home.len() as f64;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig::default()
    }

    #[test]
    fn deterministic_per_seed_and_worker() {
        let split = Split::Train { worker: 1, workers: 4 };
        let mut a = Corpus::new(256, cfg(), 5, split);
        let mut b = Corpus::new(256, cfg(), 5, split);
        assert_eq!(a.sequence(64), b.sequence(64));
        let mut c = Corpus::new(256, cfg(), 6, split);
        assert_ne!(a.sequence(64), c.sequence(64));
    }

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(100, cfg(), 0, Split::Validation);
        for tok in c.sequence(1000) {
            assert!((0..100).contains(&tok));
        }
    }

    #[test]
    fn workers_get_distinct_streams() {
        let mut w0 = Corpus::new(256, cfg(), 5, Split::Train { worker: 0, workers: 4 });
        let mut w1 = Corpus::new(256, cfg(), 5, Split::Train { worker: 1, workers: 4 });
        assert_ne!(w0.sequence(128), w1.sequence(128));
    }

    #[test]
    fn mixtures_are_normalized_and_heterogeneous() {
        for m in 0..4 {
            let w = worker_mixture(&cfg(), m, 4);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            // Home topics (z % 4 == m) carry more mass than foreign ones.
            let home_w = w[m];
            let foreign_w = w[(m + 1) % 4];
            assert!(home_w > 2.0 * foreign_w, "{w:?}");
        }
    }

    #[test]
    fn heterogeneity_zero_is_iid() {
        let mut c = cfg();
        c.heterogeneity = 0.0;
        let w0 = worker_mixture(&c, 0, 4);
        let w1 = worker_mixture(&c, 1, 4);
        assert_eq!(w0, w1);
    }

    #[test]
    fn pattern_structure_is_learnable() {
        // With pattern_prob=1 and a single topic the chain is deterministic
        // after the first token.
        let mut c = cfg();
        c.pattern_prob = 1.0;
        c.n_topics = 1;
        let mut corpus = Corpus::new(64, c, 3, Split::Validation);
        let s = corpus.sequence(32);
        let (a, b) = corpus.patterns[0];
        for w in s.windows(2) {
            let want = ((a.wrapping_mul(w[0] as u64).wrapping_add(b)) % 64) as i32;
            assert_eq!(w[1], want);
        }
    }

    #[test]
    fn zipf_cdf_monotone_and_complete() {
        let cdf = zipf_cdf(50, 1.1);
        assert!(cdf.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(*cdf.last().unwrap(), 1.0);
        assert!(cdf[0] > 1.0 / 50.0); // rank 1 above uniform
    }
}
