//! `experiments` — regenerates every table and figure in the paper's
//! evaluation (DESIGN.md §4 index):
//!
//! ```text
//! experiments fig1            # Fig. 1/2 curves (loss & PPL vs steps) + CSV
//! experiments table1          # Table I: final loss/PPL + steps-to-PPL
//! experiments wallclock       # §IV-B wall-clock comparison (WAN-accounted)
//! experiments ablate-lambda   # compensation strength sweep
//! experiments ablate-gamma    # network-utilization sweep
//! experiments ablate-tau      # overlap-depth robustness sweep
//! experiments faults          # degraded-WAN resilience sweep (severity
//!                             # curve: outage+loss+crash vs all 3 methods)
//! experiments recovery        # in-flight corruption sweep with the
//!                             # snapshot ring + divergence sentinel armed
//! experiments topology        # flat vs hierarchical two-level sync at
//!                             # matched WAN budgets (per-link timelines)
//! experiments all             # everything above
//! ```
//!
//! Flags: --artifacts DIR --outdir DIR --preset NAME --steps N --seed N
//!        --ppl X --eval-every N --backend {auto|pjrt|native}
//!        --severity S[,S...]  (faults only; default 0.0,0.3,0.6)
//!        --corruption P[,P...]  (recovery only; default 0.0,0.3,0.7)
//!        --net-preset P  (flat|us-eu|global-4: matched network + topology
//!                        for every experiment; conflicts with --latency /
//!                        --bandwidth raw overrides)
//!        --latency S --bandwidth BPS  (raw flat-link overrides)
//!        --topo-presets P[,P...]  (topology only; default us-eu,global-4)
//!
//! With `--backend native` (or auto and no artifacts present) every
//! experiment runs the pure-rust transformer backend — the full evaluation
//! regenerates offline on any machine.
//!
//! All outputs land in `results/` as long-format CSVs plus a printed
//! summary; EXPERIMENTS.md records the paper-vs-measured comparison.

use std::path::PathBuf;

use cocodc::config::{
    net_preset, Corruption, FaultConfig, FaultWindow, MethodKind, NetworkConfig, RunConfig,
    TauMode, TopologyConfig,
};
use cocodc::metrics::{max_loss_gap, table1, write_curves_csv, Curve};
use cocodc::runtime::{load_backend, Backend, BackendKind};
use cocodc::util::cli::Args;
use cocodc::{TrainOutcome, Trainer};

struct Cli {
    exp: String,
    outdir: PathBuf,
    preset: String,
    steps: u32,
    seed: u64,
    ppl: f64,
    eval_every: u32,
    /// Thread budget for the shared worker/compute pool (0 = auto,
    /// 1 = fully serial; bit-identical results for every value).
    threads: usize,
    severities: Vec<f64>,
    corruptions: Vec<f64>,
    /// `--net-preset` expansion applied to every experiment's base config.
    net: Option<(NetworkConfig, TopologyConfig)>,
    /// Raw flat-link overrides (mutually exclusive with `net`).
    latency: Option<f64>,
    bandwidth: Option<f64>,
    /// Multi-region presets the `topology` sweep compares.
    topo_presets: Vec<String>,
}

fn base_cfg(cli: &Cli, method: MethodKind) -> RunConfig {
    let mut cfg = RunConfig::paper(&cli.preset, method);
    cfg.total_steps = cli.steps;
    cfg.seed = cli.seed;
    cfg.eval_every = cli.eval_every;
    cfg.threads = cli.threads;
    if cli.threads == 1 {
        cfg.parallel_workers = false;
    }
    if let Some((net, topo)) = &cli.net {
        let step = cfg.network.step_compute_s;
        cfg.network = *net;
        cfg.network.step_compute_s = step;
        cfg.topology = topo.clone();
    }
    if let Some(v) = cli.latency {
        cfg.network.latency_s = v;
    }
    if let Some(v) = cli.bandwidth {
        cfg.network.bandwidth_bps = v;
    }
    cfg
}

fn run(backend: &dyn Backend, cfg: RunConfig, tag: &str) -> anyhow::Result<TrainOutcome> {
    let mut tr = Trainer::new(backend, cfg)?;
    tr.verbose = true;
    let mut out = tr.run()?;
    out.curve.method = tag.to_string();
    eprintln!(
        "  -> {tag}: final ppl {:.3}, wall {:.0}s, syncs {}",
        out.curve.final_ppl().unwrap_or(f64::NAN),
        out.wall_s,
        out.syncs_completed
    );
    Ok(out)
}

/// FIG1 + FIG2 + TAB1 share one three-method run.
fn fig1(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<Vec<Curve>> {
    println!("== FIG1/FIG2/TAB1: validation loss & perplexity vs steps ==");
    let mut curves = Vec::new();
    let mut outcomes = Vec::new();
    for method in MethodKind::all() {
        let out = run(backend, base_cfg(cli, method), method.name())?;
        curves.push(out.curve.clone());
        outcomes.push(out);
    }
    write_curves_csv(cli.outdir.join("fig1_loss.csv"), &curves)?;
    println!("curves -> {}", cli.outdir.join("fig1_loss.csv").display());
    println!("\nTable I reproduction (threshold PPL<={}):", cli.ppl);
    println!("{}", table1(&curves, cli.ppl));
    // Relative convergence-speed claims (paper: CoCoDC -21.0% vs Streaming,
    // -4.9% vs DiLoCo).
    let steps = |name: &str| {
        curves
            .iter()
            .find(|c| c.method == name)
            .and_then(|c| c.steps_to_ppl(cli.ppl))
    };
    if let (Some(s_str), Some(s_dil), Some(s_ccd)) =
        (steps("streaming_diloco"), steps("diloco"), steps("cocodc"))
    {
        println!(
            "steps-to-PPL reduction: cocodc vs streaming: {:+.1}%  | cocodc vs diloco: {:+.1}%",
            100.0 * (s_ccd - s_str) / s_str,
            100.0 * (s_ccd - s_dil) / s_dil,
        );
    }
    let mut table =
        String::from("method,final_loss,final_ppl,steps_to_ppl,wall_to_ppl_s,syncs,bytes_mb\n");
    for (c, o) in curves.iter().zip(&outcomes) {
        table.push_str(&format!(
            "{},{:.4},{:.4},{},{},{},{:.1}\n",
            c.method,
            c.final_loss().unwrap_or(f64::NAN),
            c.final_ppl().unwrap_or(f64::NAN),
            c.steps_to_ppl(cli.ppl).map(|s| format!("{s:.0}")).unwrap_or_default(),
            c.wall_to_ppl(cli.ppl).map(|s| format!("{s:.0}")).unwrap_or_default(),
            o.syncs_completed,
            o.bytes_sent / 1e6,
        ));
    }
    std::fs::create_dir_all(&cli.outdir)?;
    std::fs::write(cli.outdir.join("table1.csv"), table)?;
    Ok(curves)
}

/// WALL: wall-clock (WAN-accounted) comparison with τ derived from the
/// network instead of fixed — DiLoCo pays the blocking sync.
fn wallclock(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== WALL: virtual wall-clock to target PPL (tau from WAN) ==");
    let mut curves = Vec::new();
    for method in MethodKind::all() {
        let mut cfg = base_cfg(cli, method);
        cfg.tau = TauMode::Network;
        let out = run(backend, cfg, method.name())?;
        println!(
            "  {}: wall {:.0}s = compute {:.0}s + stall {:.0}s (stalled applies: {})",
            method.name(), out.wall_s, out.compute_s, out.comm_stall_s,
            out.apply_stalls
        );
        curves.push(out.curve);
    }
    write_curves_csv(cli.outdir.join("wallclock.csv"), &curves)?;
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

fn ablate_lambda(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== ABL-lambda: compensation strength ==");
    let mut curves = Vec::new();
    for lam in [0.0f32, 0.25, 0.5, 1.0] {
        let mut cfg = base_cfg(cli, MethodKind::Cocodc);
        cfg.lambda = lam;
        curves.push(run(backend, cfg, &format!("cocodc_lambda{lam}"))?.curve);
    }
    write_curves_csv(cli.outdir.join("ablate_lambda.csv"), &curves)?;
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

fn ablate_gamma(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== ABL-gamma: network utilization factor ==");
    let mut curves = Vec::new();
    for gam in [0.2f64, 0.4, 0.8] {
        let mut cfg = base_cfg(cli, MethodKind::Cocodc);
        cfg.gamma = gam;
        let out = run(backend, cfg, &format!("cocodc_gamma{gam}"))?;
        println!(
            "  gamma={gam}: syncs completed {} (bytes {:.1} MB)",
            out.syncs_completed,
            out.bytes_sent / 1e6
        );
        curves.push(out.curve);
    }
    write_curves_csv(cli.outdir.join("ablate_gamma.csv"), &curves)?;
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

fn ablate_tau(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== ABL-tau: overlap-depth robustness (streaming vs cocodc) ==");
    let mut curves = Vec::new();
    for tau in [1u32, 5, 15] {
        for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
            let mut cfg = base_cfg(cli, method);
            cfg.tau = TauMode::Fixed { tau };
            curves.push(run(backend, cfg, &format!("{}_tau{tau}", method.name()))?.curve);
        }
    }
    write_curves_csv(cli.outdir.join("ablate_tau.csv"), &curves)?;
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

fn ablate_codec(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== ABL-codec: pseudo-gradient wire compression ==");
    let mut curves = Vec::new();
    for codec in ["none", "int8", "int4"] {
        let mut cfg = base_cfg(cli, MethodKind::Cocodc);
        cfg.compression = cocodc::compression::Codec::parse(codec)?;
        let out = run(backend, cfg, &format!("cocodc_{codec}"))?;
        println!("  codec={codec}: {:.2} MB on the wire", out.bytes_sent / 1e6);
        curves.push(out.curve);
    }
    write_curves_csv(cli.outdir.join("ablate_codec.csv"), &curves)?;
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

/// FAULTS: degraded-WAN resilience sweep. Each severity scripts the same
/// seeded scenario (link outage + bandwidth-degradation window + transfer
/// loss + straggler + one worker crash/recover) for all three methods with
/// τ derived from the network, producing the degradation curve the paper's
/// robustness argument implies: DiLoCo's blocking sync eats every fault as
/// a stall, Streaming retries/requeues, CoCoDC additionally feeds observed
/// transfer times into its Eq. 9 schedule and keeps the quorum when a
/// worker is down.
fn faults(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== FAULTS: degraded-WAN resilience sweep ==");
    let mut rows = String::from(
        "severity,method,final_loss,final_ppl,wall_s,compute_s,comm_stall_s,\
         retries,drops,timeouts,requeues,apply_stalls,tau_mean,tau_max,\
         queue_delay_mean_s,queue_delay_max_s,bytes_mb\n",
    );
    let mut curves = Vec::new();
    for &sev in &cli.severities {
        let mut activity = 0usize;
        for method in MethodKind::all() {
            let mut cfg = base_cfg(cli, method);
            cfg.tau = TauMode::Network;
            // Scenario windows sit inside the compute-only horizon; stalls
            // only push the run further past them.
            let horizon = cfg.total_steps as f64 * cfg.network.step_compute_s;
            cfg.faults = FaultConfig::scenario(sev, horizon, cfg.workers);
            let out = run(backend, cfg, &format!("{}_sev{sev}", method.name()))?;
            println!(
                "  sev={sev} {:<18} wall {:>7.0}s (stall {:>6.0}s) retries={} \
                 drops={} timeouts={} requeues={}",
                method.name(),
                out.wall_s,
                out.comm_stall_s,
                out.retries,
                out.drops,
                out.timeouts,
                out.requeues
            );
            let fl = out.curve.final_loss().unwrap_or(f64::NAN);
            anyhow::ensure!(
                fl.is_finite(),
                "non-finite final loss at severity {sev} for {}",
                method.name()
            );
            activity += out.retries + out.drops + out.timeouts + out.requeues;
            rows.push_str(&format!(
                "{sev},{},{:.4},{:.4},{:.1},{:.1},{:.1},{},{},{},{},{},{:.2},{:.0},{:.3},{:.3},{:.1}\n",
                out.method,
                fl,
                out.curve.final_ppl().unwrap_or(f64::NAN),
                out.wall_s,
                out.compute_s,
                out.comm_stall_s,
                out.retries,
                out.drops,
                out.timeouts,
                out.requeues,
                out.apply_stalls,
                out.tau_dist.mean(),
                out.tau_dist.max_or_zero(),
                out.queue_delay_dist.mean(),
                out.queue_delay_dist.max_or_zero(),
                out.bytes_sent / 1e6,
            ));
            curves.push(out.curve);
        }
        // Self-check: a non-trivial severity that produces zero fault
        // activity across all three methods means the plan never touched
        // the run (mis-placed windows or a broken loss stream).
        anyhow::ensure!(
            sev == 0.0 || activity > 0,
            "fault scenario at severity {sev} produced no retries/drops/timeouts"
        );
    }
    std::fs::create_dir_all(&cli.outdir)?;
    std::fs::write(cli.outdir.join("faults.csv"), rows)?;
    write_curves_csv(cli.outdir.join("faults_curves.csv"), &curves)?;
    println!("degradation table -> {}", cli.outdir.join("faults.csv").display());
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

/// RECOVERY: in-flight corruption sweep with the self-healing state layer
/// armed (snapshot ring + divergence sentinel). A mid-run corruption window
/// flips bits in delivered fragment payloads; the strategies detect the
/// checksum mismatch, quarantine the payload and retransmit through the
/// fault-plan retry path, so the corrupted runs should converge back onto
/// the fault-free curve once every payload lands intact.
fn recovery(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== RECOVERY: fragment-corruption sweep (ring + sentinel armed) ==");
    let mut rows = String::from(
        "corruption_prob,method,final_loss,final_ppl,corrupt_fragments,quarantined,\
         retries,requeues,rollbacks,fallback_loads,nonfinite_losses,\
         max_loss_gap_vs_clean,wall_s\n",
    );
    let mut curves = Vec::new();
    for method in [MethodKind::StreamingDiloco, MethodKind::Cocodc] {
        let mut clean: Option<Curve> = None;
        for &prob in &cli.corruptions {
            let mut cfg = base_cfg(cli, method);
            // Corruption window over the middle of the compute horizon;
            // the tail of the run is clean so retransmissions drain.
            let horizon = cfg.total_steps as f64 * cfg.network.step_compute_s;
            if prob > 0.0 {
                cfg.faults.corruptions.push(Corruption {
                    window: FaultWindow {
                        start_s: 0.10 * horizon,
                        duration_s: 0.40 * horizon,
                    },
                    prob,
                });
            }
            let ring_dir = cli.outdir.join(format!("ring_{}_{prob}", method.name()));
            std::fs::remove_dir_all(&ring_dir).ok();
            cfg.recovery.snapshot_every = (cli.steps / 4).max(1);
            cfg.recovery.snapshot_dir = ring_dir.to_string_lossy().into_owned();
            let out = run(backend, cfg, &format!("{}_corrupt{prob}", method.name()))?;
            let fl = out.curve.final_loss().unwrap_or(f64::NAN);
            anyhow::ensure!(
                fl.is_finite(),
                "non-finite final loss at corruption {prob} for {}",
                method.name()
            );
            // A corrupt payload must never be applied: every detection is a
            // quarantine, and a non-trivial window must actually fire.
            anyhow::ensure!(
                out.quarantined == out.corrupt_fragments,
                "{}: {} corrupt fragments but {} quarantined",
                method.name(),
                out.corrupt_fragments,
                out.quarantined
            );
            anyhow::ensure!(
                prob == 0.0 || out.corrupt_fragments > 0,
                "corruption window at p={prob} never fired for {}",
                method.name()
            );
            let gap = clean.as_ref().and_then(|c| max_loss_gap(&out.curve, c));
            println!(
                "  p={prob} {:<18} corrupt={} quarantined={} retries={} rollbacks={} \
                 gap_vs_clean={}",
                method.name(),
                out.corrupt_fragments,
                out.quarantined,
                out.retries,
                out.rollbacks,
                gap.map(|g| format!("{g:.4}")).unwrap_or_else(|| "-".into()),
            );
            rows.push_str(&format!(
                "{prob},{},{:.4},{:.4},{},{},{},{},{},{},{},{},{:.1}\n",
                out.method,
                fl,
                out.curve.final_ppl().unwrap_or(f64::NAN),
                out.corrupt_fragments,
                out.quarantined,
                out.retries,
                out.requeues,
                out.rollbacks,
                out.fallback_loads,
                out.nonfinite_losses,
                gap.map(|g| format!("{g:.6}")).unwrap_or_default(),
                out.wall_s,
            ));
            if prob == 0.0 {
                clean = Some(out.curve.clone());
            }
            curves.push(out.curve);
        }
    }
    std::fs::create_dir_all(&cli.outdir)?;
    std::fs::write(cli.outdir.join("recovery.csv"), rows)?;
    write_curves_csv(cli.outdir.join("recovery_curves.csv"), &curves)?;
    println!("recovery table -> {}", cli.outdir.join("recovery.csv").display());
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

/// TOPOLOGY: flat vs hierarchical two-level sync at matched WAN budgets.
/// Every multi-region preset runs twice per method: once with the region
/// graph attached (intra-region all-reduce at LAN cost, leader ring over
/// per-link timelines, intra broadcast — CoCoDC additionally routes each
/// fragment by its per-link EWMA estimates) and once on the matched flat
/// single link whose latency/bandwidth equal the preset's mesh means, so
/// both modes spend the same nominal WAN budget. The hierarchical runs
/// must reach the target PPL in no more simulated wall-clock than flat.
fn topology(cli: &Cli, backend: &dyn Backend) -> anyhow::Result<()> {
    println!("== TOPOLOGY: flat vs hierarchical two-level sync ==");
    let mut rows = String::from(
        "preset,mode,method,final_loss,final_ppl,steps_to_ppl,wall_to_ppl_s,\
         wall_s,compute_s,comm_stall_s,syncs,bytes_mb,link_utils\n",
    );
    let mut curves = Vec::new();
    for preset in &cli.topo_presets {
        let (net, topo) = net_preset(preset)?;
        anyhow::ensure!(
            !topo.is_flat(),
            "topology sweep needs a multi-region preset, got '{preset}'"
        );
        let workers = 2 * topo.n_regions();
        for method in MethodKind::all() {
            // (wall_s, wall_to_ppl) for flat then hier, for the self-check.
            let mut walls: Vec<(f64, Option<f64>)> = Vec::new();
            for hier in [false, true] {
                let mode = if hier { "hier" } else { "flat" };
                let mut cfg = base_cfg(cli, method);
                cfg.workers = workers;
                cfg.tau = TauMode::Network;
                let step = cfg.network.step_compute_s;
                cfg.network = net;
                cfg.network.step_compute_s = step;
                cfg.topology = if hier { topo.clone() } else { TopologyConfig::flat() };
                let out =
                    run(backend, cfg, &format!("{}_{preset}_{mode}", method.name()))?;
                if hier {
                    anyhow::ensure!(
                        !out.link_util.is_empty(),
                        "hierarchical run {preset}/{} reported no per-link utilization",
                        method.name()
                    );
                } else {
                    anyhow::ensure!(
                        out.link_util.is_empty(),
                        "flat run {preset}/{} reported per-link utilization",
                        method.name()
                    );
                }
                let links = out
                    .link_util
                    .iter()
                    .map(|l| {
                        format!(
                            "{}:{:.1}MB/{:.1}s/{}x",
                            l.name,
                            l.bytes / 1e6,
                            l.busy_s,
                            l.transfers
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(" ");
                println!(
                    "  {preset} {mode:<4} {:<18} wall {:>7.0}s (stall {:>6.0}s) \
                     syncs={} {links}",
                    method.name(),
                    out.wall_s,
                    out.comm_stall_s,
                    out.syncs_completed
                );
                rows.push_str(&format!(
                    "{preset},{mode},{},{:.4},{:.4},{},{},{:.1},{:.1},{:.1},{},{:.1},{links}\n",
                    out.method,
                    out.curve.final_loss().unwrap_or(f64::NAN),
                    out.curve.final_ppl().unwrap_or(f64::NAN),
                    out.curve.steps_to_ppl(cli.ppl).map(|s| format!("{s:.0}")).unwrap_or_default(),
                    out.curve.wall_to_ppl(cli.ppl).map(|s| format!("{s:.0}")).unwrap_or_default(),
                    out.wall_s,
                    out.compute_s,
                    out.comm_stall_s,
                    out.syncs_completed,
                    out.bytes_sent / 1e6,
                ));
                walls.push((out.wall_s, out.curve.wall_to_ppl(cli.ppl)));
                curves.push(out.curve);
            }
            // Self-check: at the matched WAN budget the hierarchical run may
            // never be slower than flat. Compare wall-to-target-PPL when both
            // runs reach it; otherwise fall back to total simulated wall.
            let (flat_wall, flat_ppl) = walls[0];
            let (hier_wall, hier_ppl) = walls[1];
            match (flat_ppl, hier_ppl) {
                (Some(f), Some(h)) => anyhow::ensure!(
                    h <= f + 1e-6,
                    "{preset}/{}: hierarchical reached PPL<={} at {h:.1}s but flat at {f:.1}s",
                    method.name(),
                    cli.ppl
                ),
                (Some(f), None) => anyhow::bail!(
                    "{preset}/{}: flat reached PPL<={} ({f:.1}s) but hierarchical never did",
                    method.name(),
                    cli.ppl
                ),
                _ => anyhow::ensure!(
                    hier_wall <= flat_wall + 1e-6,
                    "{preset}/{}: hierarchical wall {hier_wall:.1}s exceeds flat {flat_wall:.1}s",
                    method.name()
                ),
            }
        }
    }
    std::fs::create_dir_all(&cli.outdir)?;
    std::fs::write(cli.outdir.join("topology.csv"), rows)?;
    write_curves_csv(cli.outdir.join("topology_curves.csv"), &curves)?;
    println!("topology table -> {}", cli.outdir.join("topology.csv").display());
    println!("\n{}", table1(&curves, cli.ppl));
    Ok(())
}

/// Rebuild the Table-I comparison from previously written curve CSVs
/// (`experiments report --curves a.csv,b.csv --ppl 20`).
fn report(files: &str, ppl: f64) -> anyhow::Result<()> {
    let mut curves = Vec::new();
    for f in files.split(',') {
        curves.extend(cocodc::metrics::read_curves_csv(f.trim())?);
    }
    println!("{}", table1(&curves, ppl));
    let steps = |name: &str| {
        curves.iter().find(|c| c.method == name).and_then(|c| c.steps_to_ppl(ppl))
    };
    if let (Some(s_str), Some(s_dil), Some(s_ccd)) =
        (steps("streaming_diloco"), steps("diloco"), steps("cocodc"))
    {
        println!(
            "steps-to-PPL<={ppl} reduction: cocodc vs streaming: {:+.1}% | cocodc vs diloco: {:+.1}%",
            100.0 * (s_ccd - s_str) / s_str,
            100.0 * (s_ccd - s_dil) / s_dil,
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[])?;
    if args.positional.first().map(|s| s.as_str()) == Some("report") {
        let files = args.get("curves").unwrap_or("results/fig1_loss.csv").to_string();
        let ppl = args.get_or("ppl", 20.0)?;
        args.finish()?;
        return report(&files, ppl);
    }
    // A named preset expands to a matched network + topology pair; raw flag
    // overrides would skew that matched budget, so mixing them is an error.
    let net = match args.get("net-preset") {
        Some(name) => {
            let raw: Vec<&str> = ["latency", "bandwidth"]
                .iter()
                .copied()
                .filter(|f| args.get(f).is_some())
                .collect();
            anyhow::ensure!(
                raw.is_empty(),
                "--net-preset {name} conflicts with raw link overrides (--{}); \
                 use one or the other",
                raw.join(", --")
            );
            Some(net_preset(name)?)
        }
        None => None,
    };
    let cli = Cli {
        exp: args.positional.first().cloned().unwrap_or_else(|| "all".into()),
        outdir: PathBuf::from(args.get("outdir").unwrap_or("results")),
        preset: args.get("preset").unwrap_or("exp").to_string(),
        steps: args.get_or("steps", 1200)?,
        seed: args.get_or("seed", 17)?,
        ppl: args.get_or("ppl", 20.0)?,
        eval_every: args.get_or("eval-every", 25)?,
        threads: args.get_or("threads", 0)?,
        severities: match args.get("severity") {
            Some(s) => s
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse::<f64>()
                        .map_err(|e| anyhow::anyhow!("--severity {x}: {e}"))
                })
                .collect::<anyhow::Result<Vec<f64>>>()?,
            None => vec![0.0, 0.3, 0.6],
        },
        corruptions: match args.get("corruption") {
            Some(s) => s
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse::<f64>()
                        .map_err(|e| anyhow::anyhow!("--corruption {x}: {e}"))
                })
                .collect::<anyhow::Result<Vec<f64>>>()?,
            None => vec![0.0, 0.3, 0.7],
        },
        net,
        latency: args.get_parse("latency")?,
        bandwidth: args.get_parse("bandwidth")?,
        topo_presets: match args.get("topo-presets") {
            Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
            None => vec!["us-eu".into(), "global-4".into()],
        },
    };
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let kind = BackendKind::parse(args.get("backend").unwrap_or("auto"))?;
    args.finish()?;
    std::fs::create_dir_all(&cli.outdir)?;
    let backend = load_backend(kind, &artifacts, &cli.preset, false)?;
    eprintln!(
        "backend: preset '{}' on {}, {} params, K={}",
        cli.preset,
        backend.platform(),
        backend.param_count(),
        backend.fragments().k()
    );
    match cli.exp.as_str() {
        "fig1" | "fig2" | "table1" => {
            fig1(&cli, backend.as_ref())?;
        }
        "wallclock" => wallclock(&cli, backend.as_ref())?,
        "ablate-lambda" => ablate_lambda(&cli, backend.as_ref())?,
        "ablate-gamma" => ablate_gamma(&cli, backend.as_ref())?,
        "ablate-tau" => ablate_tau(&cli, backend.as_ref())?,
        "ablate-codec" => ablate_codec(&cli, backend.as_ref())?,
        "faults" => faults(&cli, backend.as_ref())?,
        "recovery" => recovery(&cli, backend.as_ref())?,
        "topology" => topology(&cli, backend.as_ref())?,
        "all" => {
            fig1(&cli, backend.as_ref())?;
            wallclock(&cli, backend.as_ref())?;
            ablate_lambda(&cli, backend.as_ref())?;
            ablate_gamma(&cli, backend.as_ref())?;
            ablate_tau(&cli, backend.as_ref())?;
            faults(&cli, backend.as_ref())?;
            recovery(&cli, backend.as_ref())?;
            topology(&cli, backend.as_ref())?;
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
    Ok(())
}
