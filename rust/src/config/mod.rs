//! Run configuration: which method, which artifact preset, the paper's
//! hyperparameters (H, τ, K, α, λ, γ), the WAN model, data generation and
//! evaluation cadence. Serializable as JSON (`--config run.json`, via the
//! in-tree `util::json` — this build environment has no serde) with
//! programmatic presets for every experiment in DESIGN.md §4.

use crate::compression::Codec;
use crate::util::json::{num, obj, s, Json};

/// Cross-region synchronization strategy (paper §II/§III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    /// Blocking all-reduce of the full pseudo-gradient every H steps
    /// (Douillard et al., DiLoCo).
    Diloco,
    /// Fragment-wise round-robin synchronization with overlap depth τ and
    /// mixing factor α (Streaming DiLoCo).
    StreamingDiloco,
    /// Streaming + Taylor delay compensation (Alg. 1) + adaptive fragment
    /// transmission (Alg. 2) — the paper's contribution.
    Cocodc,
}

impl MethodKind {
    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::Diloco => "diloco",
            MethodKind::StreamingDiloco => "streaming_diloco",
            MethodKind::Cocodc => "cocodc",
        }
    }

    pub fn parse(t: &str) -> anyhow::Result<MethodKind> {
        match t {
            "diloco" => Ok(MethodKind::Diloco),
            "streaming" | "streaming_diloco" => Ok(MethodKind::StreamingDiloco),
            "cocodc" => Ok(MethodKind::Cocodc),
            _ => anyhow::bail!("unknown method '{t}' (diloco|streaming|cocodc)"),
        }
    }

    pub fn all() -> [MethodKind; 3] {
        [MethodKind::Diloco, MethodKind::StreamingDiloco, MethodKind::Cocodc]
    }
}

/// How the effective overlap depth τ (steps between initiating a fragment
/// sync and applying its result) is determined.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TauMode {
    /// Paper §IV-A: τ fixed (5) "to simulate network constraints".
    Fixed { tau: u32 },
    /// Derive τ from the WAN simulator: τ = ceil(T_ring(fragment)/T_c),
    /// including queueing behind in-flight transfers.
    Network,
}

/// WAN link model between datacenters (per direction, symmetric).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkConfig {
    /// One-way latency per hop, seconds (paper: high-latency WAN).
    pub latency_s: f64,
    /// Per-link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Multiplicative jitter amplitude on each transfer (0 = deterministic).
    pub jitter: f64,
    /// Average compute time of one local step, seconds.
    pub step_compute_s: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        // A moderately aggressive cross-region setting: 50 ms one-way,
        // 1 Gbps dedicated inter-DC bandwidth.
        NetworkConfig {
            latency_s: 0.05,
            bandwidth_bps: 125e6,
            jitter: 0.0,
            step_compute_s: 0.15,
        }
    }
}

/// Synthetic-C4 corpus generation (DESIGN.md §2: C4 substitute).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DataConfig {
    /// Number of latent topics; non-IID shards concentrate workers on
    /// disjoint topic subsets.
    pub n_topics: usize,
    /// Probability that the next token follows the topic's deterministic
    /// successor pattern (the learnable structure).
    pub pattern_prob: f64,
    /// Zipf exponent of the background unigram distribution.
    pub zipf_exponent: f64,
    /// Concentration of each worker on its home topics;
    /// 1.0 = fully non-IID, 0.0 = IID.
    pub heterogeneity: f64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            n_topics: 8,
            pattern_prob: 0.65,
            zipf_exponent: 1.1,
            heterogeneity: 0.8,
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Artifact preset directory under `artifacts/` (tiny / exp / e2e).
    pub preset: String,
    pub method: MethodKind,
    /// Number of simulated datacenter workers M (paper: 4).
    pub workers: usize,
    /// Local computation period H between (full) synchronizations (paper: 100).
    pub h_steps: u32,
    /// Overlap depth handling.
    pub tau: TauMode,
    /// Streaming DiLoCo mixing factor α (Eq. 3).
    pub alpha: f32,
    /// CoCoDC compensation strength λ (paper: 0.5).
    pub lambda: f32,
    /// CoCoDC network utilization factor γ ∈ (0,1] (paper: 0.4 → 8 syncs/H).
    pub gamma: f64,
    /// Outer optimizer (SGD + Nesterov momentum, DiLoCo defaults).
    pub outer_lr: f32,
    pub outer_momentum: f32,
    /// Total local training steps.
    pub total_steps: u32,
    /// Evaluate validation loss/PPL every this many steps.
    pub eval_every: u32,
    /// Number of held-out validation batches.
    pub eval_batches: usize,
    /// Base seed for data/jitter (init seed is baked into artifacts).
    pub seed: u64,
    pub network: NetworkConfig,
    pub data: DataConfig,
    /// Run worker train steps on parallel threads.
    pub parallel_workers: bool,
    /// Use the HLO/Pallas artifacts for outer step + delay compensation
    /// instead of the native rust implementations.
    pub use_hlo_fragment_ops: bool,
    /// Wire codec for pseudo-gradients (Streaming DiLoCo ships them
    /// quantized; `int8`/`int4` round-trip the values and charge the WAN
    /// at compressed size).
    pub compression: Codec,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: "exp".into(),
            method: MethodKind::Cocodc,
            workers: 4,
            h_steps: 100,
            tau: TauMode::Fixed { tau: 5 },
            alpha: 0.5,
            lambda: 0.5,
            gamma: 0.4,
            outer_lr: 0.7,
            outer_momentum: 0.9,
            total_steps: 1200,
            eval_every: 25,
            eval_batches: 8,
            seed: 17,
            network: NetworkConfig::default(),
            data: DataConfig::default(),
            parallel_workers: true,
            use_hlo_fragment_ops: false,
            compression: Codec::None,
        }
    }
}

impl RunConfig {
    /// The paper's §IV-A configuration (M=4, H=100, τ=5, λ=0.5, γ=0.4),
    /// scaled to the given artifact preset.
    pub fn paper(preset: &str, method: MethodKind) -> Self {
        RunConfig { preset: preset.into(), method, ..Default::default() }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "need at least one worker");
        anyhow::ensure!(self.h_steps >= 1, "H must be >= 1");
        anyhow::ensure!((0.0..=1.0).contains(&self.alpha), "alpha must be in [0,1]");
        anyhow::ensure!(self.gamma > 0.0 && self.gamma <= 1.0, "gamma in (0,1]");
        anyhow::ensure!(self.lambda >= 0.0, "lambda must be >= 0");
        if let TauMode::Fixed { tau } = self.tau {
            anyhow::ensure!(
                tau < self.h_steps,
                "overlap depth tau ({tau}) must be < H ({})",
                self.h_steps
            );
        }
        anyhow::ensure!(self.network.bandwidth_bps > 0.0, "bandwidth > 0");
        anyhow::ensure!(self.network.step_compute_s > 0.0, "step compute > 0");
        anyhow::ensure!(self.eval_every >= 1, "eval_every >= 1");
        anyhow::ensure!(self.eval_batches >= 1, "eval_batches >= 1");
        Ok(())
    }

    // ---------------- JSON (de)serialization ----------------
    pub fn to_json(&self) -> Json {
        let tau = match self.tau {
            TauMode::Fixed { tau } => obj(vec![("mode", s("fixed")), ("tau", num(tau as f64))]),
            TauMode::Network => obj(vec![("mode", s("network"))]),
        };
        obj(vec![
            ("preset", s(&self.preset)),
            ("method", s(self.method.name())),
            ("workers", num(self.workers as f64)),
            ("h_steps", num(self.h_steps as f64)),
            ("tau", tau),
            ("alpha", num(self.alpha as f64)),
            ("lambda", num(self.lambda as f64)),
            ("gamma", num(self.gamma)),
            ("outer_lr", num(self.outer_lr as f64)),
            ("outer_momentum", num(self.outer_momentum as f64)),
            ("total_steps", num(self.total_steps as f64)),
            ("eval_every", num(self.eval_every as f64)),
            ("eval_batches", num(self.eval_batches as f64)),
            ("seed", num(self.seed as f64)),
            (
                "network",
                obj(vec![
                    ("latency_s", num(self.network.latency_s)),
                    ("bandwidth_bps", num(self.network.bandwidth_bps)),
                    ("jitter", num(self.network.jitter)),
                    ("step_compute_s", num(self.network.step_compute_s)),
                ]),
            ),
            (
                "data",
                obj(vec![
                    ("n_topics", num(self.data.n_topics as f64)),
                    ("pattern_prob", num(self.data.pattern_prob)),
                    ("zipf_exponent", num(self.data.zipf_exponent)),
                    ("heterogeneity", num(self.data.heterogeneity)),
                ]),
            ),
            ("compression", s(self.compression.name())),
            ("parallel_workers", Json::Bool(self.parallel_workers)),
            ("use_hlo_fragment_ops", Json::Bool(self.use_hlo_fragment_ops)),
        ])
    }

    // Deliberately fills in from defaults field-by-field so new fields stay
    // backward compatible with older config files.
    #[allow(clippy::field_reassign_with_default)]
    pub fn from_json(j: &Json) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        cfg.preset = j.field("preset")?.as_str()?.to_string();
        cfg.method = MethodKind::parse(j.field("method")?.as_str()?)?;
        cfg.workers = j.field("workers")?.as_usize()?;
        cfg.h_steps = j.field("h_steps")?.as_u64()? as u32;
        let tau = j.field("tau")?;
        cfg.tau = match tau.field("mode")?.as_str()? {
            "fixed" => TauMode::Fixed { tau: tau.field("tau")?.as_u64()? as u32 },
            "network" => TauMode::Network,
            m => anyhow::bail!("unknown tau mode '{m}'"),
        };
        cfg.alpha = j.field("alpha")?.as_f64()? as f32;
        cfg.lambda = j.field("lambda")?.as_f64()? as f32;
        cfg.gamma = j.field("gamma")?.as_f64()?;
        cfg.outer_lr = j.field("outer_lr")?.as_f64()? as f32;
        cfg.outer_momentum = j.field("outer_momentum")?.as_f64()? as f32;
        cfg.total_steps = j.field("total_steps")?.as_u64()? as u32;
        cfg.eval_every = j.field("eval_every")?.as_u64()? as u32;
        cfg.eval_batches = j.field("eval_batches")?.as_usize()?;
        cfg.seed = j.field("seed")?.as_u64()?;
        let n = j.field("network")?;
        cfg.network = NetworkConfig {
            latency_s: n.field("latency_s")?.as_f64()?,
            bandwidth_bps: n.field("bandwidth_bps")?.as_f64()?,
            jitter: n.field("jitter")?.as_f64()?,
            step_compute_s: n.field("step_compute_s")?.as_f64()?,
        };
        let d = j.field("data")?;
        cfg.data = DataConfig {
            n_topics: d.field("n_topics")?.as_usize()?,
            pattern_prob: d.field("pattern_prob")?.as_f64()?,
            zipf_exponent: d.field("zipf_exponent")?.as_f64()?,
            heterogeneity: d.field("heterogeneity")?.as_f64()?,
        };
        if let Some(c) = j.get("compression") {
            cfg.compression = Codec::parse(c.as_str()?)?;
        }
        cfg.parallel_workers = j.field("parallel_workers")?.as_bool()?;
        cfg.use_hlo_fragment_ops = j.field("use_hlo_fragment_ops")?.as_bool()?;
        Ok(cfg)
    }

    pub fn from_json_file(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_json(&Json::parse(&text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_config_and_valid() {
        let c = RunConfig::default();
        c.validate().unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.h_steps, 100);
        assert_eq!(c.tau, TauMode::Fixed { tau: 5 });
        assert_eq!(c.lambda, 0.5);
        assert_eq!(c.gamma, 0.4);
    }

    #[test]
    fn json_round_trip() {
        let mut c = RunConfig::paper("exp", MethodKind::StreamingDiloco);
        c.tau = TauMode::Network;
        c.seed = 12345;
        let text = c.to_json_string();
        let back = RunConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = RunConfig::default();
        c.alpha = 1.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.tau = TauMode::Fixed { tau: 200 };
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.gamma = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn method_parse_accepts_aliases() {
        assert_eq!(MethodKind::parse("streaming").unwrap(),
                   MethodKind::StreamingDiloco);
        assert!(MethodKind::parse("bogus").is_err());
    }
}
