//! Run configuration: which method, which artifact preset, the paper's
//! hyperparameters (H, τ, K, α, λ, γ), the WAN model, data generation and
//! evaluation cadence. Serializable as JSON (`--config run.json`, via the
//! in-tree `util::json` — this build environment has no serde) with
//! programmatic presets for every experiment in DESIGN.md §4.

use crate::compression::Codec;
use crate::util::json::{num, obj, s, Json};

/// Cross-region synchronization strategy (paper §II/§III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    /// Blocking all-reduce of the full pseudo-gradient every H steps
    /// (Douillard et al., DiLoCo).
    Diloco,
    /// Fragment-wise round-robin synchronization with overlap depth τ and
    /// mixing factor α (Streaming DiLoCo).
    StreamingDiloco,
    /// Streaming + Taylor delay compensation (Alg. 1) + adaptive fragment
    /// transmission (Alg. 2) — the paper's contribution.
    Cocodc,
}

impl MethodKind {
    pub fn name(&self) -> &'static str {
        match self {
            MethodKind::Diloco => "diloco",
            MethodKind::StreamingDiloco => "streaming_diloco",
            MethodKind::Cocodc => "cocodc",
        }
    }

    pub fn parse(t: &str) -> anyhow::Result<MethodKind> {
        match t {
            "diloco" => Ok(MethodKind::Diloco),
            "streaming" | "streaming_diloco" => Ok(MethodKind::StreamingDiloco),
            "cocodc" => Ok(MethodKind::Cocodc),
            _ => anyhow::bail!("unknown method '{t}' (diloco|streaming|cocodc)"),
        }
    }

    pub fn all() -> [MethodKind; 3] {
        [MethodKind::Diloco, MethodKind::StreamingDiloco, MethodKind::Cocodc]
    }
}

/// How the effective overlap depth τ (steps between initiating a fragment
/// sync and applying its result) is determined.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TauMode {
    /// Paper §IV-A: τ fixed (5) "to simulate network constraints".
    Fixed { tau: u32 },
    /// Derive τ from the WAN simulator: τ = ceil(T_ring(fragment)/T_c),
    /// including queueing behind in-flight transfers.
    Network,
}

/// WAN link model between datacenters (per direction, symmetric).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkConfig {
    /// One-way latency per hop, seconds (paper: high-latency WAN).
    pub latency_s: f64,
    /// Per-link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Multiplicative jitter amplitude on each transfer (0 = deterministic).
    pub jitter: f64,
    /// Average compute time of one local step, seconds.
    pub step_compute_s: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        // A moderately aggressive cross-region setting: 50 ms one-way,
        // 1 Gbps dedicated inter-DC bandwidth.
        NetworkConfig {
            latency_s: 0.05,
            bandwidth_bps: 125e6,
            jitter: 0.0,
            step_compute_s: 0.15,
        }
    }
}

/// Physical parameters of one directed network link (intra-region LAN or
/// one direction of an inter-region WAN path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// One-way latency, seconds.
    pub latency_s: f64,
    /// Bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Multiplicative jitter amplitude on each transfer (0 = deterministic).
    pub jitter: f64,
}

/// Multi-region network topology (DESIGN.md §Topology): named regions with
/// a worker→region placement, a per-region LAN link and an R×R directed
/// inter-region link matrix. Each present inter-region link owns its own
/// serialized transfer timeline in the simulator. An empty `regions` list
/// means the legacy flat single-link WAN — the simulator then takes exactly
/// the pre-topology code path, bit for bit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TopologyConfig {
    /// Region names; empty = flat single shared WAN link.
    pub regions: Vec<String>,
    /// worker index → region index; empty = contiguous blocks
    /// (`worker * R / workers`).
    pub placement: Vec<usize>,
    /// Per-region LAN link used for the intra-region all-reduce tier.
    pub intra: Vec<LinkSpec>,
    /// Directed R×R inter-region matrix; `None` on the diagonal and for
    /// absent links. Asymmetric entries model asymmetric WAN paths.
    pub links: Vec<Vec<Option<LinkSpec>>>,
}

impl TopologyConfig {
    /// The legacy flat single-link WAN (no regions).
    pub fn flat() -> Self {
        TopologyConfig::default()
    }

    pub fn is_flat(&self) -> bool {
        self.regions.is_empty()
    }

    pub fn n_regions(&self) -> usize {
        self.regions.len()
    }

    /// Region hosting `worker` under this placement (flat topologies place
    /// everyone in a notional region 0).
    pub fn region_of(&self, worker: usize, workers: usize) -> usize {
        if self.is_flat() {
            0
        } else if self.placement.is_empty() {
            worker * self.regions.len() / workers.max(1)
        } else {
            self.placement[worker]
        }
    }

    /// A canonical named topology. `us-eu`: two regions over one symmetric
    /// transatlantic link. `global-4`: four regions (us/eu/ap/sa) on a full
    /// mesh with asymmetric return bandwidth. LAN tiers are 1 ms / 12.5 GB/s.
    pub fn preset(name: &str) -> anyhow::Result<TopologyConfig> {
        let lan = LinkSpec { latency_s: 0.001, bandwidth_bps: 12.5e9, jitter: 0.0 };
        let wan = |latency_s: f64, bandwidth_bps: f64| LinkSpec {
            latency_s,
            bandwidth_bps,
            jitter: 0.0,
        };
        match name {
            "flat" => Ok(TopologyConfig::flat()),
            "us-eu" => {
                let l = wan(0.045, 125e6);
                Ok(TopologyConfig {
                    regions: vec!["us".into(), "eu".into()],
                    placement: Vec::new(),
                    intra: vec![lan; 2],
                    links: vec![vec![None, Some(l)], vec![Some(l), None]],
                })
            }
            "global-4" => {
                // (one-way latency s, forward bandwidth B/s) per unordered
                // pair; the reverse direction runs at 0.9× bandwidth.
                let pairs = [
                    (0usize, 1usize, 0.045, 125e6),  // us ↔ eu
                    (0, 2, 0.090, 75e6),             // us ↔ ap
                    (0, 3, 0.075, 80e6),             // us ↔ sa
                    (1, 2, 0.120, 60e6),             // eu ↔ ap
                    (1, 3, 0.100, 70e6),             // eu ↔ sa
                    (2, 3, 0.150, 50e6),             // ap ↔ sa
                ];
                let mut links = vec![vec![None; 4]; 4];
                for &(a, b, lat, bw) in &pairs {
                    links[a][b] = Some(wan(lat, bw));
                    links[b][a] = Some(wan(lat, 0.9 * bw));
                }
                Ok(TopologyConfig {
                    regions: vec!["us".into(), "eu".into(), "ap".into(), "sa".into()],
                    placement: Vec::new(),
                    intra: vec![lan; 4],
                    links,
                })
            }
            _ => anyhow::bail!("unknown topology preset '{name}' (flat|us-eu|global-4)"),
        }
    }

    pub fn validate(&self, workers: usize) -> anyhow::Result<()> {
        if self.is_flat() {
            anyhow::ensure!(
                self.placement.is_empty() && self.intra.is_empty() && self.links.is_empty(),
                "flat topology (no regions) must have empty placement/intra/links"
            );
            return Ok(());
        }
        let r = self.regions.len();
        anyhow::ensure!(self.intra.len() == r, "need one intra-region link per region");
        anyhow::ensure!(
            self.links.len() == r && self.links.iter().all(|row| row.len() == r),
            "inter-region link matrix must be {r}x{r}"
        );
        anyhow::ensure!(
            self.placement.is_empty() || self.placement.len() == workers,
            "placement must be empty or name a region per worker"
        );
        for &p in &self.placement {
            anyhow::ensure!(p < r, "placement region {p} out of range (R={r})");
        }
        anyhow::ensure!(
            workers >= r || !self.placement.is_empty(),
            "contiguous placement needs at least one worker per region"
        );
        let mut members = vec![0usize; r];
        for w in 0..workers {
            members[self.region_of(w, workers)] += 1;
        }
        anyhow::ensure!(
            members.iter().all(|&m| m > 0),
            "every region must host at least one worker"
        );
        for (i, row) in self.links.iter().enumerate() {
            anyhow::ensure!(row[i].is_none(), "region {i} must not link to itself");
            for l in row.iter().flatten() {
                anyhow::ensure!(
                    l.latency_s >= 0.0 && l.bandwidth_bps > 0.0 && l.jitter >= 0.0,
                    "inter-region links need latency >= 0, bandwidth > 0, jitter >= 0"
                );
            }
        }
        for l in &self.intra {
            anyhow::ensure!(
                l.latency_s >= 0.0 && l.bandwidth_bps > 0.0 && l.jitter >= 0.0,
                "intra-region links need latency >= 0, bandwidth > 0, jitter >= 0"
            );
        }
        if r >= 2 {
            for i in 0..r {
                anyhow::ensure!(
                    self.links[i][(i + 1) % r].is_some(),
                    "the canonical region ring {i}->{} must exist (relay fallback \
                     routes over it when a direct link is missing)",
                    (i + 1) % r
                );
            }
        }
        Ok(())
    }

    fn link_json(l: &LinkSpec) -> Json {
        obj(vec![
            ("latency_s", num(l.latency_s)),
            ("bandwidth_bps", num(l.bandwidth_bps)),
            ("jitter", num(l.jitter)),
        ])
    }

    fn link_from_json(j: &Json) -> anyhow::Result<LinkSpec> {
        Ok(LinkSpec {
            latency_s: j.field("latency_s")?.as_f64()?,
            bandwidth_bps: j.field("bandwidth_bps")?.as_f64()?,
            jitter: j.field("jitter")?.as_f64()?,
        })
    }

    pub fn to_json(&self) -> Json {
        // The link matrix serializes sparsely as {from,to,link} entries so
        // absent links need no null encoding.
        let mut sparse = Vec::new();
        for (i, row) in self.links.iter().enumerate() {
            for (k, l) in row.iter().enumerate() {
                if let Some(l) = l {
                    sparse.push(obj(vec![
                        ("from", num(i as f64)),
                        ("to", num(k as f64)),
                        ("link", Self::link_json(l)),
                    ]));
                }
            }
        }
        obj(vec![
            ("regions", Json::Arr(self.regions.iter().map(|r| s(r)).collect())),
            (
                "placement",
                Json::Arr(self.placement.iter().map(|&p| num(p as f64)).collect()),
            ),
            ("intra", Json::Arr(self.intra.iter().map(Self::link_json).collect())),
            ("links", Json::Arr(sparse)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TopologyConfig> {
        let mut t = TopologyConfig::default();
        for r in j.field("regions")?.as_arr()? {
            t.regions.push(r.as_str()?.to_string());
        }
        for p in j.field("placement")?.as_arr()? {
            t.placement.push(p.as_usize()?);
        }
        for l in j.field("intra")?.as_arr()? {
            t.intra.push(Self::link_from_json(l)?);
        }
        let r = t.regions.len();
        t.links = vec![vec![None; r]; r];
        for e in j.field("links")?.as_arr()? {
            let from = e.field("from")?.as_usize()?;
            let to = e.field("to")?.as_usize()?;
            anyhow::ensure!(from < r && to < r, "link endpoint out of range (R={r})");
            t.links[from][to] = Some(Self::link_from_json(e.field("link")?)?);
        }
        Ok(t)
    }
}

/// Expand a `--net-preset` name into the matching flat-equivalent
/// `NetworkConfig` (used verbatim by flat runs, and as the matched-WAN-budget
/// baseline in `experiments topology`) plus the region graph. The flat link
/// carries the mean latency/bandwidth of the preset's WAN mesh.
pub fn net_preset(name: &str) -> anyhow::Result<(NetworkConfig, TopologyConfig)> {
    let topo = TopologyConfig::preset(name)?;
    let mut net = NetworkConfig::default();
    match name {
        "flat" => {}
        "us-eu" => {
            net.latency_s = 0.045;
            net.bandwidth_bps = 125e6;
        }
        "global-4" => {
            // Mean over the 12 directed mesh links.
            net.latency_s = 0.097;
            net.bandwidth_bps = 73e6;
        }
        _ => anyhow::bail!("unknown network preset '{name}' (flat|us-eu|global-4)"),
    }
    Ok((net, topo))
}

/// A closed-open window [start_s, start_s + duration_s) on the virtual
/// clock during which a fault condition holds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultWindow {
    pub start_s: f64,
    pub duration_s: f64,
}

impl FaultWindow {
    pub fn end_s(&self) -> f64 {
        self.start_s + self.duration_s
    }
    pub fn contains(&self, t: f64) -> bool {
        t >= self.start_s && t < self.end_s()
    }
}

/// Bandwidth degradation: during `window` the link runs at
/// `bandwidth_factor` × nominal bandwidth (congestion, partial cuts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Degradation {
    pub window: FaultWindow,
    /// Effective-bandwidth multiplier in (0, 1].
    pub bandwidth_factor: f64,
}

/// A worker crash: `worker` is down for `window` and rejoins afterwards by
/// adopting the current global parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashWindow {
    pub worker: usize,
    pub window: FaultWindow,
}

/// Payload corruption: transfers *delivered* inside `window` have a single
/// bit flipped in the fragment payload with probability `prob` (seeded draw
/// on a dedicated RNG stream). Checksums carried with each fragment let the
/// receiving strategy detect, quarantine and retransmit — a corrupt payload
/// must never be applied.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Corruption {
    pub window: FaultWindow,
    /// Per-delivery corruption probability in (0, 1].
    pub prob: f64,
}

/// Topology-aware outage: every WAN link touching `region` is severed for
/// `window` (transfers routed over them queue behind the window end), while
/// the region's LAN and all other inter-region links keep working. Requires
/// a non-flat `TopologyConfig`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegionalOutage {
    pub region: usize,
    pub window: FaultWindow,
}

/// Retry/backoff policy for dropped transfers (tentpole: lost transfers
/// surface as `TransferOutcome::Dropped`; callers retry under this budget).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Maximum transmission attempts per logical transfer (>= 1).
    pub max_attempts: u32,
    /// Backoff before the first retry, seconds of virtual time.
    pub backoff_base_s: f64,
    /// Multiplier applied to the backoff after each failed attempt.
    pub backoff_factor: f64,
    /// Total virtual-time budget for one logical transfer; once exceeded
    /// the transfer times out and the fragment is requeued.
    pub timeout_budget_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            backoff_base_s: 0.5,
            backoff_factor: 2.0,
            timeout_budget_s: 60.0,
        }
    }
}

/// Scriptable fault plan (the tentpole of DESIGN.md §Faults). All events are
/// placed on the virtual clock; the probabilistic transfer-loss draw flows
/// through a dedicated seeded RNG stream so a (seed, plan) pair fully
/// determines a run, and the stream is checkpointable like the jitter RNG.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultConfig {
    /// Full link outages: transfers requested inside queue behind the end.
    pub outages: Vec<FaultWindow>,
    /// Bandwidth-degradation windows (congestion).
    pub degradations: Vec<Degradation>,
    /// Probability in [0, 1) that any scheduled transfer is lost in flight.
    pub transfer_loss_prob: f64,
    /// Per-worker compute-time multipliers (>= 1); empty = no stragglers.
    /// The synchronous inner loop runs at the pace of the slowest live
    /// worker, so the step cost multiplier is the max over live workers.
    pub stragglers: Vec<f64>,
    /// Worker crash/recover events.
    pub crashes: Vec<CrashWindow>,
    /// Payload bit-flip windows (in-flight fragment corruption).
    pub corruptions: Vec<Corruption>,
    /// Per-region WAN severances (topology-aware; need a region graph).
    pub regional_outages: Vec<RegionalOutage>,
    pub retry: RetryPolicy,
}

impl FaultConfig {
    /// True when any fault source is enabled; the fault-free hot path stays
    /// allocation-free and bit-identical to the pre-fault builds.
    pub fn is_active(&self) -> bool {
        !self.outages.is_empty()
            || !self.degradations.is_empty()
            || self.transfer_loss_prob > 0.0
            || self.stragglers.iter().any(|&s| s > 1.0)
            || !self.crashes.is_empty()
            || !self.corruptions.is_empty()
            || !self.regional_outages.is_empty()
    }

    /// Canonical severity-parameterized scenario used by `experiments
    /// faults` and the CI fault matrix: one regional outage, a congestion
    /// window, probabilistic loss, one straggler and one crash/recover,
    /// all scaled by `severity` in [0, 1] over a run of `horizon_s`
    /// virtual seconds with `workers` datacenters.
    pub fn scenario(severity: f64, horizon_s: f64, workers: usize) -> FaultConfig {
        let sev = severity.clamp(0.0, 1.0);
        if sev == 0.0 {
            return FaultConfig::default();
        }
        let mut f = FaultConfig {
            outages: vec![FaultWindow {
                start_s: 0.25 * horizon_s,
                duration_s: 0.30 * sev * horizon_s,
            }],
            degradations: vec![Degradation {
                window: FaultWindow {
                    start_s: 0.60 * horizon_s,
                    duration_s: 0.20 * horizon_s,
                },
                bandwidth_factor: (1.0 - 0.7 * sev).max(0.25),
            }],
            transfer_loss_prob: 0.25 * sev,
            stragglers: Vec::new(),
            crashes: Vec::new(),
            corruptions: vec![Corruption {
                window: FaultWindow {
                    start_s: 0.10 * horizon_s,
                    duration_s: 0.10 * horizon_s,
                },
                prob: 0.5 * sev,
            }],
            regional_outages: Vec::new(),
            retry: RetryPolicy::default(),
        };
        if workers > 1 {
            f.stragglers = vec![1.0; workers];
            f.stragglers[1] = 1.0 + 0.5 * sev;
            f.crashes = vec![CrashWindow {
                worker: workers - 1,
                window: FaultWindow {
                    start_s: 0.45 * horizon_s,
                    duration_s: 0.15 * sev * horizon_s,
                },
            }];
        }
        f
    }

    pub fn validate(&self, workers: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.transfer_loss_prob),
            "transfer_loss_prob must be in [0,1) — at 1.0 retries never succeed"
        );
        for o in &self.outages {
            anyhow::ensure!(
                o.duration_s >= 0.0 && o.start_s >= 0.0,
                "outage windows need start/duration >= 0"
            );
        }
        for d in &self.degradations {
            anyhow::ensure!(
                d.bandwidth_factor > 0.0 && d.bandwidth_factor <= 1.0,
                "bandwidth_factor must be in (0,1]"
            );
        }
        for &s in &self.stragglers {
            anyhow::ensure!(s >= 1.0, "straggler multipliers must be >= 1");
        }
        anyhow::ensure!(
            self.stragglers.is_empty() || self.stragglers.len() == workers,
            "stragglers must be empty or one multiplier per worker"
        );
        for c in &self.crashes {
            anyhow::ensure!(c.worker < workers, "crash worker {} out of range", c.worker);
        }
        for c in &self.corruptions {
            anyhow::ensure!(
                c.prob > 0.0 && c.prob <= 1.0,
                "corruption prob must be in (0,1]"
            );
            anyhow::ensure!(
                c.window.start_s >= 0.0 && c.window.duration_s >= 0.0,
                "corruption windows need start/duration >= 0"
            );
        }
        for o in &self.regional_outages {
            anyhow::ensure!(
                o.window.start_s >= 0.0 && o.window.duration_s >= 0.0,
                "regional outage windows need start/duration >= 0"
            );
        }
        anyhow::ensure!(self.retry.max_attempts >= 1, "retry.max_attempts >= 1");
        anyhow::ensure!(self.retry.backoff_base_s >= 0.0, "retry.backoff_base_s >= 0");
        anyhow::ensure!(self.retry.backoff_factor >= 1.0, "retry.backoff_factor >= 1");
        anyhow::ensure!(self.retry.timeout_budget_s > 0.0, "retry.timeout_budget_s > 0");
        Ok(())
    }

    fn window_json(w: &FaultWindow) -> Json {
        obj(vec![("start_s", num(w.start_s)), ("duration_s", num(w.duration_s))])
    }

    fn window_from_json(j: &Json) -> anyhow::Result<FaultWindow> {
        Ok(FaultWindow {
            start_s: j.field("start_s")?.as_f64()?,
            duration_s: j.field("duration_s")?.as_f64()?,
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "outages",
                Json::Arr(self.outages.iter().map(Self::window_json).collect()),
            ),
            (
                "degradations",
                Json::Arr(
                    self.degradations
                        .iter()
                        .map(|d| {
                            obj(vec![
                                ("window", Self::window_json(&d.window)),
                                ("bandwidth_factor", num(d.bandwidth_factor)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("transfer_loss_prob", num(self.transfer_loss_prob)),
            (
                "stragglers",
                Json::Arr(self.stragglers.iter().map(|&s| num(s)).collect()),
            ),
            (
                "crashes",
                Json::Arr(
                    self.crashes
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("worker", num(c.worker as f64)),
                                ("window", Self::window_json(&c.window)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "corruptions",
                Json::Arr(
                    self.corruptions
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("window", Self::window_json(&c.window)),
                                ("prob", num(c.prob)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "regional_outages",
                Json::Arr(
                    self.regional_outages
                        .iter()
                        .map(|o| {
                            obj(vec![
                                ("region", num(o.region as f64)),
                                ("window", Self::window_json(&o.window)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "retry",
                obj(vec![
                    ("max_attempts", num(self.retry.max_attempts as f64)),
                    ("backoff_base_s", num(self.retry.backoff_base_s)),
                    ("backoff_factor", num(self.retry.backoff_factor)),
                    ("timeout_budget_s", num(self.retry.timeout_budget_s)),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FaultConfig> {
        let mut f = FaultConfig::default();
        for w in j.field("outages")?.as_arr()? {
            f.outages.push(Self::window_from_json(w)?);
        }
        for d in j.field("degradations")?.as_arr()? {
            f.degradations.push(Degradation {
                window: Self::window_from_json(d.field("window")?)?,
                bandwidth_factor: d.field("bandwidth_factor")?.as_f64()?,
            });
        }
        f.transfer_loss_prob = j.field("transfer_loss_prob")?.as_f64()?;
        for s in j.field("stragglers")?.as_arr()? {
            f.stragglers.push(s.as_f64()?);
        }
        for c in j.field("crashes")?.as_arr()? {
            f.crashes.push(CrashWindow {
                worker: c.field("worker")?.as_usize()?,
                window: Self::window_from_json(c.field("window")?)?,
            });
        }
        // Optional key: fault configs written before the corruption fault
        // class existed still parse.
        if let Some(cs) = j.get("corruptions") {
            for c in cs.as_arr()? {
                f.corruptions.push(Corruption {
                    window: Self::window_from_json(c.field("window")?)?,
                    prob: c.field("prob")?.as_f64()?,
                });
            }
        }
        // Optional key: plans written before topology-aware faults existed
        // still parse.
        if let Some(os) = j.get("regional_outages") {
            for o in os.as_arr()? {
                f.regional_outages.push(RegionalOutage {
                    region: o.field("region")?.as_usize()?,
                    window: Self::window_from_json(o.field("window")?)?,
                });
            }
        }
        let r = j.field("retry")?;
        f.retry = RetryPolicy {
            max_attempts: r.field("max_attempts")?.as_u64()? as u32,
            backoff_base_s: r.field("backoff_base_s")?.as_f64()?,
            backoff_factor: r.field("backoff_factor")?.as_f64()?,
            timeout_budget_s: r.field("timeout_budget_s")?.as_f64()?,
        };
        Ok(f)
    }
}

/// Synthetic-C4 corpus generation (DESIGN.md §2: C4 substitute).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DataConfig {
    /// Number of latent topics; non-IID shards concentrate workers on
    /// disjoint topic subsets.
    pub n_topics: usize,
    /// Probability that the next token follows the topic's deterministic
    /// successor pattern (the learnable structure).
    pub pattern_prob: f64,
    /// Zipf exponent of the background unigram distribution.
    pub zipf_exponent: f64,
    /// Concentration of each worker on its home topics;
    /// 1.0 = fully non-IID, 0.0 = IID.
    pub heterogeneity: f64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            n_topics: 8,
            pattern_prob: 0.65,
            zipf_exponent: 1.1,
            heterogeneity: 0.8,
        }
    }
}

/// Self-healing state layer: checkpoint ring cadence and the divergence
/// sentinel (DESIGN.md §Recovery). Disabled by default (`snapshot_every ==
/// 0`) so existing runs are untouched.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryConfig {
    /// Snapshot the full training state into the ring every this many steps
    /// (0 = recovery disabled).
    pub snapshot_every: u32,
    /// Number of snapshots kept in the ring.
    pub snapshot_ring: usize,
    /// Ring directory; must be non-empty when snapshots are enabled.
    pub snapshot_dir: String,
    /// Rollback budget: after this many rollbacks in one run, a further
    /// divergence is a hard error instead of an infinite replay loop.
    pub max_rollbacks: u32,
    /// Sentinel threshold: a train-loss z-score above this (against the
    /// loss EWMA/variance) counts as divergence. Non-finite loss always does.
    pub sentinel_zscore: f64,
    /// Number of loss observations before z-score spikes can fire (the
    /// EWMA needs warm-up; non-finite detection is active from step one).
    pub sentinel_warmup: u32,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            snapshot_every: 0,
            snapshot_ring: 4,
            snapshot_dir: String::new(),
            max_rollbacks: 3,
            sentinel_zscore: 6.0,
            sentinel_warmup: 16,
        }
    }
}

impl RecoveryConfig {
    pub fn is_active(&self) -> bool {
        self.snapshot_every > 0
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.snapshot_every > 0 {
            anyhow::ensure!(self.snapshot_ring >= 1, "snapshot_ring must be >= 1");
            anyhow::ensure!(
                !self.snapshot_dir.is_empty(),
                "snapshot_dir required when snapshot_every > 0"
            );
        }
        anyhow::ensure!(self.sentinel_zscore > 0.0, "sentinel_zscore must be > 0");
        anyhow::ensure!(self.sentinel_warmup >= 2, "sentinel_warmup must be >= 2");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("snapshot_every", num(self.snapshot_every as f64)),
            ("snapshot_ring", num(self.snapshot_ring as f64)),
            ("snapshot_dir", s(&self.snapshot_dir)),
            ("max_rollbacks", num(self.max_rollbacks as f64)),
            ("sentinel_zscore", num(self.sentinel_zscore)),
            ("sentinel_warmup", num(self.sentinel_warmup as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<RecoveryConfig> {
        Ok(RecoveryConfig {
            snapshot_every: j.field("snapshot_every")?.as_u64()? as u32,
            snapshot_ring: j.field("snapshot_ring")?.as_usize()?,
            snapshot_dir: j.field("snapshot_dir")?.as_str()?.to_string(),
            max_rollbacks: j.field("max_rollbacks")?.as_u64()? as u32,
            sentinel_zscore: j.field("sentinel_zscore")?.as_f64()?,
            sentinel_warmup: j.field("sentinel_warmup")?.as_u64()? as u32,
        })
    }
}

/// Full run configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Artifact preset directory under `artifacts/` (tiny / exp / e2e).
    pub preset: String,
    pub method: MethodKind,
    /// Number of simulated datacenter workers M (paper: 4).
    pub workers: usize,
    /// Local computation period H between (full) synchronizations (paper: 100).
    pub h_steps: u32,
    /// Overlap depth handling.
    pub tau: TauMode,
    /// Streaming DiLoCo mixing factor α (Eq. 3).
    pub alpha: f32,
    /// CoCoDC compensation strength λ (paper: 0.5).
    pub lambda: f32,
    /// CoCoDC network utilization factor γ ∈ (0,1] (paper: 0.4 → 8 syncs/H).
    pub gamma: f64,
    /// Outer optimizer (SGD + Nesterov momentum, DiLoCo defaults).
    pub outer_lr: f32,
    pub outer_momentum: f32,
    /// Total local training steps.
    pub total_steps: u32,
    /// Evaluate validation loss/PPL every this many steps.
    pub eval_every: u32,
    /// Number of held-out validation batches.
    pub eval_batches: usize,
    /// Base seed for data/jitter (init seed is baked into artifacts).
    pub seed: u64,
    pub network: NetworkConfig,
    /// Region graph for hierarchical two-level sync; flat (default) keeps
    /// the legacy single shared WAN link, bit for bit.
    pub topology: TopologyConfig,
    pub data: DataConfig,
    /// Run worker train steps on parallel threads.
    pub parallel_workers: bool,
    /// Thread budget for the shared worker/compute pool: 0 = auto (host
    /// parallelism), N > 0 pins the pool to N threads. Worker fan-out and
    /// the native backend's intra-step row sharding split this one budget
    /// (DESIGN.md §Parallelism); any value produces bit-identical results,
    /// only wall-clock changes. `--threads 1` implies `parallel_workers
    /// = false` at the CLI layer.
    pub threads: usize,
    /// Use the HLO/Pallas artifacts for outer step + delay compensation
    /// instead of the native rust implementations.
    pub use_hlo_fragment_ops: bool,
    /// Wire codec for pseudo-gradients (Streaming DiLoCo ships them
    /// quantized; `int8`/`int4` round-trip the values and charge the WAN
    /// at compressed size).
    pub compression: Codec,
    /// Scripted fault plan (outages, loss, stragglers, crashes); the
    /// default plan is empty and keeps the fault-free hot path untouched.
    pub faults: FaultConfig,
    /// Checkpoint ring + divergence sentinel (disabled by default).
    pub recovery: RecoveryConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: "exp".into(),
            method: MethodKind::Cocodc,
            workers: 4,
            h_steps: 100,
            tau: TauMode::Fixed { tau: 5 },
            alpha: 0.5,
            lambda: 0.5,
            gamma: 0.4,
            outer_lr: 0.7,
            outer_momentum: 0.9,
            total_steps: 1200,
            eval_every: 25,
            eval_batches: 8,
            seed: 17,
            network: NetworkConfig::default(),
            topology: TopologyConfig::default(),
            data: DataConfig::default(),
            parallel_workers: true,
            threads: 0,
            use_hlo_fragment_ops: false,
            compression: Codec::None,
            faults: FaultConfig::default(),
            recovery: RecoveryConfig::default(),
        }
    }
}

impl RunConfig {
    /// The paper's §IV-A configuration (M=4, H=100, τ=5, λ=0.5, γ=0.4),
    /// scaled to the given artifact preset.
    pub fn paper(preset: &str, method: MethodKind) -> Self {
        RunConfig { preset: preset.into(), method, ..Default::default() }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "need at least one worker");
        anyhow::ensure!(self.h_steps >= 1, "H must be >= 1");
        anyhow::ensure!((0.0..=1.0).contains(&self.alpha), "alpha must be in [0,1]");
        anyhow::ensure!(self.gamma > 0.0 && self.gamma <= 1.0, "gamma in (0,1]");
        anyhow::ensure!(self.lambda >= 0.0, "lambda must be >= 0");
        if let TauMode::Fixed { tau } = self.tau {
            anyhow::ensure!(
                tau < self.h_steps,
                "overlap depth tau ({tau}) must be < H ({})",
                self.h_steps
            );
        }
        anyhow::ensure!(self.network.bandwidth_bps > 0.0, "bandwidth > 0");
        anyhow::ensure!(self.network.step_compute_s > 0.0, "step compute > 0");
        anyhow::ensure!(self.eval_every >= 1, "eval_every >= 1");
        anyhow::ensure!(self.eval_batches >= 1, "eval_batches >= 1");
        self.topology.validate(self.workers)?;
        self.faults.validate(self.workers)?;
        for o in &self.faults.regional_outages {
            anyhow::ensure!(
                !self.topology.is_flat(),
                "regional outages need a multi-region topology (flat has no regions)"
            );
            anyhow::ensure!(
                o.region < self.topology.n_regions(),
                "regional outage region {} out of range (R={})",
                o.region,
                self.topology.n_regions()
            );
        }
        self.recovery.validate()?;
        Ok(())
    }

    // ---------------- JSON (de)serialization ----------------
    pub fn to_json(&self) -> Json {
        let tau = match self.tau {
            TauMode::Fixed { tau } => obj(vec![("mode", s("fixed")), ("tau", num(tau as f64))]),
            TauMode::Network => obj(vec![("mode", s("network"))]),
        };
        obj(vec![
            ("preset", s(&self.preset)),
            ("method", s(self.method.name())),
            ("workers", num(self.workers as f64)),
            ("h_steps", num(self.h_steps as f64)),
            ("tau", tau),
            ("alpha", num(self.alpha as f64)),
            ("lambda", num(self.lambda as f64)),
            ("gamma", num(self.gamma)),
            ("outer_lr", num(self.outer_lr as f64)),
            ("outer_momentum", num(self.outer_momentum as f64)),
            ("total_steps", num(self.total_steps as f64)),
            ("eval_every", num(self.eval_every as f64)),
            ("eval_batches", num(self.eval_batches as f64)),
            ("seed", num(self.seed as f64)),
            (
                "network",
                obj(vec![
                    ("latency_s", num(self.network.latency_s)),
                    ("bandwidth_bps", num(self.network.bandwidth_bps)),
                    ("jitter", num(self.network.jitter)),
                    ("step_compute_s", num(self.network.step_compute_s)),
                ]),
            ),
            (
                "data",
                obj(vec![
                    ("n_topics", num(self.data.n_topics as f64)),
                    ("pattern_prob", num(self.data.pattern_prob)),
                    ("zipf_exponent", num(self.data.zipf_exponent)),
                    ("heterogeneity", num(self.data.heterogeneity)),
                ]),
            ),
            ("topology", self.topology.to_json()),
            ("compression", s(self.compression.name())),
            ("faults", self.faults.to_json()),
            ("recovery", self.recovery.to_json()),
            ("parallel_workers", Json::Bool(self.parallel_workers)),
            ("threads", num(self.threads as f64)),
            ("use_hlo_fragment_ops", Json::Bool(self.use_hlo_fragment_ops)),
        ])
    }

    // Deliberately fills in from defaults field-by-field so new fields stay
    // backward compatible with older config files.
    #[allow(clippy::field_reassign_with_default)]
    pub fn from_json(j: &Json) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        cfg.preset = j.field("preset")?.as_str()?.to_string();
        cfg.method = MethodKind::parse(j.field("method")?.as_str()?)?;
        cfg.workers = j.field("workers")?.as_usize()?;
        cfg.h_steps = j.field("h_steps")?.as_u64()? as u32;
        let tau = j.field("tau")?;
        cfg.tau = match tau.field("mode")?.as_str()? {
            "fixed" => TauMode::Fixed { tau: tau.field("tau")?.as_u64()? as u32 },
            "network" => TauMode::Network,
            m => anyhow::bail!("unknown tau mode '{m}'"),
        };
        cfg.alpha = j.field("alpha")?.as_f64()? as f32;
        cfg.lambda = j.field("lambda")?.as_f64()? as f32;
        cfg.gamma = j.field("gamma")?.as_f64()?;
        cfg.outer_lr = j.field("outer_lr")?.as_f64()? as f32;
        cfg.outer_momentum = j.field("outer_momentum")?.as_f64()? as f32;
        cfg.total_steps = j.field("total_steps")?.as_u64()? as u32;
        cfg.eval_every = j.field("eval_every")?.as_u64()? as u32;
        cfg.eval_batches = j.field("eval_batches")?.as_usize()?;
        cfg.seed = j.field("seed")?.as_u64()?;
        let n = j.field("network")?;
        cfg.network = NetworkConfig {
            latency_s: n.field("latency_s")?.as_f64()?,
            bandwidth_bps: n.field("bandwidth_bps")?.as_f64()?,
            jitter: n.field("jitter")?.as_f64()?,
            step_compute_s: n.field("step_compute_s")?.as_f64()?,
        };
        let d = j.field("data")?;
        cfg.data = DataConfig {
            n_topics: d.field("n_topics")?.as_usize()?,
            pattern_prob: d.field("pattern_prob")?.as_f64()?,
            zipf_exponent: d.field("zipf_exponent")?.as_f64()?,
            heterogeneity: d.field("heterogeneity")?.as_f64()?,
        };
        // Optional key: pre-topology config files still parse as flat.
        if let Some(t) = j.get("topology") {
            cfg.topology = TopologyConfig::from_json(t)?;
        }
        if let Some(c) = j.get("compression") {
            cfg.compression = Codec::parse(c.as_str()?)?;
        }
        // Optional for backward compatibility with pre-fault config files.
        if let Some(f) = j.get("faults") {
            cfg.faults = FaultConfig::from_json(f)?;
        }
        if let Some(r) = j.get("recovery") {
            cfg.recovery = RecoveryConfig::from_json(r)?;
        }
        cfg.parallel_workers = j.field("parallel_workers")?.as_bool()?;
        // Optional for backward compatibility with pre-threads config files.
        if let Some(t) = j.get("threads") {
            cfg.threads = t.as_usize()?;
        }
        cfg.use_hlo_fragment_ops = j.field("use_hlo_fragment_ops")?.as_bool()?;
        Ok(cfg)
    }

    pub fn from_json_file(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_json(&Json::parse(&text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_config_and_valid() {
        let c = RunConfig::default();
        c.validate().unwrap();
        assert_eq!(c.workers, 4);
        assert_eq!(c.h_steps, 100);
        assert_eq!(c.tau, TauMode::Fixed { tau: 5 });
        assert_eq!(c.lambda, 0.5);
        assert_eq!(c.gamma, 0.4);
    }

    #[test]
    fn json_round_trip() {
        let mut c = RunConfig::paper("exp", MethodKind::StreamingDiloco);
        c.tau = TauMode::Network;
        c.seed = 12345;
        let text = c.to_json_string();
        let back = RunConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = RunConfig::default();
        c.alpha = 1.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.tau = TauMode::Fixed { tau: 200 };
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.gamma = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn faults_json_round_trip_and_back_compat() {
        let mut c = RunConfig::paper("exp", MethodKind::Cocodc);
        c.faults = FaultConfig::scenario(0.6, 300.0, 4);
        let back = RunConfig::from_json(&Json::parse(&c.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        // Pre-fault config files (no "faults" key) still parse, with an
        // inactive default plan.
        let mut legacy = RunConfig::paper("exp", MethodKind::Cocodc);
        legacy.faults = FaultConfig::default();
        let j = legacy.to_json_string().replace("\"faults\"", "\"faults_ignored\"");
        let parsed = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert!(!parsed.faults.is_active());
    }

    #[test]
    fn fault_validation_rejects_bad_plans() {
        let mut c = RunConfig::default();
        c.faults.transfer_loss_prob = 1.0; // retries could never succeed
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.faults.crashes.push(CrashWindow {
            worker: 99,
            window: FaultWindow { start_s: 0.0, duration_s: 1.0 },
        });
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.faults.stragglers = vec![0.5; c.workers]; // < 1 would speed workers up
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.faults.retry.max_attempts = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_scales_with_severity_and_validates() {
        assert!(!FaultConfig::scenario(0.0, 100.0, 4).is_active());
        let lo = FaultConfig::scenario(0.3, 100.0, 4);
        let hi = FaultConfig::scenario(0.9, 100.0, 4);
        lo.validate(4).unwrap();
        hi.validate(4).unwrap();
        assert!(hi.outages[0].duration_s > lo.outages[0].duration_s);
        assert!(hi.transfer_loss_prob > lo.transfer_loss_prob);
        assert!(hi.degradations[0].bandwidth_factor < lo.degradations[0].bandwidth_factor);
        assert!(hi.corruptions[0].prob > lo.corruptions[0].prob);
        assert!(hi.is_active() && lo.is_active());
    }

    #[test]
    fn corruption_config_round_trips_and_validates() {
        let mut c = RunConfig::default();
        c.faults.corruptions.push(Corruption {
            window: FaultWindow { start_s: 5.0, duration_s: 20.0 },
            prob: 0.4,
        });
        assert!(c.faults.is_active());
        c.validate().unwrap();
        let back = RunConfig::from_json(&Json::parse(&c.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        c.faults.corruptions[0].prob = 0.0;
        assert!(c.validate().is_err());
        c.faults.corruptions[0].prob = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn recovery_config_round_trips_and_validates() {
        let mut c = RunConfig::default();
        c.recovery = RecoveryConfig {
            snapshot_every: 10,
            snapshot_ring: 3,
            snapshot_dir: "/tmp/ring".into(),
            max_rollbacks: 2,
            sentinel_zscore: 4.0,
            sentinel_warmup: 8,
        };
        assert!(c.recovery.is_active());
        c.validate().unwrap();
        let back = RunConfig::from_json(&Json::parse(&c.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        c.recovery.snapshot_dir.clear();
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.recovery.snapshot_every = 5;
        c.recovery.snapshot_dir = "/tmp/ring".into();
        c.recovery.snapshot_ring = 0;
        assert!(c.validate().is_err());
        // Disabled recovery ignores ring/dir settings entirely.
        assert!(!RunConfig::default().recovery.is_active());
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn fault_window_contains_is_closed_open() {
        let w = FaultWindow { start_s: 10.0, duration_s: 5.0 };
        assert!(!w.contains(9.999));
        assert!(w.contains(10.0));
        assert!(w.contains(14.999));
        assert!(!w.contains(15.0));
    }

    #[test]
    fn method_parse_accepts_aliases() {
        assert_eq!(MethodKind::parse("streaming").unwrap(),
                   MethodKind::StreamingDiloco);
        assert!(MethodKind::parse("bogus").is_err());
    }

    #[test]
    fn topology_presets_validate_and_round_trip() {
        for name in ["flat", "us-eu", "global-4"] {
            let t = TopologyConfig::preset(name).unwrap();
            t.validate(8).unwrap();
            let mut c = RunConfig::paper("exp", MethodKind::Cocodc);
            c.workers = 8;
            c.topology = t;
            c.validate().unwrap();
            let back = RunConfig::from_json(&Json::parse(&c.to_json_string()).unwrap()).unwrap();
            assert_eq!(back, c);
        }
        assert!(TopologyConfig::preset("mars").is_err());
        // Pre-topology config files (no "topology" key) parse as flat.
        let j = RunConfig::default()
            .to_json_string()
            .replace("\"topology\"", "\"topology_ignored\"");
        let parsed = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert!(parsed.topology.is_flat());
    }

    #[test]
    fn net_preset_flat_matches_default_network() {
        let (net, topo) = net_preset("flat").unwrap();
        assert_eq!(net, NetworkConfig::default());
        assert!(topo.is_flat());
        let (net, topo) = net_preset("global-4").unwrap();
        assert_eq!(topo.n_regions(), 4);
        assert!(net.latency_s > NetworkConfig::default().latency_s);
        assert!(net_preset("bogus").is_err());
    }

    #[test]
    fn topology_validation_rejects_bad_graphs() {
        // More regions than workers under contiguous placement.
        let t = TopologyConfig::preset("global-4").unwrap();
        assert!(t.validate(2).is_err());
        // Placement pointing at a missing region.
        let mut t = TopologyConfig::preset("us-eu").unwrap();
        t.placement = vec![0, 0, 5, 1];
        assert!(t.validate(4).is_err());
        // A region with no workers.
        let mut t = TopologyConfig::preset("us-eu").unwrap();
        t.placement = vec![0, 0, 0, 0];
        assert!(t.validate(4).is_err());
        // Severed canonical ring.
        let mut t = TopologyConfig::preset("global-4").unwrap();
        t.links[1][2] = None;
        assert!(t.validate(8).is_err());
        // Flat topology with leftover per-region fields.
        let mut t = TopologyConfig::flat();
        t.intra = vec![LinkSpec { latency_s: 0.0, bandwidth_bps: 1.0, jitter: 0.0 }];
        assert!(t.validate(4).is_err());
    }

    #[test]
    fn contiguous_placement_assigns_blocks() {
        let t = TopologyConfig::preset("global-4").unwrap();
        let regions: Vec<usize> = (0..8).map(|w| t.region_of(w, 8)).collect();
        assert_eq!(regions, vec![0, 0, 1, 1, 2, 2, 3, 3]);
        let t = TopologyConfig::preset("us-eu").unwrap();
        let regions: Vec<usize> = (0..3).map(|w| t.region_of(w, 3)).collect();
        assert_eq!(regions, vec![0, 0, 1]);
    }

    #[test]
    fn regional_outages_require_topology_and_round_trip() {
        let mut c = RunConfig::paper("exp", MethodKind::Cocodc);
        c.faults.regional_outages.push(RegionalOutage {
            region: 1,
            window: FaultWindow { start_s: 10.0, duration_s: 30.0 },
        });
        assert!(c.faults.is_active());
        // Flat topology → rejected.
        assert!(c.validate().is_err());
        c.workers = 8;
        c.topology = TopologyConfig::preset("us-eu").unwrap();
        c.validate().unwrap();
        let back = RunConfig::from_json(&Json::parse(&c.to_json_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        // Region index out of range.
        c.faults.regional_outages[0].region = 7;
        assert!(c.validate().is_err());
    }
}
