//! The execution engine: compiled artifacts + typed wrappers around their
//! calling conventions, plus [`PjrtBackend`] — the resident-state
//! [`Backend`] implementation over the engine with dirty-fragment argument
//! marshalling (see `runtime::marshal`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::meta::Meta;
use crate::coordinator::fragments::{Fragment, FragmentTable};
use crate::runtime::backend::{validated_rows, Backend, WorkerHandle};
use crate::runtime::marshal::{LiteralCache, MarshalStats};
use crate::runtime::meta::ModelMeta;
use crate::util::pool::BufferPool;
use crate::util::vecops;

/// PJRT executables are not marked Send/Sync by the `xla` crate (raw FFI
/// handles), but the underlying XLA CPU client explicitly supports
/// concurrent `Execute` calls from multiple threads, and our usage never
/// mutates an executable after compilation. This wrapper asserts that.
struct SendExec(PjRtLoadedExecutable);
unsafe impl Send for SendExec {}
unsafe impl Sync for SendExec {}

/// Per-worker mutable training state (host-resident flat vectors).
#[derive(Debug, Clone)]
pub struct TrainState {
    /// Flat parameter vector θ (fragment-major layout, see meta.leaves).
    pub params: Vec<f32>,
    /// AdamW first moment.
    pub m: Vec<f32>,
    /// AdamW second moment.
    pub v: Vec<f32>,
    /// Local step counter (drives the in-artifact LR schedule).
    pub step: u32,
}

impl TrainState {
    pub fn new(params: Vec<f32>) -> Self {
        let n = params.len();
        TrainState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// Compiled artifact set for one preset.
pub struct Engine {
    client: PjRtClient,
    meta: Meta,
    dir: PathBuf,
    train: SendExec,
    eval: SendExec,
    grad: Option<SendExec>,
    /// fragment index -> (delay_comp, outer_step) executables.
    frag_ops: HashMap<usize, (SendExec, SendExec)>,
}

// Engine is shared read-only across worker threads (see SendExec).
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

fn compile(client: &PjRtClient, path: &Path) -> anyhow::Result<SendExec> {
    let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
        anyhow::anyhow!("loading HLO text {}: {e}", path.display())
    })?;
    let comp = XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| {
        anyhow::anyhow!("compiling {}: {e}", path.display())
    })?;
    Ok(SendExec(exe))
}

impl Engine {
    /// Load and compile every artifact under `artifacts_dir/preset`.
    pub fn load(artifacts_dir: &Path, preset: &str) -> anyhow::Result<Engine> {
        let dir = artifacts_dir.join(preset);
        let meta = Meta::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let art = |stem: &str| dir.join(stem);

        let train = compile(&client, &art(&meta.artifacts["train_step"]))?;
        let eval = compile(&client, &art(&meta.artifacts["eval_step"]))?;
        let grad = match meta.artifacts.get("grad_step") {
            Some(p) => Some(compile(&client, &art(p))?),
            None => None,
        };
        let mut frag_ops = HashMap::new();
        for i in 0..meta.n_fragments {
            let fa = &meta.fragment_artifacts[&i.to_string()];
            let dc = compile(&client, &art(&format!("{}.hlo.txt", fa.delay_comp)))?;
            let os = compile(&client, &art(&format!("{}.hlo.txt", fa.outer_step)))?;
            frag_ops.insert(i, (dc, os));
        }
        Ok(Engine { client, meta, dir, train, eval, grad, frag_ops })
    }

    pub fn meta(&self) -> &Meta {
        &self.meta
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Initial flat parameters as dumped by the AOT pipeline.
    pub fn init_params(&self) -> anyhow::Result<Vec<f32>> {
        let path = self.dir.join("init_params.bin");
        let bytes = std::fs::read(&path)?;
        anyhow::ensure!(
            bytes.len() == self.meta.param_count * 4,
            "init_params.bin size mismatch"
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn lit_f32(&self, data: &[f32]) -> Literal {
        Literal::vec1(data)
    }

    fn lit_tokens(&self, data: &[i32]) -> anyhow::Result<Literal> {
        let (b, t) = (self.meta.model.batch_size as i64, self.meta.model.seq_len as i64);
        anyhow::ensure!(data.len() as i64 == b * t, "batch shape mismatch");
        Ok(Literal::vec1(data).reshape(&[b, t])?)
    }

    /// One local training step: runs the train_step artifact in place over
    /// `state` with the given batch; returns the training loss.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<f32> {
        let (lp, lm, lv) = (
            self.lit_f32(&state.params),
            self.lit_f32(&state.m),
            self.lit_f32(&state.v),
        );
        let step = Literal::scalar(state.step as f32);
        let (tok, tgt) = (self.lit_tokens(tokens)?, self.lit_tokens(targets)?);
        let args = [&lp, &lm, &lv, &step, &tok, &tgt];
        let result = self.train.0.execute(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 4, "train_step must return 4 outputs");
        outs[0].copy_raw_to(&mut state.params)?;
        outs[1].copy_raw_to(&mut state.m)?;
        outs[2].copy_raw_to(&mut state.v)?;
        let loss: f32 = outs[3].get_first_element()?;
        state.step += 1;
        Ok(loss)
    }

    /// Validation loss of `params` on one batch.
    pub fn eval_loss(
        &self,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<f32> {
        let lp = self.lit_f32(params);
        let (tok, tgt) = (self.lit_tokens(tokens)?, self.lit_tokens(targets)?);
        let args = [&lp, &tok, &tgt];
        let result = self.eval.0.execute(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.get_first_element()?)
    }

    /// Loss + flat gradient (ablation/testing path; not used by training).
    pub fn grad_step(
        &self,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<(f32, Vec<f32>)> {
        let exec = self
            .grad
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("grad_step artifact not built for this preset"))?;
        let lp = self.lit_f32(params);
        let (tok, tgt) = (self.lit_tokens(tokens)?, self.lit_tokens(targets)?);
        let args = [&lp, &tok, &tgt];
        let result = exec.0.execute(&args)?[0][0].to_literal_sync()?;
        let (loss_l, grad_l) = result.to_tuple2()?;
        let loss: f32 = loss_l.get_first_element()?;
        let grad: Vec<f32> = grad_l.to_vec()?;
        Ok((loss, grad))
    }

    /// CoCoDC Alg. 1 via the Pallas/HLO artifact (per fragment), applied
    /// *in place*: `theta_local` is read as θ_tl (argument literals are
    /// marshalled before execution) and overwritten with the compensated
    /// state. The result is copied straight from the output literal — no
    /// fresh `Vec` per call, so the coordinator's pooled hot path stays
    /// allocation-free on the rust side. (The Literal marshalling round
    /// trip itself still copies — tracked in ROADMAP "Open items".)
    /// Matches `coordinator::delay_comp::delay_compensate` bit-for-bit
    /// (within f32 rounding); see bench_delay_comp.
    pub fn delay_comp_hlo_inplace(
        &self,
        fragment: usize,
        theta_g: &[f32],
        theta_local: &mut [f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) -> anyhow::Result<()> {
        let (dc, _) = &self.frag_ops[&fragment];
        let (lg, ll, lp) = (
            self.lit_f32(theta_g),
            self.lit_f32(theta_local),
            self.lit_f32(theta_tp),
        );
        let (st, sh, sl) =
            (Literal::scalar(tau), Literal::scalar(h), Literal::scalar(lambda));
        let args = [&lg, &ll, &lp, &st, &sh, &sl];
        let result = dc.0.execute(&args)?[0][0].to_literal_sync()?;
        result.to_tuple1()?.copy_raw_to(theta_local)?;
        Ok(())
    }

    /// Allocating convenience wrapper around [`Engine::delay_comp_hlo_inplace`]
    /// (benches/tests).
    pub fn delay_comp_hlo(
        &self,
        fragment: usize,
        theta_g: &[f32],
        theta_tl: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) -> anyhow::Result<Vec<f32>> {
        let mut out = theta_tl.to_vec();
        self.delay_comp_hlo_inplace(fragment, theta_g, &mut out, theta_tp, tau, h, lambda)?;
        Ok(out)
    }

    /// Nesterov outer step via the Pallas/HLO artifact (per fragment),
    /// writing the updated state into caller-provided (typically pooled)
    /// buffers.
    pub fn outer_step_hlo_into(
        &self,
        fragment: usize,
        theta_g: &[f32],
        delta: &[f32],
        momentum_buf: &[f32],
        lr: f32,
        momentum: f32,
        theta_out: &mut [f32],
        momentum_out: &mut [f32],
    ) -> anyhow::Result<()> {
        let (_, os) = &self.frag_ops[&fragment];
        let (lg, ld, lm) =
            (self.lit_f32(theta_g), self.lit_f32(delta), self.lit_f32(momentum_buf));
        let (sl, sm) = (Literal::scalar(lr), Literal::scalar(momentum));
        let args = [&lg, &ld, &lm, &sl, &sm];
        let result = os.0.execute(&args)?[0][0].to_literal_sync()?;
        let (t, m) = result.to_tuple2()?;
        t.copy_raw_to(theta_out)?;
        m.copy_raw_to(momentum_out)?;
        Ok(())
    }

    /// Allocating convenience wrapper around [`Engine::outer_step_hlo_into`].
    pub fn outer_step_hlo(
        &self,
        fragment: usize,
        theta_g: &[f32],
        delta: &[f32],
        momentum_buf: &[f32],
        lr: f32,
        momentum: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let mut t = vec![0.0f32; theta_g.len()];
        let mut m = vec![0.0f32; momentum_buf.len()];
        self.outer_step_hlo_into(fragment, theta_g, delta, momentum_buf, lr, momentum, &mut t, &mut m)?;
        Ok((t, m))
    }
}

// ---------------------------------------------------------------------
// PjrtBackend: the engine behind the resident-state Backend trait
// ---------------------------------------------------------------------

/// One worker's resident state on the PJRT backend: a host mirror of the
/// flat training state plus the cached argument literals that stand in for
/// device-resident buffers (real-PJRT buffer donation is a ROADMAP
/// follow-up; the caching layer already confines re-marshalling to dirty
/// fragments).
#[derive(Debug)]
pub struct PjrtWorker {
    state: TrainState,
    cache: LiteralCache,
}

/// [`Backend`] over the compiled PJRT artifacts. The *input* half of the
/// seed's marshalling round trip is gone: executor outputs are adopted as
/// the next call's argument literals, and coordinator writes re-marshal
/// only the fragment they touched. The *output* half — refreshing the host
/// mirror from the step's result literals — still runs once per step; it
/// disappears together with the mirror when real-PJRT buffer donation
/// keeps the state device-resident (ROADMAP follow-up).
pub struct PjrtBackend {
    engine: Engine,
    model: ModelMeta,
    frags: FragmentTable,
    init: Vec<f32>,
    use_hlo_fragment_ops: bool,
    /// Fragment-sized scratch for the HLO outer-step read-back.
    scratch: Mutex<BufferPool>,
}

impl PjrtBackend {
    pub fn load(
        artifacts_dir: &Path,
        preset: &str,
        use_hlo_fragment_ops: bool,
    ) -> anyhow::Result<PjrtBackend> {
        let engine = Engine::load(artifacts_dir, preset)?;
        let init = engine.init_params()?;
        let frags = FragmentTable::from_meta(engine.meta());
        Ok(PjrtBackend {
            model: engine.meta().model.clone(),
            frags,
            init,
            engine,
            use_hlo_fragment_ops,
            scratch: Mutex::new(BufferPool::new()),
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Marshalling counters of one worker (test/diagnostic hook).
    pub fn marshal_stats(&self, w: &WorkerHandle) -> anyhow::Result<MarshalStats> {
        Ok(w.get::<PjrtWorker>()?.cache.stats())
    }

    fn worker<'a>(&self, w: &'a WorkerHandle) -> anyhow::Result<&'a PjrtWorker> {
        w.get::<PjrtWorker>()
    }

    fn worker_mut<'a>(&self, w: &'a mut WorkerHandle) -> anyhow::Result<&'a mut PjrtWorker> {
        w.get_mut::<PjrtWorker>()
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.engine.platform()
    }

    fn model(&self) -> &ModelMeta {
        &self.model
    }

    fn param_count(&self) -> usize {
        self.init.len()
    }

    fn fragments(&self) -> &FragmentTable {
        &self.frags
    }

    fn init_params(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.init.clone())
    }

    fn create_worker(&self) -> anyhow::Result<WorkerHandle> {
        Ok(WorkerHandle::new(PjrtWorker {
            state: TrainState::new(self.init.clone()),
            cache: LiteralCache::new(self.frags.k()),
        }))
    }

    fn train_step(
        &self,
        w: &mut WorkerHandle,
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<f32> {
        let pw = self.worker_mut(w)?;
        let step = Literal::scalar(pw.state.step as f32);
        let (tok, tgt) = (
            self.engine.lit_tokens(tokens)?,
            self.engine.lit_tokens(targets)?,
        );
        let result = {
            let (lp, lm, lv) = pw.cache.refresh(&pw.state, &self.frags)?;
            let args = [lp, lm, lv, &step, &tok, &tgt];
            self.engine.train.0.execute(&args)?[0][0].to_literal_sync()?
        };
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 4, "train_step must return 4 outputs");
        let mut it = outs.into_iter();
        let (p, m, v) = (
            it.next().expect("len checked"),
            it.next().expect("len checked"),
            it.next().expect("len checked"),
        );
        let loss: f32 = it.next().expect("len checked").get_first_element()?;
        p.copy_raw_to(&mut pw.state.params)?;
        m.copy_raw_to(&mut pw.state.m)?;
        v.copy_raw_to(&mut pw.state.v)?;
        // The outputs *are* the next step's inputs — adopt, don't re-marshal.
        pw.cache.adopt(p, m, v);
        pw.state.step += 1;
        Ok(loss)
    }

    fn eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> anyhow::Result<f32> {
        self.engine.eval_loss(params, tokens, targets)
    }

    fn read_fragment(&self, w: &WorkerHandle, frag: Fragment, out: &mut [f32]) -> anyhow::Result<()> {
        out.copy_from_slice(&self.worker(w)?.state.params[frag.range()]);
        Ok(())
    }

    fn write_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        data: &[f32],
    ) -> anyhow::Result<()> {
        let pw = self.worker_mut(w)?;
        pw.state.params[frag.range()].copy_from_slice(data);
        pw.cache.mark_fragment(frag.index);
        Ok(())
    }

    fn delay_comp_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) -> anyhow::Result<()> {
        let pw = self.worker_mut(w)?;
        let local = &mut pw.state.params[frag.range()];
        if self.use_hlo_fragment_ops {
            self.engine
                .delay_comp_hlo_inplace(frag.index, theta_g, local, theta_tp, tau, h, lambda)?;
        } else {
            vecops::fused_delay_comp(local, theta_g, theta_tp, tau, h, lambda);
        }
        pw.cache.mark_fragment(frag.index);
        Ok(())
    }

    fn alpha_blend_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        alpha: f32,
    ) -> anyhow::Result<()> {
        let pw = self.worker_mut(w)?;
        vecops::fused_alpha_blend(&mut pw.state.params[frag.range()], theta_g, alpha);
        pw.cache.mark_fragment(frag.index);
        Ok(())
    }

    fn outer_step_fragment(
        &self,
        frag: Fragment,
        theta_g: &mut [f32],
        delta: &[f32],
        momentum: &mut [f32],
        lr: f32,
        mu: f32,
    ) -> anyhow::Result<()> {
        if !self.use_hlo_fragment_ops {
            vecops::fused_outer_step(theta_g, delta, momentum, lr, mu);
            return Ok(());
        }
        let (mut t2, mut m2) = {
            let mut pool = self.scratch.lock().expect("scratch pool poisoned");
            (pool.take(frag.size), pool.take(frag.size))
        };
        let r = self
            .engine
            .outer_step_hlo_into(frag.index, theta_g, delta, momentum, lr, mu, &mut t2, &mut m2);
        if r.is_ok() {
            theta_g.copy_from_slice(&t2);
            momentum.copy_from_slice(&m2);
        }
        let mut pool = self.scratch.lock().expect("scratch pool poisoned");
        pool.put(t2);
        pool.put(m2);
        r
    }

    fn mean_params(&self, ws: &[WorkerHandle], out: &mut [f32]) -> anyhow::Result<()> {
        let rows = validated_rows::<PjrtWorker, _>(ws, |w| w.state.params.as_slice())?;
        vecops::fused_mean_iter(out, rows);
        Ok(())
    }

    fn pseudo_mean_fragment(
        &self,
        ws: &[WorkerHandle],
        frag: Fragment,
        theta_g: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let rows =
            validated_rows::<PjrtWorker, _>(ws, move |w| &w.state.params[frag.range()])?;
        vecops::fused_pseudo_mean_iter(out, rows, theta_g);
        Ok(())
    }

    fn hlo_fragment_ops(&self) -> bool {
        self.use_hlo_fragment_ops
    }

    fn read_state(&self, w: &WorkerHandle, dst: &mut TrainState) -> anyhow::Result<()> {
        dst.clone_from(&self.worker(w)?.state);
        Ok(())
    }

    fn write_state(&self, w: &mut WorkerHandle, src: &TrainState) -> anyhow::Result<()> {
        let pw = self.worker_mut(w)?;
        pw.state.clone_from(src);
        // Everything (params *and* moments) changed: full re-marshal next use.
        pw.cache.invalidate();
        Ok(())
    }
}
