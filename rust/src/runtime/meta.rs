//! `artifacts/<preset>/meta.json` — the contract between the python AOT
//! pipeline (python/compile/aot.py) and this runtime: parameter layout,
//! fragment table, model/train hyperparameters and artifact file names.
//! Parsed with the in-tree `util::json` (offline build, no serde).

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub use_pallas_attention: bool,
}

#[derive(Debug, Clone)]
pub struct TrainMeta {
    pub lr: f64,
    pub warmup_steps: u32,
    pub total_steps: u32,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub min_lr_ratio: f64,
}

/// One parameter leaf inside the flat vector.
#[derive(Debug, Clone, PartialEq)]
pub struct LeafMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub fragment: usize,
}

/// One contiguous fragment (strided depth shard) of the flat vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FragmentMeta {
    pub index: usize,
    pub offset: usize,
    pub size: usize,
}

/// Artifact stems for a fragment's delay-comp / outer-step kernels.
#[derive(Debug, Clone)]
pub struct FragArtifacts {
    pub delay_comp: String,
    pub outer_step: String,
}

#[derive(Debug, Clone)]
pub struct Meta {
    pub preset: String,
    pub model: ModelMeta,
    pub train: TrainMeta,
    pub param_count: usize,
    pub n_fragments: usize,
    pub seed: u64,
    pub leaves: Vec<LeafMeta>,
    pub fragments: Vec<FragmentMeta>,
    pub fragment_artifacts: HashMap<String, FragArtifacts>,
    pub artifacts: HashMap<String, String>,
}

impl Meta {
    pub fn load(dir: &Path) -> anyhow::Result<Meta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        let meta = Self::from_json(&Json::parse(&text)?)?;
        meta.validate()?;
        Ok(meta)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Meta> {
        let m = j.field("model")?;
        let model = ModelMeta {
            vocab_size: m.field("vocab_size")?.as_usize()?,
            d_model: m.field("d_model")?.as_usize()?,
            n_layers: m.field("n_layers")?.as_usize()?,
            n_heads: m.field("n_heads")?.as_usize()?,
            d_ff: m.field("d_ff")?.as_usize()?,
            seq_len: m.field("seq_len")?.as_usize()?,
            batch_size: m.field("batch_size")?.as_usize()?,
            use_pallas_attention: m.field("use_pallas_attention")?.as_bool()?,
        };
        let t = j.field("train")?;
        let train = TrainMeta {
            lr: t.field("lr")?.as_f64()?,
            warmup_steps: t.field("warmup_steps")?.as_u64()? as u32,
            total_steps: t.field("total_steps")?.as_u64()? as u32,
            weight_decay: t.field("weight_decay")?.as_f64()?,
            beta1: t.field("beta1")?.as_f64()?,
            beta2: t.field("beta2")?.as_f64()?,
            eps: t.field("eps")?.as_f64()?,
            min_lr_ratio: t.field("min_lr_ratio")?.as_f64()?,
        };
        let leaves = j
            .field("leaves")?
            .as_arr()?
            .iter()
            .map(|l| -> anyhow::Result<LeafMeta> {
                Ok(LeafMeta {
                    name: l.field("name")?.as_str()?.to_string(),
                    shape: l
                        .field("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<anyhow::Result<_>>()?,
                    offset: l.field("offset")?.as_usize()?,
                    size: l.field("size")?.as_usize()?,
                    fragment: l.field("fragment")?.as_usize()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let fragments = j
            .field("fragments")?
            .as_arr()?
            .iter()
            .map(|f| -> anyhow::Result<FragmentMeta> {
                Ok(FragmentMeta {
                    index: f.field("index")?.as_usize()?,
                    offset: f.field("offset")?.as_usize()?,
                    size: f.field("size")?.as_usize()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let mut fragment_artifacts = HashMap::new();
        for (k, v) in j.field("fragment_artifacts")?.as_obj()? {
            fragment_artifacts.insert(
                k.clone(),
                FragArtifacts {
                    delay_comp: v.field("delay_comp")?.as_str()?.to_string(),
                    outer_step: v.field("outer_step")?.as_str()?.to_string(),
                },
            );
        }
        let mut artifacts = HashMap::new();
        for (k, v) in j.field("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), v.as_str()?.to_string());
        }
        Ok(Meta {
            preset: j.field("preset")?.as_str()?.to_string(),
            model,
            train,
            param_count: j.field("param_count")?.as_usize()?,
            n_fragments: j.field("n_fragments")?.as_usize()?,
            seed: j.field("seed")?.as_u64()?,
            leaves,
            fragments,
            fragment_artifacts,
            artifacts,
        })
    }

    /// Structural invariants the rust side depends on.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut off = 0usize;
        anyhow::ensure!(self.fragments.len() == self.n_fragments, "fragment count");
        for (i, f) in self.fragments.iter().enumerate() {
            anyhow::ensure!(f.index == i, "fragment indices must be ordered");
            anyhow::ensure!(f.offset == off, "fragments must tile the vector");
            anyhow::ensure!(f.size > 0, "empty fragment {i}");
            off += f.size;
        }
        anyhow::ensure!(off == self.param_count, "fragments must cover all params");
        let leaf_total: usize = self.leaves.iter().map(|l| l.size).sum();
        anyhow::ensure!(leaf_total == self.param_count, "leaves must cover all params");
        for l in &self.leaves {
            let f = &self.fragments[l.fragment];
            anyhow::ensure!(
                l.offset >= f.offset && l.offset + l.size <= f.offset + f.size,
                "leaf {} escapes its fragment",
                l.name
            );
            let elems: usize = l.shape.iter().product();
            anyhow::ensure!(elems == l.size, "leaf {} shape/size mismatch", l.name);
        }
        for i in 0..self.n_fragments {
            anyhow::ensure!(
                self.fragment_artifacts.contains_key(&i.to_string()),
                "missing fragment artifact entry {i}"
            );
        }
        for key in ["train_step", "eval_step"] {
            anyhow::ensure!(self.artifacts.contains_key(key), "missing artifact {key}");
        }
        Ok(())
    }

    /// Fragment byte size (f32) — what one fragment all-reduce moves per
    /// worker, the S in the ring cost model.
    pub fn fragment_bytes(&self, index: usize) -> f64 {
        self.fragments[index].size as f64 * 4.0
    }

    pub fn full_bytes(&self) -> f64 {
        self.param_count as f64 * 4.0
    }

    pub fn batch_elems(&self) -> usize {
        self.model.batch_size * self.model.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const MINI: &str = r#"{
        "preset":"t",
        "model":{"vocab_size":8,"d_model":4,"n_layers":2,"n_heads":2,
                 "d_ff":8,"seq_len":4,"batch_size":2,
                 "use_pallas_attention":true},
        "train":{"lr":0.001,"warmup_steps":1,"total_steps":10,
                 "weight_decay":0.1,"beta1":0.9,"beta2":0.999,"eps":1e-8,
                 "min_lr_ratio":0.1},
        "param_count":10,"n_fragments":2,"seed":0,
        "leaves":[
          {"name":"a","shape":[6],"offset":0,"size":6,"fragment":0},
          {"name":"b","shape":[4],"offset":6,"size":4,"fragment":1}],
        "fragments":[{"index":0,"offset":0,"size":6},
                     {"index":1,"offset":6,"size":4}],
        "fragment_artifacts":{"0":{"delay_comp":"d0","outer_step":"o0"},
                              "1":{"delay_comp":"d1","outer_step":"o1"}},
        "artifacts":{"train_step":"train_step.hlo.txt",
                     "eval_step":"eval_step.hlo.txt"}
    }"#;

    fn mini_meta() -> Meta {
        Meta::from_json(&Json::parse(MINI).unwrap()).unwrap()
    }

    #[test]
    fn valid_meta_passes() {
        mini_meta().validate().unwrap();
        assert_eq!(mini_meta().fragment_bytes(1), 16.0);
        assert_eq!(mini_meta().full_bytes(), 40.0);
        assert_eq!(mini_meta().batch_elems(), 8);
    }

    #[test]
    fn gap_in_fragments_rejected() {
        let mut m = mini_meta();
        m.fragments[1].offset = 7;
        assert!(m.validate().is_err());
    }

    #[test]
    fn leaf_escaping_fragment_rejected() {
        let mut m = mini_meta();
        m.leaves[0].size = 7;
        assert!(m.validate().is_err());
    }

    #[test]
    fn shape_size_mismatch_rejected() {
        let mut m = mini_meta();
        m.leaves[1].shape = vec![5];
        assert!(m.validate().is_err());
    }
}
