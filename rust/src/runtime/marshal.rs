//! Dirty-fragment argument marshalling for the PJRT backend.
//!
//! The seed engine rebuilt the full `params`/`m`/`v` argument literals on
//! every `train_step` — three P-sized host copies per step per worker even
//! when nothing but a single synced fragment had changed since the last
//! call. [`LiteralCache`] keeps the argument literals resident across steps
//! and re-marshals **only dirty fragments**:
//!
//! * after an execution, the output literals are *adopted* as the next
//!   call's input literals (the host-side analogue of PJRT buffer
//!   donation — the real-PJRT donation path is a ROADMAP follow-up);
//! * coordinator writes (`write_fragment`, delay-comp, α-blend) mark just
//!   their fragment dirty; `refresh` patches exactly those byte ranges via
//!   `Literal::write_raw_at`;
//! * full re-marshalling happens only on first use and checkpoint restore.
//!
//! [`MarshalStats`] counts every path so tests can assert the contract
//! (tests/backend_equiv.rs drives this against the vendored stub).

use xla::Literal;

use crate::coordinator::fragments::FragmentTable;
use crate::runtime::engine::TrainState;

/// Counters for the marshalling paths since construction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MarshalStats {
    /// Times the full (params, m, v) literal set was rebuilt from scratch.
    pub full_marshals: usize,
    /// Individual fragment ranges patched into a cached literal.
    pub fragment_marshals: usize,
    /// Times executor outputs were adopted as the next inputs (no copy of
    /// the parameter state crossed the boundary).
    pub adopted: usize,
}

/// Cached (params, m, v) argument literals with per-fragment dirty bits.
#[derive(Debug, Default)]
pub struct LiteralCache {
    params: Option<Literal>,
    m: Option<Literal>,
    v: Option<Literal>,
    dirty: Vec<bool>,
    stats: MarshalStats,
}

impl LiteralCache {
    pub fn new(n_fragments: usize) -> LiteralCache {
        LiteralCache { dirty: vec![false; n_fragments], ..Default::default() }
    }

    /// Record that fragment `p` of the host mirror changed (sync write).
    pub fn mark_fragment(&mut self, p: usize) {
        self.dirty[p] = true;
    }

    /// Drop the cached literals entirely (checkpoint restore: everything
    /// changed, including the moments).
    pub fn invalidate(&mut self) {
        self.params = None;
        self.m = None;
        self.v = None;
        self.dirty.fill(false);
    }

    /// Bring the cached literals in sync with `state`, marshalling only
    /// what is dirty, and return them ready to pass to `execute`.
    pub fn refresh(
        &mut self,
        state: &TrainState,
        frags: &FragmentTable,
    ) -> anyhow::Result<(&Literal, &Literal, &Literal)> {
        if self.params.is_none() || self.m.is_none() || self.v.is_none() {
            self.params = Some(Literal::vec1(&state.params));
            self.m = Some(Literal::vec1(&state.m));
            self.v = Some(Literal::vec1(&state.v));
            self.dirty.fill(false);
            self.stats.full_marshals += 1;
        } else if self.dirty.iter().any(|&d| d) {
            let lit = self.params.as_mut().expect("checked above");
            for p in 0..self.dirty.len() {
                if !self.dirty[p] {
                    continue;
                }
                let frag = frags.get(p);
                lit.write_raw_at(frag.offset, &state.params[frag.range()])
                    .map_err(|e| anyhow::anyhow!("fragment marshal: {e}"))?;
                self.dirty[p] = false;
                self.stats.fragment_marshals += 1;
            }
        }
        Ok((
            self.params.as_ref().expect("set above"),
            self.m.as_ref().expect("set above"),
            self.v.as_ref().expect("set above"),
        ))
    }

    /// Adopt executor outputs as the next call's inputs. The outputs *are*
    /// the post-step state, so nothing is re-marshalled.
    pub fn adopt(&mut self, params: Literal, m: Literal, v: Literal) {
        self.params = Some(params);
        self.m = Some(m);
        self.v = Some(v);
        self.dirty.fill(false);
        self.stats.adopted += 1;
    }

    pub fn stats(&self) -> MarshalStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (TrainState, FragmentTable, LiteralCache) {
        let frags = FragmentTable::from_sizes(&[4, 6, 2]);
        let state = TrainState::new((0..12).map(|i| i as f32).collect());
        (state, frags, LiteralCache::new(3))
    }

    #[test]
    fn first_refresh_is_one_full_marshal() {
        let (state, frags, mut cache) = setup();
        let (p, m, v) = cache.refresh(&state, &frags).unwrap();
        assert_eq!(p.to_vec::<f32>().unwrap(), state.params);
        assert_eq!(m.element_count(), 12);
        assert_eq!(v.element_count(), 12);
        assert_eq!(
            cache.stats(),
            MarshalStats { full_marshals: 1, fragment_marshals: 0, adopted: 0 }
        );
    }

    #[test]
    fn clean_refresh_marshals_nothing() {
        let (state, frags, mut cache) = setup();
        cache.refresh(&state, &frags).unwrap();
        cache.refresh(&state, &frags).unwrap();
        cache.refresh(&state, &frags).unwrap();
        assert_eq!(cache.stats().full_marshals, 1);
        assert_eq!(cache.stats().fragment_marshals, 0);
    }

    #[test]
    fn dirty_fragment_patches_only_that_range() {
        let (mut state, frags, mut cache) = setup();
        cache.refresh(&state, &frags).unwrap();
        // Mutate fragment 1 in the mirror and mark it.
        for x in &mut state.params[4..10] {
            *x += 100.0;
        }
        cache.mark_fragment(1);
        let (p, _, _) = cache.refresh(&state, &frags).unwrap();
        assert_eq!(p.to_vec::<f32>().unwrap(), state.params);
        let s = cache.stats();
        assert_eq!((s.full_marshals, s.fragment_marshals), (1, 1));
        // Second refresh: dirty bit cleared, nothing re-marshalled.
        cache.refresh(&state, &frags).unwrap();
        assert_eq!(cache.stats().fragment_marshals, 1);
    }

    #[test]
    fn adopt_replaces_literals_without_marshalling() {
        let (state, frags, mut cache) = setup();
        cache.refresh(&state, &frags).unwrap();
        let new_p = Literal::vec1(&[9.0f32; 12]);
        cache.adopt(new_p, Literal::vec1(&[1.0f32; 12]), Literal::vec1(&[2.0f32; 12]));
        let (p, m, _) = cache.refresh(&state, &frags).unwrap();
        // Adopted outputs win; the host mirror is NOT re-pushed.
        assert_eq!(p.to_vec::<f32>().unwrap(), vec![9.0; 12]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0; 12]);
        let s = cache.stats();
        assert_eq!((s.full_marshals, s.fragment_marshals, s.adopted), (1, 0, 1));
    }

    #[test]
    fn invalidate_forces_full_remarshal() {
        let (state, frags, mut cache) = setup();
        cache.refresh(&state, &frags).unwrap();
        cache.invalidate();
        cache.refresh(&state, &frags).unwrap();
        assert_eq!(cache.stats().full_marshals, 2);
    }
}
