//! Execution runtime: the [`Backend`] abstraction over resident worker
//! state, with three implementations —
//!
//! * [`PjrtBackend`] / [`Engine`]: AOT artifacts (`artifacts/<preset>/
//!   *.hlo.txt`) executed on the XLA CPU client (pattern per
//!   /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `client.compile` → `execute`), with per-worker cached argument
//!   literals re-marshalled only for dirty fragments (`marshal`);
//! * [`NativeBackend`]: pure-rust tiny transformer (fused 8-lane kernels),
//!   runnable end-to-end with zero artifacts on any machine;
//! * [`HostBackend`]: flat host vectors without a model, for
//!   pure-simulation tests that drive the coordinator with synthetic drift.

pub mod backend;
pub mod engine;
pub mod marshal;
pub mod meta;
pub mod native;

pub use backend::{load_backend, Backend, BackendKind, HostBackend, WorkerHandle};
pub use engine::{Engine, PjrtBackend, TrainState};
pub use marshal::{LiteralCache, MarshalStats};
pub use meta::{FragmentMeta, LeafMeta, Meta, ModelMeta, TrainMeta};
pub use native::{
    col_shards, intra_step_units, lr_schedule, row_shards, NativeBackend, NativeSpec,
};
