//! PJRT runtime: loads the AOT artifacts (`artifacts/<preset>/*.hlo.txt`)
//! and executes them on the XLA CPU client from the coordinator's hot loop.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts are lowered with
//! `return_tuple=True`, so results come back as one tuple literal.

pub mod engine;
pub mod meta;

pub use engine::{Engine, TrainState};
pub use meta::{FragmentMeta, LeafMeta, Meta};
