//! The execution-backend abstraction: resident worker state behind an
//! opaque [`WorkerHandle`].
//!
//! The seed runtime marshalled the full `params`/`m`/`v` vectors
//! host → Literal → device → Literal → host on *every* `train_step` /
//! `eval_loss` / fragment-op call — copies of state that never needed to
//! leave the execution backend. This trait applies the paper's own
//! discipline ("keep optimizer state resident, overlap only what must
//! move") at the runtime boundary:
//!
//! * per-worker training state (θ, m, v, step) lives *inside* the backend,
//!   owned by an opaque [`WorkerHandle`]; the trainer and the coordinator
//!   never see the flat vectors on the hot path;
//! * only synchronized fragments cross the boundary, through
//!   [`Backend::read_fragment`] / [`Backend::write_fragment`] into pooled
//!   buffers;
//! * the fragment algebra (delay compensation, α-blend, outer step) runs
//!   backend-side so resident state is updated in place.
//!
//! Implementations:
//! * [`crate::runtime::NativeBackend`] — pure-rust tiny transformer
//!   (fused vecops kernels), runnable with zero artifacts;
//! * [`crate::runtime::PjrtBackend`] — the PJRT/HLO engine with cached
//!   argument literals re-marshalled only for dirty fragments;
//! * [`HostBackend`] — flat host vectors with no model, for pure-simulation
//!   tests and examples that drive strategies with synthetic drift.

use std::any::Any;
use std::path::Path;
use std::sync::Arc;

use crate::coordinator::fragments::{Fragment, FragmentTable};
use crate::runtime::engine::TrainState;
use crate::runtime::meta::ModelMeta;
use crate::util::threadpool::WorkerPool;
use crate::util::vecops;

/// Opaque, backend-owned resident worker state. Constructed by
/// [`Backend::create_worker`]; the concrete payload is private to the
/// backend that made it.
pub struct WorkerHandle {
    inner: Box<dyn Any + Send>,
}

impl WorkerHandle {
    pub fn new<T: Any + Send>(inner: T) -> Self {
        WorkerHandle { inner: Box::new(inner) }
    }

    /// Downcast to the backend's concrete worker type. Backends use this
    /// internally; passing a handle to a different backend than the one
    /// that created it is a caller bug and errors cleanly.
    pub fn get<T: Any>(&self) -> anyhow::Result<&T> {
        self.inner
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow::anyhow!("WorkerHandle belongs to a different backend"))
    }

    pub fn get_mut<T: Any>(&mut self) -> anyhow::Result<&mut T> {
        self.inner
            .downcast_mut::<T>()
            .ok_or_else(|| anyhow::anyhow!("WorkerHandle belongs to a different backend"))
    }
}

impl std::fmt::Debug for WorkerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("WorkerHandle(..)")
    }
}

/// Shared plumbing for the backends' mean/pseudo-mean implementations:
/// validate that every handle belongs to worker type `T`, then yield one
/// borrowed f32 row per worker. Allocation-free, so it is safe on the
/// zero-allocation sync hot path.
pub(crate) fn validated_rows<'a, T, F>(
    ws: &'a [WorkerHandle],
    row: F,
) -> anyhow::Result<impl ExactSizeIterator<Item = &'a [f32]>>
where
    T: Any,
    F: Fn(&'a T) -> &'a [f32] + 'a,
{
    for w in ws {
        w.get::<T>()?;
    }
    Ok(ws.iter().map(move |w| row(w.get::<T>().expect("validated above"))))
}

/// An execution backend owning resident per-worker training state.
///
/// Contract (DESIGN.md §Backend):
/// * handles are only valid with the backend that created them;
/// * `train_step` advances the worker's resident (θ, m, v, step) in place
///   and returns only the scalar loss — no state crosses the boundary;
/// * `read_fragment`/`write_fragment` are the *only* way the coordinator
///   moves parameter data in or out, and it does so per synced fragment
///   into pooled buffers;
/// * the fragment ops must be bit-identical to their `vecops` twins (or
///   within the documented HLO tolerance for PJRT artifact dispatch);
/// * all methods take `&self` and are safe to call from the trainer's
///   worker pool with disjoint `&mut WorkerHandle`s.
pub trait Backend: Send + Sync {
    /// Human-readable execution platform (e.g. "native", "cpu", "stub").
    fn platform(&self) -> String;

    /// Model dimensions (batch/seq shape the data pipeline must produce).
    fn model(&self) -> &ModelMeta;

    /// Flat parameter-vector length P.
    fn param_count(&self) -> usize;

    /// The fragment partition of the flat vector.
    fn fragments(&self) -> &FragmentTable;

    /// Initial flat parameters (the replicated θ₀ every worker starts from).
    fn init_params(&self) -> anyhow::Result<Vec<f32>>;

    /// Create one worker with resident state initialized to θ₀.
    fn create_worker(&self) -> anyhow::Result<WorkerHandle>;

    /// One local training step on the worker's resident state; returns the
    /// training loss. `tokens`/`targets` are row-major `[batch, seq]`.
    fn train_step(
        &self,
        w: &mut WorkerHandle,
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<f32>;

    /// Validation loss of an explicit (host-side) parameter vector — used
    /// for the consensus mean, which exists outside any worker.
    fn eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32])
        -> anyhow::Result<f32>;

    /// Copy fragment `frag` of the worker's resident θ into `out`
    /// (`out.len() == frag.size`).
    fn read_fragment(
        &self,
        w: &WorkerHandle,
        frag: Fragment,
        out: &mut [f32],
    ) -> anyhow::Result<()>;

    /// Overwrite fragment `frag` of the worker's resident θ with `data`.
    fn write_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        data: &[f32],
    ) -> anyhow::Result<()>;

    /// CoCoDC Alg. 1 on the worker's resident fragment:
    /// θ_local ← θ_g + g_corr·τ (see `vecops::fused_delay_comp`).
    #[allow(clippy::too_many_arguments)]
    fn delay_comp_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) -> anyhow::Result<()>;

    /// Streaming DiLoCo's mixing step (Eq. 3) on the resident fragment:
    /// θ ← (1−α)·θ + α·θ_g.
    fn alpha_blend_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        alpha: f32,
    ) -> anyhow::Result<()>;

    /// Nesterov outer step (Eq. 2) on the replicated global fragment state
    /// (host-side: the consensus is not any worker's resident state).
    fn outer_step_fragment(
        &self,
        frag: Fragment,
        theta_g: &mut [f32],
        delta: &[f32],
        momentum: &mut [f32],
        lr: f32,
        mu: f32,
    ) -> anyhow::Result<()> {
        let _ = frag;
        vecops::fused_outer_step(theta_g, delta, momentum, lr, mu);
        Ok(())
    }

    /// Element-wise mean of every worker's resident θ written into `out` —
    /// the consensus the trainer evaluates. Backends compute this over
    /// resident state directly (no per-worker full-vector copies).
    fn mean_params(&self, ws: &[WorkerHandle], out: &mut [f32]) -> anyhow::Result<()>;

    /// Averaged pseudo-gradient Δθ_p^g = mean_m(θ_p^m) − θ_p^g over one
    /// fragment (paper Eq. 1), computed straight over resident worker
    /// state — the zero-copy path for syncs that don't need per-worker
    /// snapshots (DiLoCo rounds, plain Streaming DiLoCo initiations).
    fn pseudo_mean_fragment(
        &self,
        ws: &[WorkerHandle],
        frag: Fragment,
        theta_g: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()>;

    /// Whether this backend dispatches the fragment algebra to Pallas/HLO
    /// artifacts (PJRT only; used to keep `RunConfig::use_hlo_fragment_ops`
    /// and the constructed backend consistent).
    fn hlo_fragment_ops(&self) -> bool {
        false
    }

    /// Install (or clear, with `None`) a shared compute pool for intra-step
    /// data parallelism. Backends that can shard a single step across
    /// threads (the native backend's row shards) pick the pool up on the
    /// next step; the contract is that pooled execution stays bit-identical
    /// to serial, so this only ever changes wall-clock. The trainer calls
    /// this with its own worker pool — nested scopes make worker fan-out
    /// and intra-step sharding share one set of threads (DESIGN.md
    /// §Parallelism). Default: ignore the pool (backend steps stay serial).
    fn set_compute_pool(&self, pool: Option<Arc<WorkerPool>>) {
        let _ = pool;
    }

    /// Snapshot the worker's full state into `dst` (checkpoint path; not
    /// allocation-sensitive).
    fn read_state(&self, w: &WorkerHandle, dst: &mut TrainState) -> anyhow::Result<()>;

    /// Restore the worker's full state from `src` (checkpoint path).
    fn write_state(&self, w: &mut WorkerHandle, src: &TrainState) -> anyhow::Result<()>;
}

// ---------------------------------------------------------------------
// HostBackend: flat vectors, no model
// ---------------------------------------------------------------------

/// Minimal backend whose resident state is a host [`TrainState`] and whose
/// fragment ops are the fused vecops kernels. It has no model:
/// `train_step`/`eval_loss` error. Pure-simulation tests and examples use
/// it to drive the strategies with synthetic drift, mutating worker
/// parameters directly through [`HostBackend::state_mut`].
pub struct HostBackend {
    frags: FragmentTable,
    model: ModelMeta,
    init: Vec<f32>,
}

impl HostBackend {
    pub fn new(frags: FragmentTable) -> Self {
        let init = vec![0.0f32; frags.total_params()];
        HostBackend { frags, model: sim_model_meta(), init }
    }

    /// Direct access to a worker's flat state (simulation drift only —
    /// real data paths go through the fragment API).
    pub fn state<'a>(&self, w: &'a WorkerHandle) -> &'a TrainState {
        w.get::<TrainState>().expect("HostBackend handle")
    }

    pub fn state_mut<'a>(&self, w: &'a mut WorkerHandle) -> &'a mut TrainState {
        w.get_mut::<TrainState>().expect("HostBackend handle")
    }
}

/// Placeholder dimensions for backends that carry no model.
fn sim_model_meta() -> ModelMeta {
    ModelMeta {
        vocab_size: 4,
        d_model: 1,
        n_layers: 0,
        n_heads: 1,
        d_ff: 1,
        seq_len: 1,
        batch_size: 1,
        use_pallas_attention: false,
    }
}

impl Backend for HostBackend {
    fn platform(&self) -> String {
        "host-sim".into()
    }

    fn model(&self) -> &ModelMeta {
        &self.model
    }

    fn param_count(&self) -> usize {
        self.frags.total_params()
    }

    fn fragments(&self) -> &FragmentTable {
        &self.frags
    }

    fn init_params(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.init.clone())
    }

    fn create_worker(&self) -> anyhow::Result<WorkerHandle> {
        Ok(WorkerHandle::new(TrainState::new(self.init.clone())))
    }

    fn train_step(&self, _w: &mut WorkerHandle, _t: &[i32], _y: &[i32]) -> anyhow::Result<f32> {
        anyhow::bail!("HostBackend has no model; use NativeBackend or PjrtBackend")
    }

    fn eval_loss(&self, _p: &[f32], _t: &[i32], _y: &[i32]) -> anyhow::Result<f32> {
        anyhow::bail!("HostBackend has no model; use NativeBackend or PjrtBackend")
    }

    fn read_fragment(&self, w: &WorkerHandle, frag: Fragment, out: &mut [f32]) -> anyhow::Result<()> {
        out.copy_from_slice(&self.state(w).params[frag.range()]);
        Ok(())
    }

    fn write_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        data: &[f32],
    ) -> anyhow::Result<()> {
        self.state_mut(w).params[frag.range()].copy_from_slice(data);
        Ok(())
    }

    fn delay_comp_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) -> anyhow::Result<()> {
        let local = &mut self.state_mut(w).params[frag.range()];
        vecops::fused_delay_comp(local, theta_g, theta_tp, tau, h, lambda);
        Ok(())
    }

    fn alpha_blend_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        alpha: f32,
    ) -> anyhow::Result<()> {
        let local = &mut self.state_mut(w).params[frag.range()];
        vecops::fused_alpha_blend(local, theta_g, alpha);
        Ok(())
    }

    fn mean_params(&self, ws: &[WorkerHandle], out: &mut [f32]) -> anyhow::Result<()> {
        let rows = validated_rows::<TrainState, _>(ws, |s| s.params.as_slice())?;
        vecops::fused_mean_iter(out, rows);
        Ok(())
    }

    fn pseudo_mean_fragment(
        &self,
        ws: &[WorkerHandle],
        frag: Fragment,
        theta_g: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let rows = validated_rows::<TrainState, _>(ws, move |s| &s.params[frag.range()])?;
        vecops::fused_pseudo_mean_iter(out, rows, theta_g);
        Ok(())
    }

    fn read_state(&self, w: &WorkerHandle, dst: &mut TrainState) -> anyhow::Result<()> {
        dst.clone_from(self.state(w));
        Ok(())
    }

    fn write_state(&self, w: &mut WorkerHandle, src: &TrainState) -> anyhow::Result<()> {
        self.state_mut(w).clone_from(src);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Backend selection (--backend {auto,pjrt,native})
// ---------------------------------------------------------------------

/// Which backend a CLI run should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when `artifacts/<preset>/meta.json` exists, native otherwise.
    Auto,
    Pjrt,
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "pjrt" => Ok(BackendKind::Pjrt),
            "native" => Ok(BackendKind::Native),
            other => anyhow::bail!("unknown backend '{other}' (auto|pjrt|native)"),
        }
    }
}

/// Instantiate the backend for `preset`. `use_hlo_fragment_ops` routes the
/// PJRT backend's fragment algebra through the Pallas/HLO artifacts.
pub fn load_backend(
    kind: BackendKind,
    artifacts_dir: &Path,
    preset: &str,
    use_hlo_fragment_ops: bool,
) -> anyhow::Result<Box<dyn Backend>> {
    use crate::runtime::{NativeBackend, PjrtBackend};
    let kind = match kind {
        BackendKind::Auto => {
            if artifacts_dir.join(preset).join("meta.json").exists() {
                BackendKind::Pjrt
            } else {
                BackendKind::Native
            }
        }
        k => k,
    };
    match kind {
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::load(
            artifacts_dir,
            preset,
            use_hlo_fragment_ops,
        )?)),
        BackendKind::Native => {
            // Never degrade silently: a run explicitly configured to
            // exercise the Pallas/HLO fragment-op path must not fall back
            // to the vecops kernels just because artifacts are missing.
            anyhow::ensure!(
                !use_hlo_fragment_ops,
                "use_hlo_fragment_ops requires the PJRT backend (artifacts for \
                 preset '{preset}' under {}); the native backend has no HLO path",
                artifacts_dir.display()
            );
            Ok(Box::new(NativeBackend::preset(preset)?))
        }
        BackendKind::Auto => unreachable!("resolved above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> HostBackend {
        HostBackend::new(FragmentTable::from_sizes(&[8, 8]))
    }

    #[test]
    fn handle_downcast_is_typed() {
        let b = backend();
        let mut w = b.create_worker().unwrap();
        assert!(w.get::<TrainState>().is_ok());
        assert!(w.get::<u32>().is_err());
        assert!(w.get_mut::<Vec<f32>>().is_err());
    }

    #[test]
    fn fragment_round_trip_touches_only_that_fragment() {
        let b = backend();
        let mut w = b.create_worker().unwrap();
        let frag = b.fragments().get(1);
        b.write_fragment(&mut w, frag, &[3.0; 8]).unwrap();
        let mut out = [0.0f32; 8];
        b.read_fragment(&w, b.fragments().get(0), &mut out).unwrap();
        assert_eq!(out, [0.0; 8]);
        b.read_fragment(&w, frag, &mut out).unwrap();
        assert_eq!(out, [3.0; 8]);
    }

    #[test]
    fn mean_params_is_elementwise_mean() {
        let b = backend();
        let mut w1 = b.create_worker().unwrap();
        let mut w2 = b.create_worker().unwrap();
        b.state_mut(&mut w1).params.fill(2.0);
        b.state_mut(&mut w2).params.fill(4.0);
        let mut mean = vec![0.0f32; b.param_count()];
        b.mean_params(&[w1, w2], &mut mean).unwrap();
        assert!(mean.iter().all(|&x| x == 3.0));
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn native_backend_rejects_hlo_fragment_ops() {
        let err = load_backend(
            BackendKind::Native,
            std::path::Path::new("/nonexistent"),
            "tiny",
            true,
        )
        .unwrap_err();
        assert!(err.to_string().contains("use_hlo_fragment_ops"));
        // Without the flag the native backend loads fine.
        assert!(load_backend(
            BackendKind::Native,
            std::path::Path::new("/nonexistent"),
            "tiny",
            false
        )
        .is_ok());
    }
}
