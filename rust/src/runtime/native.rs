//! Native execution backend: a pure-rust LLaMA-style tiny transformer with
//! hand-written forward/backward/AdamW kernels in the `util::vecops` 8-lane
//! style — the zero-artifact twin of the python AOT model
//! (python/compile/model.py), so every end-to-end scenario (experiments,
//! wallclock sweeps, outage robustness) runs on any machine.
//!
//! Architecture (matches the artifact model leaf-for-leaf):
//! embed → N × [RMSNorm → RoPE multi-head causal attention → residual →
//! RMSNorm → SwiGLU MLP → residual] → final RMSNorm → untied LM head →
//! mean token cross-entropy. The optimizer is decoupled AdamW with bias
//! correction and the warmup+cosine LR schedule computed from the same
//! `TrainMeta` fields the artifacts bake in.
//!
//! Resident-state discipline (DESIGN.md §Backend): each worker owns its
//! flat (θ, m, v, step) *and* all forward/backward scratch, allocated once
//! at `create_worker` — a steady-state `train_step` performs **zero** heap
//! allocations (tests/alloc_steady_state.rs proves it with a counting
//! allocator). Evaluation borrows scratch from a recycling pool so
//! concurrent validation batches stay allocation-free after warm-up.
//!
//! The flat layout is fragment-major over the same strided depth partition
//! as python/compile/config.flat_layout: layer l joins fragment l mod K,
//! the embedding joins fragment 0, final norm + LM head join fragment K−1.

use std::sync::Mutex;

use crate::coordinator::fragments::{Fragment, FragmentTable};
use crate::runtime::backend::{validated_rows, Backend, WorkerHandle};
use crate::runtime::engine::TrainState;
use crate::runtime::meta::{LeafMeta, ModelMeta, TrainMeta};
use crate::util::vecops::{self, axpy, dot};
use crate::util::Rng;

const RMS_EPS: f32 = 1e-6;
const ROPE_THETA: f32 = 10000.0;

/// Full specification of a native model + optimizer.
#[derive(Debug, Clone)]
pub struct NativeSpec {
    pub name: String,
    pub model: ModelMeta,
    pub train: TrainMeta,
    pub n_fragments: usize,
    pub seed: u64,
}

impl NativeSpec {
    /// Named presets. These mirror the artifact presets' architecture
    /// family but are scaled so the full three-method comparison runs in
    /// seconds on a laptop CPU with no artifacts present.
    pub fn preset(name: &str) -> anyhow::Result<NativeSpec> {
        let (model, train, k) = match name {
            "tiny" => (
                model_meta(64, 32, 2, 2, 64, 16, 2),
                train_meta(1e-3, 10, 200),
                2,
            ),
            "exp" => (
                model_meta(256, 64, 4, 4, 128, 32, 4),
                train_meta(2e-3, 20, 1200),
                4,
            ),
            "e2e" => (
                model_meta(512, 128, 6, 4, 256, 64, 4),
                train_meta(1e-3, 50, 2000),
                4,
            ),
            other => anyhow::bail!("unknown native preset '{other}' (tiny|exp|e2e)"),
        };
        Ok(NativeSpec { name: name.to_string(), model, train, n_fragments: k, seed: 0 })
    }
}

fn model_meta(
    vocab: usize,
    d: usize,
    layers: usize,
    heads: usize,
    ff: usize,
    seq: usize,
    batch: usize,
) -> ModelMeta {
    ModelMeta {
        vocab_size: vocab,
        d_model: d,
        n_layers: layers,
        n_heads: heads,
        d_ff: ff,
        seq_len: seq,
        batch_size: batch,
        use_pallas_attention: false,
    }
}

fn train_meta(lr: f64, warmup: u32, total: u32) -> TrainMeta {
    TrainMeta {
        lr,
        warmup_steps: warmup,
        total_steps: total,
        weight_decay: 0.1,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        min_lr_ratio: 0.1,
    }
}

/// Warmup + cosine LR schedule — same formula the train_step artifact bakes
/// in (python/compile/train.lr_schedule), with `step` 0-indexed.
pub fn lr_schedule(step: u32, t: &TrainMeta) -> f32 {
    let s = step as f64;
    let warm = (t.warmup_steps as f64).max(1.0);
    if (step as f64) < t.warmup_steps as f64 {
        return (t.lr * (s + 1.0) / warm) as f32;
    }
    let total = t.total_steps as f64;
    let prog = ((s - warm) / (total - warm).max(1.0)).clamp(0.0, 1.0);
    let cos = 0.5 * (1.0 + (std::f64::consts::PI * prog).cos());
    (t.lr * (t.min_lr_ratio + (1.0 - t.min_lr_ratio) * cos)) as f32
}

// ---------------------------------------------------------------------
// Flat layout (fragment-major strided depth partition)
// ---------------------------------------------------------------------

/// Offsets of one decoder block's leaves in the flat vector.
#[derive(Debug, Clone, Copy)]
struct LayerOff {
    attn_norm: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    mlp_norm: usize,
    w1: usize,
    w3: usize,
    w2: usize,
}

#[derive(Debug, Clone)]
struct Layout {
    embed: usize,
    layers: Vec<LayerOff>,
    final_norm: usize,
    lm_head: usize,
    leaves: Vec<LeafMeta>,
    frags: FragmentTable,
    total: usize,
}

/// Strided depth partition (python/compile/config.fragment_of): layer l →
/// shard l mod K; embedding → shard 0; final norm + LM head → shard K−1.
fn fragment_of(layer: i64, k: usize) -> usize {
    match layer {
        -1 => 0,
        -2 => k - 1,
        l => l as usize % k,
    }
}

fn build_layout(spec: &NativeSpec) -> Layout {
    let (v, d, f) = (spec.model.vocab_size, spec.model.d_model, spec.model.d_ff);
    let k = spec.n_fragments;
    assert!(k >= 1 && k <= spec.model.n_layers, "need 1 <= K <= n_layers");
    // Canonical leaf table: (name, size, layer).
    let mut canon: Vec<(String, Vec<usize>, i64)> = vec![("embed".into(), vec![v, d], -1)];
    for l in 0..spec.model.n_layers {
        let li = l as i64;
        canon.push((format!("layer{l}.attn_norm"), vec![d], li));
        canon.push((format!("layer{l}.wq"), vec![d, d], li));
        canon.push((format!("layer{l}.wk"), vec![d, d], li));
        canon.push((format!("layer{l}.wv"), vec![d, d], li));
        canon.push((format!("layer{l}.wo"), vec![d, d], li));
        canon.push((format!("layer{l}.mlp_norm"), vec![d], li));
        canon.push((format!("layer{l}.w1"), vec![d, f], li));
        canon.push((format!("layer{l}.w3"), vec![d, f], li));
        canon.push((format!("layer{l}.w2"), vec![f, d], li));
    }
    canon.push(("final_norm".into(), vec![d], -2));
    canon.push(("lm_head".into(), vec![d, v], -2));

    // Fragment-major packing.
    let mut leaves: Vec<LeafMeta> = Vec::new();
    let mut sizes = vec![0usize; k];
    let mut off = 0usize;
    for p in 0..k {
        let frag_off = off;
        for (name, shape, layer) in &canon {
            if fragment_of(*layer, k) != p {
                continue;
            }
            let size: usize = shape.iter().product();
            leaves.push(LeafMeta {
                name: name.clone(),
                shape: shape.clone(),
                offset: off,
                size,
                fragment: p,
            });
            off += size;
        }
        sizes[p] = off - frag_off;
    }
    let frags = FragmentTable::from_sizes(&sizes);

    let leaf_off = |name: &str| -> usize {
        leaves
            .iter()
            .find(|l| l.name == name)
            .unwrap_or_else(|| panic!("missing leaf {name}"))
            .offset
    };
    let layers = (0..spec.model.n_layers)
        .map(|l| LayerOff {
            attn_norm: leaf_off(&format!("layer{l}.attn_norm")),
            wq: leaf_off(&format!("layer{l}.wq")),
            wk: leaf_off(&format!("layer{l}.wk")),
            wv: leaf_off(&format!("layer{l}.wv")),
            wo: leaf_off(&format!("layer{l}.wo")),
            mlp_norm: leaf_off(&format!("layer{l}.mlp_norm")),
            w1: leaf_off(&format!("layer{l}.w1")),
            w3: leaf_off(&format!("layer{l}.w3")),
            w2: leaf_off(&format!("layer{l}.w2")),
        })
        .collect();
    Layout {
        embed: leaf_off("embed"),
        layers,
        final_norm: leaf_off("final_norm"),
        lm_head: leaf_off("lm_head"),
        leaves,
        frags,
        total: off,
    }
}

// ---------------------------------------------------------------------
// Dense kernels (row-major, vecops 8-lane style)
// ---------------------------------------------------------------------

/// out[n,p] = a[n,m] @ b[m,p] — axpy inner loop, every access contiguous.
fn matmul(out: &mut [f32], a: &[f32], b: &[f32], n: usize, m: usize, p: usize) {
    debug_assert_eq!(out.len(), n * p);
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(b.len(), m * p);
    for i in 0..n {
        let row = &mut out[i * p..(i + 1) * p];
        row.fill(0.0);
        for j in 0..m {
            axpy(row, a[i * m + j], &b[j * p..(j + 1) * p]);
        }
    }
}

/// out[n,m] = dout[n,p] @ bᵀ where b is [m,p] — dot-product inner loop.
fn matmul_bt(out: &mut [f32], dout: &[f32], b: &[f32], n: usize, m: usize, p: usize) {
    debug_assert_eq!(out.len(), n * m);
    for i in 0..n {
        let drow = &dout[i * p..(i + 1) * p];
        for j in 0..m {
            out[i * m + j] = dot(drow, &b[j * p..(j + 1) * p]);
        }
    }
}

/// gb[m,p] += aᵀ[m,n] @ dout[n,p] — weight-gradient accumulation.
fn matmul_at_acc(gb: &mut [f32], a: &[f32], dout: &[f32], n: usize, m: usize, p: usize) {
    debug_assert_eq!(gb.len(), m * p);
    for i in 0..n {
        let drow = &dout[i * p..(i + 1) * p];
        for j in 0..m {
            axpy(&mut gb[j * p..(j + 1) * p], a[i * m + j], drow);
        }
    }
}

/// y[i] = x[i] · rinv(row) · gain — saves 1/rms per row for backward.
fn rmsnorm(y: &mut [f32], rinv: &mut [f32], x: &[f32], gain: &[f32], n: usize, d: usize) {
    for i in 0..n {
        let xr = &x[i * d..(i + 1) * d];
        let ms = dot(xr, xr) / d as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        rinv[i] = r;
        let yr = &mut y[i * d..(i + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * r * gain[j];
        }
    }
}

/// RMSNorm backward: accumulates dx into `dx_acc` (residual-friendly) and
/// the gain gradient into `dgain`.
fn rmsnorm_backward(
    dx_acc: &mut [f32],
    dgain: &mut [f32],
    dy: &[f32],
    x: &[f32],
    rinv: &[f32],
    gain: &[f32],
    n: usize,
    d: usize,
) {
    for i in 0..n {
        let (xr, dyr) = (&x[i * d..(i + 1) * d], &dy[i * d..(i + 1) * d]);
        let r = rinv[i];
        // t = dy ⊙ gain; dx = r·t − x·(r³/D)·⟨t, x⟩; dgain += dy ⊙ x · r.
        let mut tx = 0.0f32;
        for j in 0..d {
            tx += dyr[j] * gain[j] * xr[j];
        }
        let c = r * r * r * tx / d as f32;
        let dxr = &mut dx_acc[i * d..(i + 1) * d];
        for j in 0..d {
            dxr[j] += r * dyr[j] * gain[j] - c * xr[j];
            dgain[j] += dyr[j] * xr[j] * r;
        }
    }
}

// ---------------------------------------------------------------------
// Scratch: every buffer a forward+backward pass needs, allocated once
// ---------------------------------------------------------------------

#[derive(Debug)]
struct LayerScratch {
    hn_attn: Vec<f32>,  // RMSNormed attention input   [n·D]
    rinv_attn: Vec<f32>,// per-row 1/rms               [n]
    q: Vec<f32>,        // post-RoPE queries           [n·D]
    k: Vec<f32>,        // post-RoPE keys              [n·D]
    v: Vec<f32>,        // values                      [n·D]
    probs: Vec<f32>,    // softmax attention           [B·nh·T·T]
    ctx: Vec<f32>,      // attention context (pre-wo)  [n·D]
    x_mid: Vec<f32>,    // residual after attention    [n·D]
    hn_mlp: Vec<f32>,   // RMSNormed MLP input         [n·D]
    rinv_mlp: Vec<f32>, // per-row 1/rms               [n]
    u: Vec<f32>,        // x@w1                        [n·F]
    g3: Vec<f32>,       // x@w3                        [n·F]
    s: Vec<f32>,        // silu(u)·g3                  [n·F]
    x_out: Vec<f32>,    // residual after MLP          [n·D]
}

#[derive(Debug)]
struct Scratch {
    x0: Vec<f32>,      // embeddings [n·D]
    layers: Vec<LayerScratch>,
    xf: Vec<f32>,      // final normed [n·D]
    rinv_f: Vec<f32>,  // [n]
    logits: Vec<f32>,  // [n·V]; reused in place as dlogits in backward
    // backward-only (shared across layers)
    grad: Vec<f32>,    // [P]
    d_x: Vec<f32>,     // [n·D]
    d_res: Vec<f32>,   // [n·D]
    d_h: Vec<f32>,     // [n·D]
    d_q: Vec<f32>,     // [n·D]
    d_k: Vec<f32>,     // [n·D]
    d_v: Vec<f32>,     // [n·D]
    d_p: Vec<f32>,     // [T·T] per (b,h)
    d_u: Vec<f32>,     // [n·F]
    d_g3: Vec<f32>,    // [n·F]
    d_s: Vec<f32>,     // [n·F]
}

impl Scratch {
    /// `with_backward = false` leaves the backward-only buffers (grad and
    /// the d_* family) empty — forward-only evaluation never touches them,
    /// so pooled eval scratch stays roughly half the size of train scratch.
    fn new(m: &ModelMeta, total: usize, with_backward: bool) -> Scratch {
        let (b, t, d, f, v) = (m.batch_size, m.seq_len, m.d_model, m.d_ff, m.vocab_size);
        let n = b * t;
        let bw = |len: usize| if with_backward { vec![0.0; len] } else { Vec::new() };
        let layer = || LayerScratch {
            hn_attn: vec![0.0; n * d],
            rinv_attn: vec![0.0; n],
            q: vec![0.0; n * d],
            k: vec![0.0; n * d],
            v: vec![0.0; n * d],
            probs: vec![0.0; b * m.n_heads * t * t],
            ctx: vec![0.0; n * d],
            x_mid: vec![0.0; n * d],
            hn_mlp: vec![0.0; n * d],
            rinv_mlp: vec![0.0; n],
            u: vec![0.0; n * f],
            g3: vec![0.0; n * f],
            s: vec![0.0; n * f],
            x_out: vec![0.0; n * d],
        };
        Scratch {
            x0: vec![0.0; n * d],
            layers: (0..m.n_layers).map(|_| layer()).collect(),
            xf: vec![0.0; n * d],
            rinv_f: vec![0.0; n],
            logits: vec![0.0; n * v],
            grad: bw(total),
            d_x: bw(n * d),
            d_res: bw(n * d),
            d_h: bw(n * d),
            d_q: bw(n * d),
            d_k: bw(n * d),
            d_v: bw(n * d),
            d_p: bw(t * t),
            d_u: bw(n * f),
            d_g3: bw(n * f),
            d_s: bw(n * f),
        }
    }
}

/// One worker's resident state: flat (θ, m, v, step) plus its private
/// forward/backward scratch.
#[derive(Debug)]
pub struct NativeWorker {
    state: TrainState,
    scratch: Scratch,
}

// ---------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------

pub struct NativeBackend {
    spec: NativeSpec,
    layout: Layout,
    init: Vec<f32>,
    /// RoPE tables: cos/sin of t·freq_j, [T · dh/2] each.
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    /// Recycled eval scratch (validation batches run concurrently).
    eval_scratch: Mutex<Vec<Box<Scratch>>>,
}

impl NativeBackend {
    pub fn new(spec: NativeSpec) -> anyhow::Result<NativeBackend> {
        anyhow::ensure!(
            spec.model.d_model % spec.model.n_heads == 0,
            "d_model must be divisible by n_heads"
        );
        let dh = spec.model.d_model / spec.model.n_heads;
        anyhow::ensure!(dh % 2 == 0, "head_dim must be even for RoPE");
        let layout = build_layout(&spec);
        let init = init_flat(&spec, &layout);
        let half = dh / 2;
        let t_len = spec.model.seq_len;
        let mut rope_cos = vec![0.0f32; t_len * half];
        let mut rope_sin = vec![0.0f32; t_len * half];
        for t in 0..t_len {
            for j in 0..half {
                let freq = 1.0 / (ROPE_THETA as f64).powf(j as f64 / half as f64);
                let ang = t as f64 * freq;
                rope_cos[t * half + j] = ang.cos() as f32;
                rope_sin[t * half + j] = ang.sin() as f32;
            }
        }
        Ok(NativeBackend {
            spec,
            layout,
            init,
            rope_cos,
            rope_sin,
            eval_scratch: Mutex::new(Vec::new()),
        })
    }

    pub fn preset(name: &str) -> anyhow::Result<NativeBackend> {
        NativeBackend::new(NativeSpec::preset(name)?)
    }

    pub fn spec(&self) -> &NativeSpec {
        &self.spec
    }

    pub fn leaves(&self) -> &[LeafMeta] {
        &self.layout.leaves
    }

    fn worker<'a>(&self, w: &'a WorkerHandle) -> anyhow::Result<&'a NativeWorker> {
        w.get::<NativeWorker>()
    }

    fn worker_mut<'a>(&self, w: &'a mut WorkerHandle) -> anyhow::Result<&'a mut NativeWorker> {
        w.get_mut::<NativeWorker>()
    }

    // ------------------------------------------------------------------
    // forward / backward
    // ------------------------------------------------------------------

    /// RoPE rotation applied in place to every head slice of `x` [n·D].
    /// `dir` = 1.0 forward, −1.0 backward (the transpose rotation).
    fn rope(&self, x: &mut [f32], dir: f32) {
        let m = &self.spec.model;
        let (t_len, d, nh) = (m.seq_len, m.d_model, m.n_heads);
        let dh = d / nh;
        let half = dh / 2;
        let n = x.len() / d;
        for i in 0..n {
            let t = i % t_len;
            let (cos, sin) = (
                &self.rope_cos[t * half..(t + 1) * half],
                &self.rope_sin[t * half..(t + 1) * half],
            );
            let row = &mut x[i * d..(i + 1) * d];
            for h in 0..nh {
                let head = &mut row[h * dh..(h + 1) * dh];
                for j in 0..half {
                    let (a, b) = (head[j], head[j + half]);
                    let s = dir * sin[j];
                    head[j] = a * cos[j] - b * s;
                    head[j + half] = a * s + b * cos[j];
                }
            }
        }
    }

    /// Forward pass storing every activation needed by backward; returns
    /// the mean token cross-entropy.
    fn forward(&self, params: &[f32], tokens: &[i32], targets: &[i32], s: &mut Scratch) -> f32 {
        let m = &self.spec.model;
        let lay = &self.layout;
        let (b, t_len, d, f, v, nh) =
            (m.batch_size, m.seq_len, m.d_model, m.d_ff, m.vocab_size, m.n_heads);
        let n = b * t_len;
        let dh = d / nh;
        let scale = 1.0 / (dh as f32).sqrt();
        debug_assert_eq!(tokens.len(), n);

        // Embedding lookup.
        let embed = &params[lay.embed..lay.embed + v * d];
        for i in 0..n {
            let tok = tokens[i] as usize;
            s.x0[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }

        for l in 0..m.n_layers {
            let off = lay.layers[l];
            // Work around the borrow checker: split the one &mut LayerScratch
            // out of the vec, everything else is shared reads.
            let (before, rest) = s.layers.split_at_mut(l);
            let ls = &mut rest[0];
            let x_in: &[f32] = if l == 0 { &s.x0 } else { &before[l - 1].x_out };

            rmsnorm(
                &mut ls.hn_attn,
                &mut ls.rinv_attn,
                x_in,
                &params[off.attn_norm..off.attn_norm + d],
                n,
                d,
            );
            matmul(&mut ls.q, &ls.hn_attn, &params[off.wq..off.wq + d * d], n, d, d);
            matmul(&mut ls.k, &ls.hn_attn, &params[off.wk..off.wk + d * d], n, d, d);
            matmul(&mut ls.v, &ls.hn_attn, &params[off.wv..off.wv + d * d], n, d, d);
            self.rope(&mut ls.q, 1.0);
            self.rope(&mut ls.k, 1.0);

            // Causal softmax attention per (batch, head).
            for bi in 0..b {
                for h in 0..nh {
                    let pb = &mut ls.probs
                        [(bi * nh + h) * t_len * t_len..(bi * nh + h + 1) * t_len * t_len];
                    for t1 in 0..t_len {
                        let qrow = &ls.q[((bi * t_len + t1) * d + h * dh)..][..dh];
                        let prow = &mut pb[t1 * t_len..(t1 + 1) * t_len];
                        let mut mx = f32::NEG_INFINITY;
                        for (t2, p_val) in prow.iter_mut().enumerate().take(t1 + 1) {
                            let krow = &ls.k[((bi * t_len + t2) * d + h * dh)..][..dh];
                            let sc = dot(qrow, krow) * scale;
                            *p_val = sc;
                            if sc > mx {
                                mx = sc;
                            }
                        }
                        let mut z = 0.0f32;
                        for p_val in prow.iter_mut().take(t1 + 1) {
                            *p_val = (*p_val - mx).exp();
                            z += *p_val;
                        }
                        let inv = 1.0 / z;
                        for p_val in prow.iter_mut().take(t1 + 1) {
                            *p_val *= inv;
                        }
                        for p_val in prow.iter_mut().skip(t1 + 1) {
                            *p_val = 0.0;
                        }
                        // ctx row = Σ_t2 p·v_t2
                        let crow = &mut ls.ctx[((bi * t_len + t1) * d + h * dh)..][..dh];
                        crow.fill(0.0);
                        for t2 in 0..=t1 {
                            let vrow = &ls.v[((bi * t_len + t2) * d + h * dh)..][..dh];
                            axpy(crow, pb[t1 * t_len + t2], vrow);
                        }
                    }
                }
            }

            // x_mid = x_in + ctx @ wo (matmul into x_mid, then add residual).
            matmul(&mut ls.x_mid, &ls.ctx, &params[off.wo..off.wo + d * d], n, d, d);
            vecops::add_assign(&mut ls.x_mid, x_in);

            // SwiGLU MLP: x_out = x_mid + (silu(x̂@w1) ⊙ (x̂@w3)) @ w2.
            rmsnorm(
                &mut ls.hn_mlp,
                &mut ls.rinv_mlp,
                &ls.x_mid,
                &params[off.mlp_norm..off.mlp_norm + d],
                n,
                d,
            );
            matmul(&mut ls.u, &ls.hn_mlp, &params[off.w1..off.w1 + d * f], n, d, f);
            matmul(&mut ls.g3, &ls.hn_mlp, &params[off.w3..off.w3 + d * f], n, d, f);
            for i in 0..n * f {
                let u = ls.u[i];
                let sig = 1.0 / (1.0 + (-u).exp());
                ls.s[i] = u * sig * ls.g3[i];
            }
            matmul(&mut ls.x_out, &ls.s, &params[off.w2..off.w2 + f * d], n, f, d);
            vecops::add_assign(&mut ls.x_out, &ls.x_mid);
        }

        // Final norm + untied LM head + mean token cross-entropy.
        let x_last: &[f32] =
            if m.n_layers == 0 { &s.x0 } else { &s.layers[m.n_layers - 1].x_out };
        rmsnorm(
            &mut s.xf,
            &mut s.rinv_f,
            x_last,
            &params[lay.final_norm..lay.final_norm + d],
            n,
            d,
        );
        matmul(&mut s.logits, &s.xf, &params[lay.lm_head..lay.lm_head + d * v], n, d, v);
        let mut loss = 0.0f64;
        for i in 0..n {
            let row = &s.logits[i * v..(i + 1) * v];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let z: f32 = row.iter().map(|&x| (x - mx).exp()).sum();
            let logz = mx + z.ln();
            loss += (logz - row[targets[i] as usize]) as f64;
        }
        (loss / n as f64) as f32
    }

    /// Backward pass into `s.grad` (overwritten). Must be called right
    /// after [`NativeBackend::forward`] on the same scratch.
    fn backward(&self, params: &[f32], tokens: &[i32], targets: &[i32], s: &mut Scratch) {
        let m = &self.spec.model;
        let lay = &self.layout;
        let (b, t_len, d, f, v, nh) =
            (m.batch_size, m.seq_len, m.d_model, m.d_ff, m.vocab_size, m.n_heads);
        let n = b * t_len;
        let dh = d / nh;
        let scale = 1.0 / (dh as f32).sqrt();

        s.grad.fill(0.0);

        // dlogits in place: (softmax − onehot) / n.
        let inv_n = 1.0 / n as f32;
        for i in 0..n {
            let row = &mut s.logits[i * v..(i + 1) * v];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let mut z = 0.0f32;
            for x in row.iter_mut() {
                *x = (*x - mx).exp();
                z += *x;
            }
            let inv_z = 1.0 / z;
            for x in row.iter_mut() {
                *x *= inv_z * inv_n;
            }
            row[targets[i] as usize] -= inv_n;
        }

        // LM head: d_xf = dlogits @ lm_headᵀ; g_lm += xfᵀ @ dlogits.
        let lm = &params[lay.lm_head..lay.lm_head + d * v];
        matmul_bt(&mut s.d_h, &s.logits, lm, n, d, v);
        matmul_at_acc(&mut s.grad[lay.lm_head..lay.lm_head + d * v], &s.xf, &s.logits, n, d, v);

        // Final RMSNorm (d_x accumulates; start from zero).
        let x_last: &[f32] =
            if m.n_layers == 0 { &s.x0 } else { &s.layers[m.n_layers - 1].x_out };
        s.d_x.fill(0.0);
        rmsnorm_backward(
            &mut s.d_x,
            &mut s.grad[lay.final_norm..lay.final_norm + d],
            &s.d_h,
            x_last,
            &s.rinv_f,
            &params[lay.final_norm..lay.final_norm + d],
            n,
            d,
        );

        for l in (0..m.n_layers).rev() {
            let off = lay.layers[l];
            let (before, rest) = s.layers.split_at(l);
            let ls = &rest[0];
            let x_in: &[f32] = if l == 0 { &s.x0 } else { &before[l - 1].x_out };

            // ---- MLP block backward: x_out = x_mid + s@w2.
            // d_s = d_x @ w2ᵀ; g_w2 += sᵀ @ d_x.
            matmul_bt(&mut s.d_s, &s.d_x, &params[off.w2..off.w2 + f * d], n, f, d);
            matmul_at_acc(&mut s.grad[off.w2..off.w2 + f * d], &ls.s, &s.d_x, n, f, d);
            // s = silu(u) ⊙ g3.
            for i in 0..n * f {
                let u = ls.u[i];
                let sig = 1.0 / (1.0 + (-u).exp());
                let silu = u * sig;
                s.d_g3[i] = s.d_s[i] * silu;
                s.d_u[i] = s.d_s[i] * ls.g3[i] * (sig * (1.0 + u * (1.0 - sig)));
            }
            // d_hn = d_u @ w1ᵀ + d_g3 @ w3ᵀ; weight grads.
            matmul_bt(&mut s.d_h, &s.d_u, &params[off.w1..off.w1 + d * f], n, d, f);
            matmul_bt(&mut s.d_res, &s.d_g3, &params[off.w3..off.w3 + d * f], n, d, f);
            vecops::add_assign(&mut s.d_h, &s.d_res);
            matmul_at_acc(&mut s.grad[off.w1..off.w1 + d * f], &ls.hn_mlp, &s.d_u, n, d, f);
            matmul_at_acc(&mut s.grad[off.w3..off.w3 + d * f], &ls.hn_mlp, &s.d_g3, n, d, f);
            // RMSNorm backward at x_mid; residual adds d_x through.
            rmsnorm_backward(
                &mut s.d_x,
                &mut s.grad[off.mlp_norm..off.mlp_norm + d],
                &s.d_h,
                &ls.x_mid,
                &ls.rinv_mlp,
                &params[off.mlp_norm..off.mlp_norm + d],
                n,
                d,
            );

            // ---- Attention block backward: x_mid = x_in + ctx@wo.
            // d_ctx = d_x @ woᵀ; g_wo += ctxᵀ @ d_x.
            matmul_bt(&mut s.d_h, &s.d_x, &params[off.wo..off.wo + d * d], n, d, d);
            matmul_at_acc(&mut s.grad[off.wo..off.wo + d * d], &ls.ctx, &s.d_x, n, d, d);
            // Per (batch, head): softmax/score backward.
            s.d_q.fill(0.0);
            s.d_k.fill(0.0);
            s.d_v.fill(0.0);
            for bi in 0..b {
                for h in 0..nh {
                    let pb = &ls.probs
                        [(bi * nh + h) * t_len * t_len..(bi * nh + h + 1) * t_len * t_len];
                    // dP = d_ctx @ vᵀ ; d_v += Pᵀ @ d_ctx.
                    for t1 in 0..t_len {
                        let dctx = &s.d_h[((bi * t_len + t1) * d + h * dh)..][..dh];
                        let prow = &pb[t1 * t_len..(t1 + 1) * t_len];
                        let dprow = &mut s.d_p[t1 * t_len..(t1 + 1) * t_len];
                        for t2 in 0..=t1 {
                            let vrow = &ls.v[((bi * t_len + t2) * d + h * dh)..][..dh];
                            dprow[t2] = dot(dctx, vrow);
                            let dvrow = &mut s.d_v[((bi * t_len + t2) * d + h * dh)..][..dh];
                            axpy(dvrow, prow[t2], dctx);
                        }
                        // dS = P ⊙ (dP − ⟨dP, P⟩) on the causal prefix.
                        let mut acc = 0.0f32;
                        for t2 in 0..=t1 {
                            acc += dprow[t2] * prow[t2];
                        }
                        for t2 in 0..=t1 {
                            dprow[t2] = prow[t2] * (dprow[t2] - acc);
                        }
                        // d_q row += dS @ K · scale; d_k rows += dSᵀ @ q · scale.
                        let qrow = &ls.q[((bi * t_len + t1) * d + h * dh)..][..dh];
                        // (d_q and q are disjoint buffers; split borrows.)
                        for t2 in 0..=t1 {
                            let w = dprow[t2] * scale;
                            let krow = &ls.k[((bi * t_len + t2) * d + h * dh)..][..dh];
                            let dqrow = &mut s.d_q[((bi * t_len + t1) * d + h * dh)..][..dh];
                            axpy(dqrow, w, krow);
                            let dkrow = &mut s.d_k[((bi * t_len + t2) * d + h * dh)..][..dh];
                            axpy(dkrow, w, qrow);
                        }
                    }
                }
            }
            // Undo RoPE (transpose rotation) on d_q/d_k.
            self.rope(&mut s.d_q, -1.0);
            self.rope(&mut s.d_k, -1.0);
            // d_hn = d_q@wqᵀ + d_k@wkᵀ + d_v@wvᵀ; weight grads.
            matmul_bt(&mut s.d_h, &s.d_q, &params[off.wq..off.wq + d * d], n, d, d);
            matmul_bt(&mut s.d_res, &s.d_k, &params[off.wk..off.wk + d * d], n, d, d);
            vecops::add_assign(&mut s.d_h, &s.d_res);
            matmul_bt(&mut s.d_res, &s.d_v, &params[off.wv..off.wv + d * d], n, d, d);
            vecops::add_assign(&mut s.d_h, &s.d_res);
            matmul_at_acc(&mut s.grad[off.wq..off.wq + d * d], &ls.hn_attn, &s.d_q, n, d, d);
            matmul_at_acc(&mut s.grad[off.wk..off.wk + d * d], &ls.hn_attn, &s.d_k, n, d, d);
            matmul_at_acc(&mut s.grad[off.wv..off.wv + d * d], &ls.hn_attn, &s.d_v, n, d, d);
            // RMSNorm backward at x_in; residual passthrough stays in d_x.
            rmsnorm_backward(
                &mut s.d_x,
                &mut s.grad[off.attn_norm..off.attn_norm + d],
                &s.d_h,
                x_in,
                &ls.rinv_attn,
                &params[off.attn_norm..off.attn_norm + d],
                n,
                d,
            );
        }

        // Embedding scatter-add.
        let gemb = &mut s.grad[lay.embed..lay.embed + v * d];
        for i in 0..n {
            let tok = tokens[i] as usize;
            axpy(&mut gemb[tok * d..(tok + 1) * d], 1.0, &s.d_x[i * d..(i + 1) * d]);
        }
    }

    /// Fused decoupled AdamW with bias correction (8-lane unrolled), same
    /// formula as the Pallas kernel in python/compile/kernels/elementwise.
    fn adamw(&self, st: &mut TrainState, grad: &[f32], lr: f32) {
        let t = &self.spec.train;
        let (b1, b2, eps, wd) =
            (t.beta1 as f32, t.beta2 as f32, t.eps as f32, t.weight_decay as f32);
        let step1 = (st.step + 1) as f64; // 1-indexed for bias correction
        let bc1 = (1.0 - (t.beta1).powf(step1)) as f32;
        let bc2 = (1.0 - (t.beta2).powf(step1)) as f32;
        const LANES: usize = vecops::LANES;
        let mut pc = st.params.chunks_exact_mut(LANES);
        let mut mc = st.m.chunks_exact_mut(LANES);
        let mut vc = st.v.chunks_exact_mut(LANES);
        let mut gc = grad.chunks_exact(LANES);
        for (((p, mm), vv), g) in (&mut pc).zip(&mut mc).zip(&mut vc).zip(&mut gc) {
            for i in 0..LANES {
                let m2 = b1 * mm[i] + (1.0 - b1) * g[i];
                let v2 = b2 * vv[i] + (1.0 - b2) * g[i] * g[i];
                mm[i] = m2;
                vv[i] = v2;
                let upd = (m2 / bc1) / ((v2 / bc2).sqrt() + eps) + wd * p[i];
                p[i] -= lr * upd;
            }
        }
        for (((p, mm), vv), g) in pc
            .into_remainder()
            .iter_mut()
            .zip(mc.into_remainder().iter_mut())
            .zip(vc.into_remainder().iter_mut())
            .zip(gc.remainder())
        {
            let m2 = b1 * *mm + (1.0 - b1) * g;
            let v2 = b2 * *vv + (1.0 - b2) * g * g;
            *mm = m2;
            *vv = v2;
            let upd = (m2 / bc1) / ((v2 / bc2).sqrt() + eps) + wd * *p;
            *p -= lr * upd;
        }
    }

    fn check_batch(&self, tokens: &[i32], targets: &[i32]) -> anyhow::Result<()> {
        let n = self.spec.model.batch_size * self.spec.model.seq_len;
        anyhow::ensure!(
            tokens.len() == n && targets.len() == n,
            "batch shape mismatch: got {}/{} tokens, want {n}",
            tokens.len(),
            targets.len()
        );
        let v = self.spec.model.vocab_size as i32;
        anyhow::ensure!(
            tokens.iter().chain(targets).all(|&x| x >= 0 && x < v),
            "token id out of vocabulary range"
        );
        Ok(())
    }
}

/// Deterministic scaled-normal init (model.py init_flat): std 0.02,
/// residual-out projections (wo/w2) scaled by 1/√(2·n_layers), norms at 1.
fn init_flat(spec: &NativeSpec, layout: &Layout) -> Vec<f32> {
    let mut rng = Rng::new(spec.seed, 0x1217);
    let mut flat = vec![0.0f32; layout.total];
    let resid_scale = 1.0 / (2.0 * spec.model.n_layers as f64).sqrt();
    for leaf in &layout.leaves {
        let sl = &mut flat[leaf.offset..leaf.offset + leaf.size];
        if leaf.name.ends_with("_norm") {
            sl.fill(1.0);
        } else {
            let mut std = 0.02;
            if leaf.name.ends_with(".wo") || leaf.name.ends_with(".w2") {
                std *= resid_scale;
            }
            for x in sl.iter_mut() {
                *x = (rng.next_gaussian() * std) as f32;
            }
        }
    }
    flat
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native".into()
    }

    fn model(&self) -> &ModelMeta {
        &self.spec.model
    }

    fn param_count(&self) -> usize {
        self.layout.total
    }

    fn fragments(&self) -> &FragmentTable {
        &self.layout.frags
    }

    fn init_params(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.init.clone())
    }

    fn create_worker(&self) -> anyhow::Result<WorkerHandle> {
        Ok(WorkerHandle::new(NativeWorker {
            state: TrainState::new(self.init.clone()),
            scratch: Scratch::new(&self.spec.model, self.layout.total, true),
        }))
    }

    fn train_step(
        &self,
        w: &mut WorkerHandle,
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<f32> {
        self.check_batch(tokens, targets)?;
        let nw = self.worker_mut(w)?;
        let (st, sc) = (&mut nw.state, &mut nw.scratch);
        let loss = self.forward(&st.params, tokens, targets, sc);
        self.backward(&st.params, tokens, targets, sc);
        let lr = lr_schedule(st.step, &self.spec.train);
        self.adamw(st, &sc.grad, lr);
        st.step += 1;
        Ok(loss)
    }

    fn eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> anyhow::Result<f32> {
        self.check_batch(tokens, targets)?;
        anyhow::ensure!(params.len() == self.layout.total, "param vector length mismatch");
        let mut sc = self
            .eval_scratch
            .lock()
            .expect("eval scratch pool poisoned")
            .pop()
            .unwrap_or_else(|| {
                Box::new(Scratch::new(&self.spec.model, self.layout.total, false))
            });
        let loss = self.forward(params, tokens, targets, &mut sc);
        self.eval_scratch
            .lock()
            .expect("eval scratch pool poisoned")
            .push(sc);
        Ok(loss)
    }

    fn read_fragment(&self, w: &WorkerHandle, frag: Fragment, out: &mut [f32]) -> anyhow::Result<()> {
        out.copy_from_slice(&self.worker(w)?.state.params[frag.range()]);
        Ok(())
    }

    fn write_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        data: &[f32],
    ) -> anyhow::Result<()> {
        self.worker_mut(w)?.state.params[frag.range()].copy_from_slice(data);
        Ok(())
    }

    fn delay_comp_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) -> anyhow::Result<()> {
        let local = &mut self.worker_mut(w)?.state.params[frag.range()];
        vecops::fused_delay_comp(local, theta_g, theta_tp, tau, h, lambda);
        Ok(())
    }

    fn alpha_blend_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        alpha: f32,
    ) -> anyhow::Result<()> {
        let local = &mut self.worker_mut(w)?.state.params[frag.range()];
        vecops::fused_alpha_blend(local, theta_g, alpha);
        Ok(())
    }

    fn mean_params(&self, ws: &[WorkerHandle], out: &mut [f32]) -> anyhow::Result<()> {
        let rows = validated_rows::<NativeWorker, _>(ws, |w| w.state.params.as_slice())?;
        vecops::fused_mean_iter(out, rows);
        Ok(())
    }

    fn pseudo_mean_fragment(
        &self,
        ws: &[WorkerHandle],
        frag: Fragment,
        theta_g: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let rows =
            validated_rows::<NativeWorker, _>(ws, move |w| &w.state.params[frag.range()])?;
        vecops::fused_pseudo_mean_iter(out, rows, theta_g);
        Ok(())
    }

    fn read_state(&self, w: &WorkerHandle, dst: &mut TrainState) -> anyhow::Result<()> {
        dst.clone_from(&self.worker(w)?.state);
        Ok(())
    }

    fn write_state(&self, w: &mut WorkerHandle, src: &TrainState) -> anyhow::Result<()> {
        self.worker_mut(w)?.state.clone_from(src);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_spec() -> NativeSpec {
        NativeSpec {
            name: "micro".into(),
            model: model_meta(8, 4, 1, 2, 8, 4, 1),
            train: train_meta(1e-2, 2, 100),
            n_fragments: 1,
            seed: 3,
        }
    }

    fn batch(b: &NativeBackend, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let m = b.model();
        let n = m.batch_size * m.seq_len;
        let mut rng = Rng::new(seed, 0);
        let tokens: Vec<i32> =
            (0..n).map(|_| rng.below(m.vocab_size as u64) as i32).collect();
        let mut targets = tokens.clone();
        targets.rotate_left(1);
        (tokens, targets)
    }

    #[test]
    fn layout_tiles_and_matches_param_count() {
        let b = NativeBackend::preset("tiny").unwrap();
        let frags = b.fragments();
        let total: usize = (0..frags.k()).map(|p| frags.get(p).size).sum();
        assert_eq!(total, b.param_count());
        let leaf_total: usize = b.leaves().iter().map(|l| l.size).sum();
        assert_eq!(leaf_total, b.param_count());
        // Leaves stay inside their fragments.
        for l in b.leaves() {
            let f = frags.get(l.fragment);
            assert!(l.offset >= f.offset && l.offset + l.size <= f.offset + f.size);
        }
    }

    #[test]
    fn init_is_deterministic_and_norms_are_one() {
        let a = NativeBackend::preset("tiny").unwrap();
        let b = NativeBackend::preset("tiny").unwrap();
        assert_eq!(a.init_params().unwrap(), b.init_params().unwrap());
        let init = a.init_params().unwrap();
        let norm = a.leaves().iter().find(|l| l.name.ends_with("attn_norm")).unwrap();
        assert!(init[norm.offset..norm.offset + norm.size].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let be = NativeBackend::new(micro_spec()).unwrap();
        let (tokens, targets) = batch(&be, 5);
        let params = be.init_params().unwrap();
        let mut sc = Scratch::new(&be.spec.model, be.layout.total, true);
        let _ = be.forward(&params, &tokens, &targets, &mut sc);
        be.backward(&params, &tokens, &targets, &mut sc);
        let grad = sc.grad.clone();
        let mut rng = Rng::new(11, 0);
        let eps = 3e-3f32;
        let mut checked = 0;
        while checked < 40 {
            let i = rng.below(params.len() as u64) as usize;
            let mut pp = params.clone();
            pp[i] += eps;
            let lp = be.forward(&pp, &tokens, &targets, &mut sc);
            pp[i] = params[i] - eps;
            let lm = be.forward(&pp, &tokens, &targets, &mut sc);
            let fd = (lp - lm) / (2.0 * eps);
            let tol = 2e-2 * (1.0 + fd.abs().max(grad[i].abs()));
            assert!(
                (fd - grad[i]).abs() < tol,
                "param {i}: fd {fd} vs analytic {}",
                grad[i]
            );
            checked += 1;
        }
    }

    #[test]
    fn train_step_learns_fixed_batch() {
        let be = NativeBackend::preset("tiny").unwrap();
        let mut w = be.create_worker().unwrap();
        let (tokens, targets) = batch(&be, 7);
        let first = be.train_step(&mut w, &tokens, &targets).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = be.train_step(&mut w, &tokens, &targets).unwrap();
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first - 0.1, "no learning: {first} -> {last}");
        assert_eq!(w.get::<NativeWorker>().unwrap().state.step, 31);
    }

    #[test]
    fn eval_at_init_is_near_uniform_and_deterministic() {
        let be = NativeBackend::preset("tiny").unwrap();
        let (tokens, targets) = batch(&be, 9);
        let params = be.init_params().unwrap();
        let a = be.eval_loss(&params, &tokens, &targets).unwrap();
        let b = be.eval_loss(&params, &tokens, &targets).unwrap();
        assert_eq!(a, b);
        let uniform = (be.model().vocab_size as f32).ln();
        assert!((a - uniform).abs() < 0.5, "init loss {a} vs ln V {uniform}");
    }

    #[test]
    fn train_steps_are_deterministic() {
        let run = || {
            let be = NativeBackend::preset("tiny").unwrap();
            let mut w = be.create_worker().unwrap();
            let (tokens, targets) = batch(&be, 13);
            let mut losses = Vec::new();
            for _ in 0..5 {
                losses.push(be.train_step(&mut w, &tokens, &targets).unwrap());
            }
            let mut st = TrainState::new(vec![0.0; be.param_count()]);
            be.read_state(&w, &mut st).unwrap();
            (losses, st.params)
        };
        let (l1, p1) = run();
        let (l2, p2) = run();
        assert_eq!(l1, l2);
        assert_eq!(p1, p2);
    }

    #[test]
    fn lr_schedule_warmup_then_cosine() {
        let t = train_meta(1e-3, 10, 100);
        assert!((lr_schedule(0, &t) - 1e-4).abs() < 1e-9);
        assert!((lr_schedule(9, &t) - 1e-3).abs() < 1e-9);
        // Past warmup the schedule decays toward min_lr_ratio·lr.
        assert!(lr_schedule(50, &t) < 1e-3);
        let end = lr_schedule(99, &t);
        assert!(end >= 1e-4 - 1e-9 && end < 2e-4, "end lr {end}");
    }

    #[test]
    fn batch_shape_and_vocab_validated() {
        let be = NativeBackend::preset("tiny").unwrap();
        let mut w = be.create_worker().unwrap();
        assert!(be.train_step(&mut w, &[0; 3], &[0; 3]).is_err());
        let n = be.model().batch_size * be.model().seq_len;
        let bad = vec![be.model().vocab_size as i32; n];
        assert!(be.train_step(&mut w, &bad, &bad).is_err());
    }
}
