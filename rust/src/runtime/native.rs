//! Native execution backend: a pure-rust LLaMA-style tiny transformer with
//! hand-written forward/backward/AdamW kernels in the `util::vecops` 8-lane
//! style — the zero-artifact twin of the python AOT model
//! (python/compile/model.py), so every end-to-end scenario (experiments,
//! wallclock sweeps, outage robustness) runs on any machine.
//!
//! Architecture (matches the artifact model leaf-for-leaf):
//! embed → N × [RMSNorm → RoPE multi-head causal attention → residual →
//! RMSNorm → SwiGLU MLP → residual] → final RMSNorm → untied LM head →
//! mean token cross-entropy. The optimizer is decoupled AdamW with bias
//! correction and the warmup+cosine LR schedule computed from the same
//! `TrainMeta` fields the artifacts bake in.
//!
//! Intra-step data parallelism (DESIGN.md §Parallelism): each worker's
//! batch is split into [`row_shards`] whole-sequence shards — a function
//! of the model shape only, never the thread count. Every shard owns a
//! private [`ShardScratch`] (activations for its rows plus a full-size
//! gradient buffer), so forward/backward over shards is embarrassingly
//! parallel; when a compute pool is installed via
//! `Backend::set_compute_pool` the shards run on pool threads (a *nested*
//! scope when the trainer already fanned out per worker). A second,
//! orthogonal axis makes batch-1 runs scale: inside one shard, every
//! dense matmul (QKV/O projections, MLP w1/w3/w2, the LM head), the
//! embedding gather/scatter and the fused softmax–cross-entropy are
//! partitioned over *output columns* into [`col_shards`] fixed chunks —
//! again shape-only, never thread-count-dependent — dispatched on the
//! same pool whenever threads outnumber the row tasks. All reductions
//! are fixed-order: the loss is the ascending-shard sum of per-shard f64
//! sums (each itself an ascending-chunk combine, see
//! [`softmax_xent_cols`]), and AdamW folds the per-element
//! shard-gradient sum into its update loop — the identical arithmetic
//! runs serial and pooled, so results are bit-identical for any
//! `--threads` value.
//!
//! Resident-state discipline (DESIGN.md §Backend): each worker owns its
//! flat (θ, m, v, step) *and* all shard scratch, allocated once at
//! `create_worker` — a steady-state serial `train_step` performs **zero**
//! heap allocations (tests/alloc_steady_state.rs proves it with a counting
//! allocator); the pooled path queues one boxed task per shard per step.
//! Evaluation borrows shard sets from a recycling pool so concurrent
//! validation batches stay allocation-free after warm-up.
//!
//! The flat layout is fragment-major over the same strided depth partition
//! as python/compile/config.flat_layout: layer l joins fragment l mod K,
//! the embedding joins fragment 0, final norm + LM head join fragment K−1.

use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::fragments::{Fragment, FragmentTable};
use crate::runtime::backend::{validated_rows, Backend, WorkerHandle};
use crate::runtime::engine::TrainState;
use crate::runtime::meta::{LeafMeta, ModelMeta, TrainMeta};
use crate::util::threadpool::{ScopedTask, WorkerPool};
use crate::util::vecops::{self, axpy, dot};
use crate::util::Rng;

const RMS_EPS: f32 = 1e-6;
const ROPE_THETA: f32 = 10000.0;

/// Upper bound on row shards per worker (8 matches the vecops lane width;
/// beyond it the per-shard full-size gradient buffers dominate memory).
pub const MAX_ROW_SHARDS: usize = 8;

/// Number of row shards one worker's batch is split into. A function of
/// the model shape only — never the thread count — so the computation and
/// reduction structure (and therefore every result bit) is identical for
/// any `--threads` value; fewer threads just run the same shards with
/// less overlap. Shards hold whole sequences, so causal attention never
/// crosses a shard boundary.
pub fn row_shards(batch_size: usize) -> usize {
    batch_size.clamp(1, MAX_ROW_SHARDS)
}

/// Minimum output columns per column chunk: below one 16-float tile the
/// per-job dispatch overhead beats the matmul work saved (and the tiled
/// kernels' NR=16 main loop would never engage).
pub const MIN_COL_CHUNK: usize = 16;

/// Upper bound on column chunks per operator (mirrors [`MAX_ROW_SHARDS`]).
pub const MAX_COL_SHARDS: usize = 8;

/// Number of column chunks a `cols`-wide operator output is split into.
/// A function of the width only — never the thread count — so the chunk
/// grid, and with it every fixed-order cross-chunk combine in
/// [`softmax_xent_cols`], is identical for any `--threads` value.
pub fn col_shards(cols: usize) -> usize {
    (cols / MIN_COL_CHUNK).clamp(1, MAX_COL_SHARDS)
}

/// Column range of chunk `s` out of `shards` over a `cols`-wide output:
/// contiguous, sized as evenly as integer division allows.
pub fn col_chunk(cols: usize, shards: usize, s: usize) -> (usize, usize) {
    (s * cols / shards, (s + 1) * cols / shards)
}

/// Independent work units one worker's train step exposes to the pool:
/// the 2D partition of row shards × the widest operator's column chunks.
/// The trainer's thread budget multiplies its worker fan-out by this, so
/// batch-1 runs (one row shard) still claim threads for column chunks.
pub fn intra_step_units(m: &ModelMeta) -> usize {
    row_shards(m.batch_size) * col_shards(m.vocab_size.max(m.d_ff).max(m.d_model))
}

/// A raw mutable base pointer smuggled into the `Fn` column-chunk
/// closures of [`dispatch`]. Soundness rests on the `*_cols_ptr`
/// contracts: every job materializes references only inside its own
/// disjoint column range of the target buffer.
struct SendPtr<T>(*mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: see the struct docs — disjointness is the caller's contract.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `count` column-chunk jobs: boxed scoped tasks on the pool when one
/// is handed in, a plain ascending inline loop (no allocation) otherwise.
/// Jobs must write disjoint output ranges; their arithmetic never depends
/// on which thread runs them, so pool presence is pure scheduling.
fn dispatch<F: Fn(usize) + Send + Sync>(pool: Option<&WorkerPool>, count: usize, f: F) {
    match pool {
        Some(tp) if count > 1 => {
            let fr = &f;
            let tasks: Vec<ScopedTask<'_>> =
                (0..count).map(|j| Box::new(move || fr(j)) as ScopedTask<'_>).collect();
            tp.scoped(tasks);
        }
        _ => {
            for j in 0..count {
                f(j);
            }
        }
    }
}

/// Full specification of a native model + optimizer.
#[derive(Debug, Clone)]
pub struct NativeSpec {
    pub name: String,
    pub model: ModelMeta,
    pub train: TrainMeta,
    pub n_fragments: usize,
    pub seed: u64,
}

impl NativeSpec {
    /// Named presets. These mirror the artifact presets' architecture
    /// family but are scaled so the full three-method comparison runs in
    /// seconds on a laptop CPU with no artifacts present.
    pub fn preset(name: &str) -> anyhow::Result<NativeSpec> {
        let (model, train, k) = match name {
            "tiny" => (
                model_meta(64, 32, 2, 2, 64, 16, 2),
                train_meta(1e-3, 10, 200),
                2,
            ),
            "exp" => (
                model_meta(256, 64, 4, 4, 128, 32, 4),
                train_meta(2e-3, 20, 1200),
                4,
            ),
            "e2e" => (
                model_meta(512, 128, 6, 4, 256, 64, 4),
                train_meta(1e-3, 50, 2000),
                4,
            ),
            other => anyhow::bail!("unknown native preset '{other}' (tiny|exp|e2e)"),
        };
        Ok(NativeSpec { name: name.to_string(), model, train, n_fragments: k, seed: 0 })
    }
}

fn model_meta(
    vocab: usize,
    d: usize,
    layers: usize,
    heads: usize,
    ff: usize,
    seq: usize,
    batch: usize,
) -> ModelMeta {
    ModelMeta {
        vocab_size: vocab,
        d_model: d,
        n_layers: layers,
        n_heads: heads,
        d_ff: ff,
        seq_len: seq,
        batch_size: batch,
        use_pallas_attention: false,
    }
}

fn train_meta(lr: f64, warmup: u32, total: u32) -> TrainMeta {
    TrainMeta {
        lr,
        warmup_steps: warmup,
        total_steps: total,
        weight_decay: 0.1,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        min_lr_ratio: 0.1,
    }
}

/// Warmup + cosine LR schedule — same formula the train_step artifact bakes
/// in (python/compile/train.lr_schedule), with `step` 0-indexed.
pub fn lr_schedule(step: u32, t: &TrainMeta) -> f32 {
    let s = step as f64;
    let warm = (t.warmup_steps as f64).max(1.0);
    if (step as f64) < t.warmup_steps as f64 {
        return (t.lr * (s + 1.0) / warm) as f32;
    }
    let total = t.total_steps as f64;
    let prog = ((s - warm) / (total - warm).max(1.0)).clamp(0.0, 1.0);
    let cos = 0.5 * (1.0 + (std::f64::consts::PI * prog).cos());
    (t.lr * (t.min_lr_ratio + (1.0 - t.min_lr_ratio) * cos)) as f32
}

// ---------------------------------------------------------------------
// Flat layout (fragment-major strided depth partition)
// ---------------------------------------------------------------------

/// Offsets of one decoder block's leaves in the flat vector.
#[derive(Debug, Clone, Copy)]
struct LayerOff {
    attn_norm: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    mlp_norm: usize,
    w1: usize,
    w3: usize,
    w2: usize,
}

#[derive(Debug, Clone)]
struct Layout {
    embed: usize,
    layers: Vec<LayerOff>,
    final_norm: usize,
    lm_head: usize,
    leaves: Vec<LeafMeta>,
    frags: FragmentTable,
    total: usize,
}

/// Strided depth partition (python/compile/config.fragment_of): layer l →
/// shard l mod K; embedding → shard 0; final norm + LM head → shard K−1.
fn fragment_of(layer: i64, k: usize) -> usize {
    match layer {
        -1 => 0,
        -2 => k - 1,
        l => l as usize % k,
    }
}

fn build_layout(spec: &NativeSpec) -> Layout {
    let (v, d, f) = (spec.model.vocab_size, spec.model.d_model, spec.model.d_ff);
    let k = spec.n_fragments;
    assert!(k >= 1 && k <= spec.model.n_layers, "need 1 <= K <= n_layers");
    // Canonical leaf table: (name, size, layer).
    let mut canon: Vec<(String, Vec<usize>, i64)> = vec![("embed".into(), vec![v, d], -1)];
    for l in 0..spec.model.n_layers {
        let li = l as i64;
        canon.push((format!("layer{l}.attn_norm"), vec![d], li));
        canon.push((format!("layer{l}.wq"), vec![d, d], li));
        canon.push((format!("layer{l}.wk"), vec![d, d], li));
        canon.push((format!("layer{l}.wv"), vec![d, d], li));
        canon.push((format!("layer{l}.wo"), vec![d, d], li));
        canon.push((format!("layer{l}.mlp_norm"), vec![d], li));
        canon.push((format!("layer{l}.w1"), vec![d, f], li));
        canon.push((format!("layer{l}.w3"), vec![d, f], li));
        canon.push((format!("layer{l}.w2"), vec![f, d], li));
    }
    canon.push(("final_norm".into(), vec![d], -2));
    canon.push(("lm_head".into(), vec![d, v], -2));

    // Fragment-major packing.
    let mut leaves: Vec<LeafMeta> = Vec::new();
    let mut sizes = vec![0usize; k];
    let mut off = 0usize;
    for p in 0..k {
        let frag_off = off;
        for (name, shape, layer) in &canon {
            if fragment_of(*layer, k) != p {
                continue;
            }
            let size: usize = shape.iter().product();
            leaves.push(LeafMeta {
                name: name.clone(),
                shape: shape.clone(),
                offset: off,
                size,
                fragment: p,
            });
            off += size;
        }
        sizes[p] = off - frag_off;
    }
    let frags = FragmentTable::from_sizes(&sizes);

    let leaf_off = |name: &str| -> usize {
        leaves
            .iter()
            .find(|l| l.name == name)
            .unwrap_or_else(|| panic!("missing leaf {name}"))
            .offset
    };
    let layers = (0..spec.model.n_layers)
        .map(|l| LayerOff {
            attn_norm: leaf_off(&format!("layer{l}.attn_norm")),
            wq: leaf_off(&format!("layer{l}.wq")),
            wk: leaf_off(&format!("layer{l}.wk")),
            wv: leaf_off(&format!("layer{l}.wv")),
            wo: leaf_off(&format!("layer{l}.wo")),
            mlp_norm: leaf_off(&format!("layer{l}.mlp_norm")),
            w1: leaf_off(&format!("layer{l}.w1")),
            w3: leaf_off(&format!("layer{l}.w3")),
            w2: leaf_off(&format!("layer{l}.w2")),
        })
        .collect();
    Layout {
        embed: leaf_off("embed"),
        layers,
        final_norm: leaf_off("final_norm"),
        lm_head: leaf_off("lm_head"),
        leaves,
        frags,
        total: off,
    }
}

// ---------------------------------------------------------------------
// Dense per-row kernels (matmuls live in util::vecops since the tiling)
// ---------------------------------------------------------------------

/// y[i] = x[i] · rinv(row) · gain — saves 1/rms per row for backward.
fn rmsnorm(y: &mut [f32], rinv: &mut [f32], x: &[f32], gain: &[f32], n: usize, d: usize) {
    for i in 0..n {
        let xr = &x[i * d..(i + 1) * d];
        let ms = dot(xr, xr) / d as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        rinv[i] = r;
        let yr = &mut y[i * d..(i + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * r * gain[j];
        }
    }
}

/// RMSNorm backward: accumulates dx into `dx_acc` (residual-friendly) and
/// the gain gradient into `dgain`.
fn rmsnorm_backward(
    dx_acc: &mut [f32],
    dgain: &mut [f32],
    dy: &[f32],
    x: &[f32],
    rinv: &[f32],
    gain: &[f32],
    n: usize,
    d: usize,
) {
    for i in 0..n {
        let (xr, dyr) = (&x[i * d..(i + 1) * d], &dy[i * d..(i + 1) * d]);
        let r = rinv[i];
        // t = dy ⊙ gain; dx = r·t − x·(r³/D)·⟨t, x⟩; dgain += dy ⊙ x · r.
        let mut tx = 0.0f32;
        for j in 0..d {
            tx += dyr[j] * gain[j] * xr[j];
        }
        let c = r * r * r * tx / d as f32;
        let dxr = &mut dx_acc[i * d..(i + 1) * d];
        for j in 0..d {
            dxr[j] += r * dyr[j] * gain[j] - c * xr[j];
            dgain[j] += dyr[j] * xr[j] * r;
        }
    }
}

// ---------------------------------------------------------------------
// Column-chunked softmax–cross-entropy
// ---------------------------------------------------------------------

/// Scratch for [`softmax_xent_cols`]: per-chunk partials plus the combined
/// per-row statistics, sized for `n` rows at the [`col_shards`]`(v)` grid.
#[derive(Debug)]
pub struct XentScratch {
    /// Per-(chunk, row) partial maxima, chunk-major [C·n].
    cmax: Vec<f32>,
    /// Per-(chunk, row) f64 partial partition sums, chunk-major [C·n].
    zpart: Vec<f64>,
    /// Combined per-row maxima [n].
    mx: Vec<f32>,
    /// Combined per-row f64 partition sums [n].
    z: Vec<f64>,
    /// Target logits, saved before the exp phase overwrites them [n].
    tgt: Vec<f32>,
}

impl XentScratch {
    pub fn new(n: usize, v: usize) -> XentScratch {
        let c = col_shards(v);
        XentScratch {
            cmax: vec![0.0; c * n],
            zpart: vec![0.0; c * n],
            mx: vec![0.0; n],
            z: vec![0.0; n],
            tgt: vec![0.0; n],
        }
    }
}

/// Fused softmax–cross-entropy over `targets.len()` rows of `v` logits,
/// column-chunked at the shape-only [`col_shards`]`(v)` grid: per-chunk
/// maxima and f64 partition sums run (possibly pooled) per chunk, every
/// cross-chunk combine runs serially in ascending-chunk order, and the
/// grad phase leaves `logits` holding the cross-entropy dlogits (softmax
/// scaled by `inv_n`, `inv_n` subtracted at each target). Returns the
/// summed negative log-likelihood in f64.
///
/// Determinism: the grid never depends on the pool, and max / f64-sum
/// combines are fixed-order, so the result is bit-identical for any
/// `--threads` value — and exactly equal to the single-sweep
/// [`vecops::softmax_xent`] at one chunk (within 1 ulp otherwise, from
/// the f64 reassociation of z alone; tests/native_parallel.rs).
pub fn softmax_xent_cols(
    pool: Option<&WorkerPool>,
    logits: &mut [f32],
    targets: &[i32],
    v: usize,
    inv_n: f32,
    grad: bool,
    xs: &mut XentScratch,
) -> f64 {
    let n = targets.len();
    debug_assert_eq!(logits.len(), n * v);
    let cc = col_shards(v);
    debug_assert_eq!(xs.cmax.len(), cc * n);
    // Save the target logits before the exp phase overwrites them.
    for (r, &t) in targets.iter().enumerate() {
        xs.tgt[r] = logits[r * v + t as usize];
    }
    // Phase 1: per-chunk row maxima.
    {
        let cm = SendPtr(xs.cmax.as_mut_ptr());
        let lg = &*logits;
        dispatch(pool, cc, |c| {
            let (c0, c1) = col_chunk(v, cc, c);
            // SAFETY: chunk c writes only its own [c·n, (c+1)·n) window.
            let out = unsafe { std::slice::from_raw_parts_mut(cm.0.add(c * n), n) };
            vecops::softmax_colmax(lg, v, c0, c1, out);
        });
    }
    // Serial ascending-chunk max combine (exact for any grid).
    for r in 0..n {
        let mut mx = f32::NEG_INFINITY;
        for c in 0..cc {
            let x = xs.cmax[c * n + r];
            if x > mx {
                mx = x;
            }
        }
        xs.mx[r] = mx;
    }
    // Phase 2: exp in place + per-chunk f64 partial partition sums.
    {
        let lg = SendPtr(logits.as_mut_ptr());
        let zp = SendPtr(xs.zpart.as_mut_ptr());
        let mx = &xs.mx;
        dispatch(pool, cc, |c| {
            let (c0, c1) = col_chunk(v, cc, c);
            // SAFETY: disjoint logits columns; disjoint zpart windows.
            unsafe {
                let out = std::slice::from_raw_parts_mut(zp.0.add(c * n), n);
                vecops::softmax_expsum_ptr(lg.0, n, v, c0, c1, mx, out);
            }
        });
    }
    // Serial ascending-chunk f64 sum combine + loss.
    let mut loss = 0.0f64;
    for r in 0..n {
        let mut z = 0.0f64;
        for c in 0..cc {
            z += xs.zpart[c * n + r];
        }
        xs.z[r] = z;
        loss += xs.mx[r] as f64 + z.ln() - xs.tgt[r] as f64;
    }
    // Phase 3: scale the in-place exp values into dlogits.
    if grad {
        let lg = SendPtr(logits.as_mut_ptr());
        let z = &xs.z;
        dispatch(pool, cc, |c| {
            let (c0, c1) = col_chunk(v, cc, c);
            // SAFETY: disjoint logits columns.
            unsafe { vecops::softmax_grad_ptr(lg.0, targets, v, c0, c1, z, inv_n) }
        });
    }
    loss
}

/// Embedding gather restricted to columns [c0, c1): x0[i, c0..c1) =
/// embed[tokens[i], c0..c1). Pure copies — exact for any column grid.
///
/// # Safety
///
/// `x0` points to an n×d buffer; concurrent calls must use disjoint
/// column ranges.
unsafe fn gather_cols(x0: *mut f32, embed: &[f32], tokens: &[i32], d: usize, c0: usize, c1: usize) {
    for (i, &tok) in tokens.iter().enumerate() {
        let dst = std::slice::from_raw_parts_mut(x0.add(i * d + c0), c1 - c0);
        dst.copy_from_slice(&embed[tok as usize * d + c0..tok as usize * d + c1]);
    }
}

/// Embedding scatter-add restricted to columns [c0, c1):
/// gemb[tokens[i], c0..c1) += d_x[i, c0..c1), i ascending. Repeated token
/// ids accumulate per element in the same i-ascending order for any
/// column grid, so any chunking is bit-identical to the full-width sweep.
///
/// # Safety
///
/// `gemb` points to a v×d buffer; concurrent calls must use disjoint
/// column ranges (rows may repeat — columns are the partition axis).
unsafe fn scatter_add_cols(
    gemb: *mut f32,
    d_x: &[f32],
    tokens: &[i32],
    d: usize,
    c0: usize,
    c1: usize,
) {
    for (i, &tok) in tokens.iter().enumerate() {
        let dst = std::slice::from_raw_parts_mut(gemb.add(tok as usize * d + c0), c1 - c0);
        let src = &d_x[i * d + c0..i * d + c1];
        for (a, b) in dst.iter_mut().zip(src) {
            *a += b;
        }
    }
}

// ---------------------------------------------------------------------
// ShardScratch: every buffer one row shard's forward+backward needs
// ---------------------------------------------------------------------

#[derive(Debug)]
struct LayerScratch {
    hn_attn: Vec<f32>,  // RMSNormed attention input   [n·D]
    rinv_attn: Vec<f32>,// per-row 1/rms               [n]
    q: Vec<f32>,        // post-RoPE queries           [n·D]
    k: Vec<f32>,        // post-RoPE keys              [n·D]
    v: Vec<f32>,        // values                      [n·D]
    probs: Vec<f32>,    // softmax attention           [b·nh·T·T]
    ctx: Vec<f32>,      // attention context (pre-wo)  [n·D]
    x_mid: Vec<f32>,    // residual after attention    [n·D]
    hn_mlp: Vec<f32>,   // RMSNormed MLP input         [n·D]
    rinv_mlp: Vec<f32>, // per-row 1/rms               [n]
    u: Vec<f32>,        // x@w1                        [n·F]
    g3: Vec<f32>,       // x@w3                        [n·F]
    s: Vec<f32>,        // silu(u)·g3                  [n·F]
    x_out: Vec<f32>,    // residual after MLP          [n·D]
}

/// Activations and gradients for one contiguous run of whole sequences
/// (`b` = `seqs` batch rows, n = b·T tokens). The gradient buffer is
/// full-size [P] — shards accumulate disjoint row contributions into
/// private buffers and AdamW reduces them in ascending shard order.
#[derive(Debug)]
struct ShardScratch {
    seq0: usize,       // first batch row of this shard
    seqs: usize,       // number of batch rows
    loss_sum: f64,     // un-normalized f64 token-loss sum of the shard
    x0: Vec<f32>,      // embeddings [n·D]
    layers: Vec<LayerScratch>,
    xf: Vec<f32>,      // final normed [n·D]
    rinv_f: Vec<f32>,  // [n]
    logits: Vec<f32>,  // [n·V]; left holding dlogits when forward runs with grad
    xent: XentScratch, // chunked softmax–xent partials/combines
    // backward-only (shared across layers)
    grad: Vec<f32>,    // [P]
    d_x: Vec<f32>,     // [n·D]
    d_res: Vec<f32>,   // [n·D]
    d_res2: Vec<f32>,  // [n·D] third summand of the QKV-backward scope
    d_h: Vec<f32>,     // [n·D]
    d_q: Vec<f32>,     // [n·D]
    d_k: Vec<f32>,     // [n·D]
    d_v: Vec<f32>,     // [n·D]
    d_p: Vec<f32>,     // [T·T] per (b,h)
    d_u: Vec<f32>,     // [n·F]
    d_g3: Vec<f32>,    // [n·F]
    d_s: Vec<f32>,     // [n·F]
}

impl ShardScratch {
    /// `with_backward = false` leaves the backward-only buffers (grad and
    /// the d_* family) empty — forward-only evaluation never touches them,
    /// so pooled eval shard sets stay a fraction of the train footprint.
    fn new(
        m: &ModelMeta,
        total: usize,
        seq0: usize,
        seqs: usize,
        with_backward: bool,
    ) -> ShardScratch {
        let (t, d, f, v) = (m.seq_len, m.d_model, m.d_ff, m.vocab_size);
        let n = seqs * t;
        let bw = |len: usize| if with_backward { vec![0.0; len] } else { Vec::new() };
        let layer = || LayerScratch {
            hn_attn: vec![0.0; n * d],
            rinv_attn: vec![0.0; n],
            q: vec![0.0; n * d],
            k: vec![0.0; n * d],
            v: vec![0.0; n * d],
            probs: vec![0.0; seqs * m.n_heads * t * t],
            ctx: vec![0.0; n * d],
            x_mid: vec![0.0; n * d],
            hn_mlp: vec![0.0; n * d],
            rinv_mlp: vec![0.0; n],
            u: vec![0.0; n * f],
            g3: vec![0.0; n * f],
            s: vec![0.0; n * f],
            x_out: vec![0.0; n * d],
        };
        ShardScratch {
            seq0,
            seqs,
            loss_sum: 0.0,
            x0: vec![0.0; n * d],
            layers: (0..m.n_layers).map(|_| layer()).collect(),
            xf: vec![0.0; n * d],
            rinv_f: vec![0.0; n],
            logits: vec![0.0; n * v],
            xent: XentScratch::new(n, v),
            grad: bw(total),
            d_x: bw(n * d),
            d_res: bw(n * d),
            d_res2: bw(n * d),
            d_h: bw(n * d),
            d_q: bw(n * d),
            d_k: bw(n * d),
            d_v: bw(n * d),
            d_p: bw(t * t),
            d_u: bw(n * f),
            d_g3: bw(n * f),
            d_s: bw(n * f),
        }
    }
}

/// The fixed shard partition for one batch: [`row_shards`] contiguous runs
/// of whole sequences, sized as evenly as integer division allows.
fn make_shards(m: &ModelMeta, total: usize, with_backward: bool) -> Vec<ShardScratch> {
    let s_count = row_shards(m.batch_size);
    (0..s_count)
        .map(|s| {
            let seq0 = s * m.batch_size / s_count;
            let seq1 = (s + 1) * m.batch_size / s_count;
            ShardScratch::new(m, total, seq0, seq1 - seq0, with_backward)
        })
        .collect()
}

/// One worker's resident state: flat (θ, m, v, step) plus its private
/// per-shard forward/backward scratch.
#[derive(Debug)]
pub struct NativeWorker {
    state: TrainState,
    shards: Vec<ShardScratch>,
}

/// Precomputed AdamW scalars shared by every parameter span of one step.
#[derive(Debug, Clone, Copy)]
struct AdamCoef {
    b1: f32,
    b2: f32,
    eps: f32,
    wd: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
}

/// Fused decoupled AdamW with bias correction (8-lane unrolled) over one
/// span of the flat vectors, with the per-element gradient reduced over
/// the row shards *inside* the update loop, in ascending shard order. The
/// same code runs serial (one span) and pooled (disjoint spans), so the
/// reduction order — and therefore every bit of θ/m/v — is independent of
/// the thread count. `off` is the span's offset into the flat vector.
fn adamw_span(
    coef: AdamCoef,
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    shards: &[ShardScratch],
    off: usize,
) {
    const LANES: usize = vecops::LANES;
    let AdamCoef { b1, b2, eps, wd, bc1, bc2, lr } = coef;
    let mut pc = params.chunks_exact_mut(LANES);
    let mut mc = m.chunks_exact_mut(LANES);
    let mut vc = v.chunks_exact_mut(LANES);
    let mut base = off;
    for ((p, mm), vv) in (&mut pc).zip(&mut mc).zip(&mut vc) {
        let mut g = [0.0f32; LANES];
        for sc in shards {
            let gs = &sc.grad[base..base + LANES];
            for i in 0..LANES {
                g[i] += gs[i];
            }
        }
        for i in 0..LANES {
            let m2 = b1 * mm[i] + (1.0 - b1) * g[i];
            let v2 = b2 * vv[i] + (1.0 - b2) * g[i] * g[i];
            mm[i] = m2;
            vv[i] = v2;
            let upd = (m2 / bc1) / ((v2 / bc2).sqrt() + eps) + wd * p[i];
            p[i] -= lr * upd;
        }
        base += LANES;
    }
    for (k, ((p, mm), vv)) in pc
        .into_remainder()
        .iter_mut()
        .zip(mc.into_remainder().iter_mut())
        .zip(vc.into_remainder().iter_mut())
        .enumerate()
    {
        let mut g = 0.0f32;
        for sc in shards {
            g += sc.grad[base + k];
        }
        let m2 = b1 * *mm + (1.0 - b1) * g;
        let v2 = b2 * *vv + (1.0 - b2) * g * g;
        *mm = m2;
        *vv = v2;
        let upd = (m2 / bc1) / ((v2 / bc2).sqrt() + eps) + wd * *p;
        *p -= lr * upd;
    }
}

// ---------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------

pub struct NativeBackend {
    spec: NativeSpec,
    layout: Layout,
    init: Vec<f32>,
    /// RoPE tables: cos/sin of t·freq_j, [T · dh/2] each.
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    /// Recycled eval shard sets (validation batches run concurrently).
    eval_scratch: Mutex<Vec<Vec<ShardScratch>>>,
    /// Intra-step compute pool installed by the trainer (None = serial).
    pool: RwLock<Option<Arc<WorkerPool>>>,
}

impl NativeBackend {
    pub fn new(spec: NativeSpec) -> anyhow::Result<NativeBackend> {
        anyhow::ensure!(
            spec.model.d_model % spec.model.n_heads == 0,
            "d_model must be divisible by n_heads"
        );
        let dh = spec.model.d_model / spec.model.n_heads;
        anyhow::ensure!(dh % 2 == 0, "head_dim must be even for RoPE");
        let layout = build_layout(&spec);
        let init = init_flat(&spec, &layout);
        let half = dh / 2;
        let t_len = spec.model.seq_len;
        let mut rope_cos = vec![0.0f32; t_len * half];
        let mut rope_sin = vec![0.0f32; t_len * half];
        for t in 0..t_len {
            for j in 0..half {
                let freq = 1.0 / (ROPE_THETA as f64).powf(j as f64 / half as f64);
                let ang = t as f64 * freq;
                rope_cos[t * half + j] = ang.cos() as f32;
                rope_sin[t * half + j] = ang.sin() as f32;
            }
        }
        Ok(NativeBackend {
            spec,
            layout,
            init,
            rope_cos,
            rope_sin,
            eval_scratch: Mutex::new(Vec::new()),
            pool: RwLock::new(None),
        })
    }

    pub fn preset(name: &str) -> anyhow::Result<NativeBackend> {
        NativeBackend::new(NativeSpec::preset(name)?)
    }

    pub fn spec(&self) -> &NativeSpec {
        &self.spec
    }

    pub fn leaves(&self) -> &[LeafMeta] {
        &self.layout.leaves
    }

    fn worker<'a>(&self, w: &'a WorkerHandle) -> anyhow::Result<&'a NativeWorker> {
        w.get::<NativeWorker>()
    }

    fn worker_mut<'a>(&self, w: &'a mut WorkerHandle) -> anyhow::Result<&'a mut NativeWorker> {
        w.get_mut::<NativeWorker>()
    }

    fn compute_pool(&self) -> Option<Arc<WorkerPool>> {
        self.pool.read().expect("compute pool poisoned").clone()
    }

    // ------------------------------------------------------------------
    // forward / backward (per row shard)
    // ------------------------------------------------------------------

    /// RoPE rotation applied in place to every head slice of `x` [n·D].
    /// `dir` = 1.0 forward, −1.0 backward (the transpose rotation). Works
    /// on shard slices unchanged because shards hold whole sequences, so
    /// the position of row i is still i mod T.
    fn rope(&self, x: &mut [f32], dir: f32) {
        let m = &self.spec.model;
        let (t_len, d, nh) = (m.seq_len, m.d_model, m.n_heads);
        let dh = d / nh;
        let half = dh / 2;
        let n = x.len() / d;
        for i in 0..n {
            let t = i % t_len;
            let (cos, sin) = (
                &self.rope_cos[t * half..(t + 1) * half],
                &self.rope_sin[t * half..(t + 1) * half],
            );
            let row = &mut x[i * d..(i + 1) * d];
            for h in 0..nh {
                let head = &mut row[h * dh..(h + 1) * dh];
                for j in 0..half {
                    let (a, b) = (head[j], head[j + half]);
                    let s = dir * sin[j];
                    head[j] = a * cos[j] - b * s;
                    head[j + half] = a * s + b * cos[j];
                }
            }
        }
    }

    /// Forward pass over one shard's rows (whole sequences
    /// [seq0, seq0+seqs)), storing every activation backward needs.
    /// `tokens`/`targets` are the *full* batch; the shard's slice is cut
    /// here. The shard's un-normalized f64 token-loss sum lands in
    /// `sc.loss_sum`; the caller reduces shard sums in ascending order and
    /// divides once by the global token count. With `grad`, the fused
    /// softmax–xent leaves `sc.logits` holding dlogits for
    /// [`NativeBackend::backward_shard`]. `pool` parallelizes the dense
    /// operators over column chunks (pure scheduling — see [`dispatch`]).
    fn forward_shard(
        &self,
        pool: Option<&WorkerPool>,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        sc: &mut ShardScratch,
        grad: bool,
    ) {
        let m = &self.spec.model;
        let lay = &self.layout;
        let (t_len, d, f, v, nh) = (m.seq_len, m.d_model, m.d_ff, m.vocab_size, m.n_heads);
        let b = sc.seqs;
        let n = b * t_len;
        let r0 = sc.seq0 * t_len;
        let tokens = &tokens[r0..r0 + n];
        let targets = &targets[r0..r0 + n];
        let dh = d / nh;
        let scale = 1.0 / (dh as f32).sqrt();
        let (cd, cf, cv) = (col_shards(d), col_shards(f), col_shards(v));

        // Embedding gather, column-chunked.
        let embed = &params[lay.embed..lay.embed + v * d];
        {
            let x0 = SendPtr(sc.x0.as_mut_ptr());
            dispatch(pool, cd, |c| {
                let (c0, c1) = col_chunk(d, cd, c);
                // SAFETY: disjoint x0 columns per job.
                unsafe { gather_cols(x0.0, embed, tokens, d, c0, c1) }
            });
        }

        for l in 0..m.n_layers {
            let off = lay.layers[l];
            // Work around the borrow checker: split the one &mut LayerScratch
            // out of the vec, everything else is shared reads.
            let (before, rest) = sc.layers.split_at_mut(l);
            let ls = &mut rest[0];
            let x_in: &[f32] = if l == 0 { &sc.x0 } else { &before[l - 1].x_out };

            rmsnorm(
                &mut ls.hn_attn,
                &mut ls.rinv_attn,
                x_in,
                &params[off.attn_norm..off.attn_norm + d],
                n,
                d,
            );
            // QKV projections: one scope, 3·cd disjoint (buffer, column
            // range) jobs.
            {
                let q = SendPtr(ls.q.as_mut_ptr());
                let k = SendPtr(ls.k.as_mut_ptr());
                let vv = SendPtr(ls.v.as_mut_ptr());
                let hn = &ls.hn_attn;
                let wq = &params[off.wq..off.wq + d * d];
                let wk = &params[off.wk..off.wk + d * d];
                let wv = &params[off.wv..off.wv + d * d];
                dispatch(pool, 3 * cd, |job| {
                    let (which, c) = (job / cd, job % cd);
                    let (c0, c1) = col_chunk(d, cd, c);
                    let (out, w) = match which {
                        0 => (&q, wq),
                        1 => (&k, wk),
                        _ => (&vv, wv),
                    };
                    // SAFETY: disjoint (buffer, column-range) per job.
                    unsafe { vecops::matmul_cols_ptr(out.0, hn, w, n, d, d, c0, c1) }
                });
            }
            self.rope(&mut ls.q, 1.0);
            self.rope(&mut ls.k, 1.0);

            // Causal softmax attention per (shard row, head).
            for bi in 0..b {
                for h in 0..nh {
                    let pb = &mut ls.probs
                        [(bi * nh + h) * t_len * t_len..(bi * nh + h + 1) * t_len * t_len];
                    for t1 in 0..t_len {
                        let qrow = &ls.q[((bi * t_len + t1) * d + h * dh)..][..dh];
                        let prow = &mut pb[t1 * t_len..(t1 + 1) * t_len];
                        let mut mx = f32::NEG_INFINITY;
                        for (t2, p_val) in prow.iter_mut().enumerate().take(t1 + 1) {
                            let krow = &ls.k[((bi * t_len + t2) * d + h * dh)..][..dh];
                            let sc_val = dot(qrow, krow) * scale;
                            *p_val = sc_val;
                            if sc_val > mx {
                                mx = sc_val;
                            }
                        }
                        let mut z = 0.0f32;
                        for p_val in prow.iter_mut().take(t1 + 1) {
                            *p_val = (*p_val - mx).exp();
                            z += *p_val;
                        }
                        let inv = 1.0 / z;
                        for p_val in prow.iter_mut().take(t1 + 1) {
                            *p_val *= inv;
                        }
                        for p_val in prow.iter_mut().skip(t1 + 1) {
                            *p_val = 0.0;
                        }
                        // ctx row = Σ_t2 p·v_t2
                        let crow = &mut ls.ctx[((bi * t_len + t1) * d + h * dh)..][..dh];
                        crow.fill(0.0);
                        for t2 in 0..=t1 {
                            let vrow = &ls.v[((bi * t_len + t2) * d + h * dh)..][..dh];
                            axpy(crow, pb[t1 * t_len + t2], vrow);
                        }
                    }
                }
            }

            // x_mid = x_in + ctx @ wo (matmul into x_mid, then add residual).
            {
                let xm = SendPtr(ls.x_mid.as_mut_ptr());
                let ctx = &ls.ctx;
                let wo = &params[off.wo..off.wo + d * d];
                dispatch(pool, cd, |c| {
                    let (c0, c1) = col_chunk(d, cd, c);
                    // SAFETY: disjoint x_mid columns per job.
                    unsafe { vecops::matmul_cols_ptr(xm.0, ctx, wo, n, d, d, c0, c1) }
                });
            }
            vecops::add_assign(&mut ls.x_mid, x_in);

            // SwiGLU MLP: x_out = x_mid + (silu(x̂@w1) ⊙ (x̂@w3)) @ w2.
            rmsnorm(
                &mut ls.hn_mlp,
                &mut ls.rinv_mlp,
                &ls.x_mid,
                &params[off.mlp_norm..off.mlp_norm + d],
                n,
                d,
            );
            {
                let u = SendPtr(ls.u.as_mut_ptr());
                let g3 = SendPtr(ls.g3.as_mut_ptr());
                let hn = &ls.hn_mlp;
                let w1 = &params[off.w1..off.w1 + d * f];
                let w3 = &params[off.w3..off.w3 + d * f];
                dispatch(pool, 2 * cf, |job| {
                    let (which, c) = (job / cf, job % cf);
                    let (c0, c1) = col_chunk(f, cf, c);
                    let (out, w) = if which == 0 { (&u, w1) } else { (&g3, w3) };
                    // SAFETY: disjoint (buffer, column-range) per job.
                    unsafe { vecops::matmul_cols_ptr(out.0, hn, w, n, d, f, c0, c1) }
                });
            }
            for i in 0..n * f {
                let u = ls.u[i];
                let sig = 1.0 / (1.0 + (-u).exp());
                ls.s[i] = u * sig * ls.g3[i];
            }
            {
                let xo = SendPtr(ls.x_out.as_mut_ptr());
                let s = &ls.s;
                let w2 = &params[off.w2..off.w2 + f * d];
                dispatch(pool, cd, |c| {
                    let (c0, c1) = col_chunk(d, cd, c);
                    // SAFETY: disjoint x_out columns per job.
                    unsafe { vecops::matmul_cols_ptr(xo.0, s, w2, n, f, d, c0, c1) }
                });
            }
            vecops::add_assign(&mut ls.x_out, &ls.x_mid);
        }

        // Final norm + untied LM head + fused softmax–cross-entropy.
        let x_last: &[f32] =
            if m.n_layers == 0 { &sc.x0 } else { &sc.layers[m.n_layers - 1].x_out };
        rmsnorm(
            &mut sc.xf,
            &mut sc.rinv_f,
            x_last,
            &params[lay.final_norm..lay.final_norm + d],
            n,
            d,
        );
        {
            let lg = SendPtr(sc.logits.as_mut_ptr());
            let xf = &sc.xf;
            let lm = &params[lay.lm_head..lay.lm_head + d * v];
            dispatch(pool, cv, |c| {
                let (c0, c1) = col_chunk(v, cv, c);
                // SAFETY: disjoint logits columns per job.
                unsafe { vecops::matmul_cols_ptr(lg.0, xf, lm, n, d, v, c0, c1) }
            });
        }
        let inv_n = 1.0 / (m.batch_size * m.seq_len) as f32;
        sc.loss_sum = softmax_xent_cols(pool, &mut sc.logits, targets, v, inv_n, grad, &mut sc.xent);
    }

    /// Backward pass for one shard into `sc.grad` (overwritten; full-size,
    /// holding only this shard's row contributions). Must be called right
    /// after [`NativeBackend::forward_shard`] with `grad = true` on the
    /// same shard: the fused softmax–xent already left `sc.logits` holding
    /// dlogits scaled by the *global* 1/N, so the per-shard gradients sum
    /// to the whole-batch gradient with no vocab re-sweep here.
    fn backward_shard(&self, pool: Option<&WorkerPool>, params: &[f32], tokens: &[i32], sc: &mut ShardScratch) {
        let m = &self.spec.model;
        let lay = &self.layout;
        let (t_len, d, f, v, nh) = (m.seq_len, m.d_model, m.d_ff, m.vocab_size, m.n_heads);
        let b = sc.seqs;
        let n = b * t_len;
        let r0 = sc.seq0 * t_len;
        let tokens = &tokens[r0..r0 + n];
        let dh = d / nh;
        let scale = 1.0 / (dh as f32).sqrt();
        let (cd, cf, cv) = (col_shards(d), col_shards(f), col_shards(v));

        sc.grad.fill(0.0);

        // LM head: d_xf = dlogits @ lm_headᵀ; g_lm += xfᵀ @ dlogits — one
        // scope, two disjoint output buffers.
        {
            let lm = &params[lay.lm_head..lay.lm_head + d * v];
            let dlg = &sc.logits;
            let xf = &sc.xf;
            let dhh = SendPtr(sc.d_h.as_mut_ptr());
            let gbase = SendPtr(sc.grad.as_mut_ptr());
            let lm_off = lay.lm_head;
            dispatch(pool, cd + cv, |job| {
                // SAFETY: disjoint (buffer, column-range) per job.
                if job < cd {
                    let (c0, c1) = col_chunk(d, cd, job);
                    unsafe { vecops::matmul_bt_cols_ptr(dhh.0, dlg, lm, n, d, v, c0, c1) }
                } else {
                    let (c0, c1) = col_chunk(v, cv, job - cd);
                    unsafe {
                        vecops::matmul_at_acc_cols_ptr(gbase.0.add(lm_off), xf, dlg, n, d, v, c0, c1)
                    }
                }
            });
        }

        // Final RMSNorm (d_x accumulates; start from zero).
        let x_last: &[f32] =
            if m.n_layers == 0 { &sc.x0 } else { &sc.layers[m.n_layers - 1].x_out };
        sc.d_x.fill(0.0);
        rmsnorm_backward(
            &mut sc.d_x,
            &mut sc.grad[lay.final_norm..lay.final_norm + d],
            &sc.d_h,
            x_last,
            &sc.rinv_f,
            &params[lay.final_norm..lay.final_norm + d],
            n,
            d,
        );

        for l in (0..m.n_layers).rev() {
            let off = lay.layers[l];
            let (before, rest) = sc.layers.split_at(l);
            let ls = &rest[0];
            let x_in: &[f32] = if l == 0 { &sc.x0 } else { &before[l - 1].x_out };

            // ---- MLP block backward: x_out = x_mid + s@w2.
            // d_s = d_x @ w2ᵀ; g_w2 += sᵀ @ d_x — one scope.
            {
                let w2 = &params[off.w2..off.w2 + f * d];
                let dx = &sc.d_x;
                let s = &ls.s;
                let ds = SendPtr(sc.d_s.as_mut_ptr());
                let gbase = SendPtr(sc.grad.as_mut_ptr());
                let w2_off = off.w2;
                dispatch(pool, cf + cd, |job| {
                    // SAFETY: disjoint (buffer, column-range) per job.
                    if job < cf {
                        let (c0, c1) = col_chunk(f, cf, job);
                        unsafe { vecops::matmul_bt_cols_ptr(ds.0, dx, w2, n, f, d, c0, c1) }
                    } else {
                        let (c0, c1) = col_chunk(d, cd, job - cf);
                        unsafe {
                            vecops::matmul_at_acc_cols_ptr(gbase.0.add(w2_off), s, dx, n, f, d, c0, c1)
                        }
                    }
                });
            }
            // s = silu(u) ⊙ g3.
            for i in 0..n * f {
                let u = ls.u[i];
                let sig = 1.0 / (1.0 + (-u).exp());
                let silu = u * sig;
                sc.d_g3[i] = sc.d_s[i] * silu;
                sc.d_u[i] = sc.d_s[i] * ls.g3[i] * (sig * (1.0 + u * (1.0 - sig)));
            }
            // d_hn = d_u @ w1ᵀ + d_g3 @ w3ᵀ; weight grads — one scope,
            // four disjoint output buffers.
            {
                let w1 = &params[off.w1..off.w1 + d * f];
                let w3 = &params[off.w3..off.w3 + d * f];
                let du = &sc.d_u;
                let dg3 = &sc.d_g3;
                let hn = &ls.hn_mlp;
                let dhh = SendPtr(sc.d_h.as_mut_ptr());
                let dres = SendPtr(sc.d_res.as_mut_ptr());
                let gbase = SendPtr(sc.grad.as_mut_ptr());
                let (w1_off, w3_off) = (off.w1, off.w3);
                dispatch(pool, 2 * cd + 2 * cf, |job| {
                    // SAFETY: disjoint (buffer, column-range) per job.
                    unsafe {
                        if job < cd {
                            let (c0, c1) = col_chunk(d, cd, job);
                            vecops::matmul_bt_cols_ptr(dhh.0, du, w1, n, d, f, c0, c1)
                        } else if job < 2 * cd {
                            let (c0, c1) = col_chunk(d, cd, job - cd);
                            vecops::matmul_bt_cols_ptr(dres.0, dg3, w3, n, d, f, c0, c1)
                        } else if job < 2 * cd + cf {
                            let (c0, c1) = col_chunk(f, cf, job - 2 * cd);
                            vecops::matmul_at_acc_cols_ptr(gbase.0.add(w1_off), hn, du, n, d, f, c0, c1)
                        } else {
                            let (c0, c1) = col_chunk(f, cf, job - 2 * cd - cf);
                            vecops::matmul_at_acc_cols_ptr(gbase.0.add(w3_off), hn, dg3, n, d, f, c0, c1)
                        }
                    }
                });
            }
            vecops::add_assign(&mut sc.d_h, &sc.d_res);
            // RMSNorm backward at x_mid; residual adds d_x through.
            rmsnorm_backward(
                &mut sc.d_x,
                &mut sc.grad[off.mlp_norm..off.mlp_norm + d],
                &sc.d_h,
                &ls.x_mid,
                &ls.rinv_mlp,
                &params[off.mlp_norm..off.mlp_norm + d],
                n,
                d,
            );

            // ---- Attention block backward: x_mid = x_in + ctx@wo.
            // d_ctx = d_x @ woᵀ; g_wo += ctxᵀ @ d_x — one scope.
            {
                let wo = &params[off.wo..off.wo + d * d];
                let dx = &sc.d_x;
                let ctx = &ls.ctx;
                let dhh = SendPtr(sc.d_h.as_mut_ptr());
                let gbase = SendPtr(sc.grad.as_mut_ptr());
                let wo_off = off.wo;
                dispatch(pool, 2 * cd, |job| {
                    // SAFETY: disjoint (buffer, column-range) per job.
                    if job < cd {
                        let (c0, c1) = col_chunk(d, cd, job);
                        unsafe { vecops::matmul_bt_cols_ptr(dhh.0, dx, wo, n, d, d, c0, c1) }
                    } else {
                        let (c0, c1) = col_chunk(d, cd, job - cd);
                        unsafe {
                            vecops::matmul_at_acc_cols_ptr(gbase.0.add(wo_off), ctx, dx, n, d, d, c0, c1)
                        }
                    }
                });
            }
            // Per (shard row, head): softmax/score backward.
            sc.d_q.fill(0.0);
            sc.d_k.fill(0.0);
            sc.d_v.fill(0.0);
            for bi in 0..b {
                for h in 0..nh {
                    let pb = &ls.probs
                        [(bi * nh + h) * t_len * t_len..(bi * nh + h + 1) * t_len * t_len];
                    // dP = d_ctx @ vᵀ ; d_v += Pᵀ @ d_ctx.
                    for t1 in 0..t_len {
                        let dctx = &sc.d_h[((bi * t_len + t1) * d + h * dh)..][..dh];
                        let prow = &pb[t1 * t_len..(t1 + 1) * t_len];
                        let dprow = &mut sc.d_p[t1 * t_len..(t1 + 1) * t_len];
                        for t2 in 0..=t1 {
                            let vrow = &ls.v[((bi * t_len + t2) * d + h * dh)..][..dh];
                            dprow[t2] = dot(dctx, vrow);
                            let dvrow = &mut sc.d_v[((bi * t_len + t2) * d + h * dh)..][..dh];
                            axpy(dvrow, prow[t2], dctx);
                        }
                        // dS = P ⊙ (dP − ⟨dP, P⟩) on the causal prefix.
                        let mut acc = 0.0f32;
                        for t2 in 0..=t1 {
                            acc += dprow[t2] * prow[t2];
                        }
                        for t2 in 0..=t1 {
                            dprow[t2] = prow[t2] * (dprow[t2] - acc);
                        }
                        // d_q row += dS @ K · scale; d_k rows += dSᵀ @ q · scale.
                        let qrow = &ls.q[((bi * t_len + t1) * d + h * dh)..][..dh];
                        // (d_q and q are disjoint buffers; split borrows.)
                        for t2 in 0..=t1 {
                            let w = dprow[t2] * scale;
                            let krow = &ls.k[((bi * t_len + t2) * d + h * dh)..][..dh];
                            let dqrow = &mut sc.d_q[((bi * t_len + t1) * d + h * dh)..][..dh];
                            axpy(dqrow, w, krow);
                            let dkrow = &mut sc.d_k[((bi * t_len + t2) * d + h * dh)..][..dh];
                            axpy(dkrow, w, qrow);
                        }
                    }
                }
            }
            // Undo RoPE (transpose rotation) on d_q/d_k.
            self.rope(&mut sc.d_q, -1.0);
            self.rope(&mut sc.d_k, -1.0);
            // d_hn = d_q@wqᵀ + d_k@wkᵀ + d_v@wvᵀ; weight grads — one
            // scope, six disjoint output buffers (d_res2 carries the wv
            // summand so all three bt products coexist).
            {
                let wq = &params[off.wq..off.wq + d * d];
                let wk = &params[off.wk..off.wk + d * d];
                let wv = &params[off.wv..off.wv + d * d];
                let dq = &sc.d_q;
                let dk = &sc.d_k;
                let dv = &sc.d_v;
                let hn = &ls.hn_attn;
                let dhh = SendPtr(sc.d_h.as_mut_ptr());
                let dres = SendPtr(sc.d_res.as_mut_ptr());
                let dres2 = SendPtr(sc.d_res2.as_mut_ptr());
                let gbase = SendPtr(sc.grad.as_mut_ptr());
                let (wq_off, wk_off, wv_off) = (off.wq, off.wk, off.wv);
                dispatch(pool, 6 * cd, |job| {
                    let (which, c) = (job / cd, job % cd);
                    let (c0, c1) = col_chunk(d, cd, c);
                    // SAFETY: disjoint (buffer, column-range) per job.
                    unsafe {
                        match which {
                            0 => vecops::matmul_bt_cols_ptr(dhh.0, dq, wq, n, d, d, c0, c1),
                            1 => vecops::matmul_bt_cols_ptr(dres.0, dk, wk, n, d, d, c0, c1),
                            2 => vecops::matmul_bt_cols_ptr(dres2.0, dv, wv, n, d, d, c0, c1),
                            3 => vecops::matmul_at_acc_cols_ptr(
                                gbase.0.add(wq_off), hn, dq, n, d, d, c0, c1,
                            ),
                            4 => vecops::matmul_at_acc_cols_ptr(
                                gbase.0.add(wk_off), hn, dk, n, d, d, c0, c1,
                            ),
                            _ => vecops::matmul_at_acc_cols_ptr(
                                gbase.0.add(wv_off), hn, dv, n, d, d, c0, c1,
                            ),
                        }
                    }
                });
            }
            vecops::add_assign(&mut sc.d_h, &sc.d_res);
            vecops::add_assign(&mut sc.d_h, &sc.d_res2);
            // RMSNorm backward at x_in; residual passthrough stays in d_x.
            rmsnorm_backward(
                &mut sc.d_x,
                &mut sc.grad[off.attn_norm..off.attn_norm + d],
                &sc.d_h,
                x_in,
                &ls.rinv_attn,
                &params[off.attn_norm..off.attn_norm + d],
                n,
                d,
            );
        }

        // Embedding scatter-add, column-chunked (private grad buffer —
        // repeated token ids across shards never race; within the shard,
        // columns are the partition axis so repeats stay i-ascending).
        {
            let dx = &sc.d_x;
            let gbase = SendPtr(sc.grad.as_mut_ptr());
            let e_off = lay.embed;
            dispatch(pool, cd, |c| {
                let (c0, c1) = col_chunk(d, cd, c);
                // SAFETY: disjoint embedding-gradient columns per job.
                unsafe { scatter_add_cols(gbase.0.add(e_off), dx, tokens, d, c0, c1) }
            });
        }
    }

    /// Run forward (and optionally backward) over every shard, choosing
    /// the 2D partition's schedule from the pool size: row shards fan out
    /// on the pool when both sides exceed one (a 1-thread pool would be
    /// pure queue overhead — the sharded1 regression), and the column axis
    /// engages only when threads outnumber the row tasks (otherwise rows
    /// already saturate the pool). Both gates are pure scheduling: the
    /// chunk grids are shape-only, so every result bit is identical
    /// serial, row-pooled, column-pooled, or both. The serial path boxes
    /// nothing, keeping the steady-state train step allocation-free.
    fn run_shards(
        &self,
        pool: Option<&WorkerPool>,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        shards: &mut [ShardScratch],
        with_backward: bool,
    ) {
        let col_pool = match pool {
            Some(tp) if tp.threads() > shards.len() => Some(tp),
            _ => None,
        };
        match pool {
            Some(tp) if shards.len() > 1 && tp.threads() > 1 => {
                let tasks: Vec<ScopedTask<'_>> = shards
                    .iter_mut()
                    .map(|sc| {
                        Box::new(move || {
                            self.forward_shard(col_pool, params, tokens, targets, sc, with_backward);
                            if with_backward {
                                self.backward_shard(col_pool, params, tokens, sc);
                            }
                        }) as ScopedTask<'_>
                    })
                    .collect();
                tp.scoped(tasks);
            }
            _ => {
                for sc in shards.iter_mut() {
                    self.forward_shard(col_pool, params, tokens, targets, sc, with_backward);
                    if with_backward {
                        self.backward_shard(col_pool, params, tokens, sc);
                    }
                }
            }
        }
    }

    /// Fixed-order reduction of the per-shard loss sums: ascending shard
    /// index, then one divide by the global token count.
    fn reduce_loss(&self, shards: &[ShardScratch]) -> f32 {
        let n = self.spec.model.batch_size * self.spec.model.seq_len;
        let sum: f64 = shards.iter().map(|sc| sc.loss_sum).sum();
        (sum / n as f64) as f32
    }

    /// AdamW over the whole flat state, parallelized over disjoint
    /// LANES-aligned parameter spans when a pool is available. The
    /// per-span work includes the shard-gradient reduction (see
    /// [`adamw_span`]), so no merged gradient buffer ever materializes.
    fn adamw(
        &self,
        st: &mut TrainState,
        shards: &[ShardScratch],
        lr: f32,
        pool: Option<&WorkerPool>,
    ) {
        let t = &self.spec.train;
        let step1 = (st.step + 1) as f64; // 1-indexed for bias correction
        let coef = AdamCoef {
            b1: t.beta1 as f32,
            b2: t.beta2 as f32,
            eps: t.eps as f32,
            wd: t.weight_decay as f32,
            bc1: (1.0 - (t.beta1).powf(step1)) as f32,
            bc2: (1.0 - (t.beta2).powf(step1)) as f32,
            lr,
        };
        match pool {
            // A 1-thread pool gains nothing from span fan-out (the
            // sharded1 regression); the span chunking never changes bits,
            // so this gate is pure scheduling.
            Some(tp) if tp.threads() > 1 => {
                let total = st.params.len();
                let slots = tp.threads() + 1;
                let chunk = total.div_ceil(slots).next_multiple_of(vecops::LANES);
                let tasks: Vec<ScopedTask<'_>> = st
                    .params
                    .chunks_mut(chunk)
                    .zip(st.m.chunks_mut(chunk))
                    .zip(st.v.chunks_mut(chunk))
                    .enumerate()
                    .map(|(ci, ((p, mm), vv))| {
                        Box::new(move || adamw_span(coef, p, mm, vv, shards, ci * chunk))
                            as ScopedTask<'_>
                    })
                    .collect();
                tp.scoped(tasks);
            }
            _ => adamw_span(coef, &mut st.params, &mut st.m, &mut st.v, shards, 0),
        }
    }

    fn check_batch(&self, tokens: &[i32], targets: &[i32]) -> anyhow::Result<()> {
        let n = self.spec.model.batch_size * self.spec.model.seq_len;
        anyhow::ensure!(
            tokens.len() == n && targets.len() == n,
            "batch shape mismatch: got {}/{} tokens, want {n}",
            tokens.len(),
            targets.len()
        );
        let v = self.spec.model.vocab_size as i32;
        anyhow::ensure!(
            tokens.iter().chain(targets).all(|&x| x >= 0 && x < v),
            "token id out of vocabulary range"
        );
        Ok(())
    }
}

/// Deterministic scaled-normal init (model.py init_flat): std 0.02,
/// residual-out projections (wo/w2) scaled by 1/√(2·n_layers), norms at 1.
fn init_flat(spec: &NativeSpec, layout: &Layout) -> Vec<f32> {
    let mut rng = Rng::new(spec.seed, 0x1217);
    let mut flat = vec![0.0f32; layout.total];
    let resid_scale = 1.0 / (2.0 * spec.model.n_layers as f64).sqrt();
    for leaf in &layout.leaves {
        let sl = &mut flat[leaf.offset..leaf.offset + leaf.size];
        if leaf.name.ends_with("_norm") {
            sl.fill(1.0);
        } else {
            let mut std = 0.02;
            if leaf.name.ends_with(".wo") || leaf.name.ends_with(".w2") {
                std *= resid_scale;
            }
            for x in sl.iter_mut() {
                *x = (rng.next_gaussian() * std) as f32;
            }
        }
    }
    flat
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native".into()
    }

    fn model(&self) -> &ModelMeta {
        &self.spec.model
    }

    fn param_count(&self) -> usize {
        self.layout.total
    }

    fn fragments(&self) -> &FragmentTable {
        &self.layout.frags
    }

    fn init_params(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.init.clone())
    }

    fn create_worker(&self) -> anyhow::Result<WorkerHandle> {
        Ok(WorkerHandle::new(NativeWorker {
            state: TrainState::new(self.init.clone()),
            shards: make_shards(&self.spec.model, self.layout.total, true),
        }))
    }

    fn set_compute_pool(&self, pool: Option<Arc<WorkerPool>>) {
        *self.pool.write().expect("compute pool poisoned") = pool;
    }

    fn train_step(
        &self,
        w: &mut WorkerHandle,
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<f32> {
        self.check_batch(tokens, targets)?;
        let pool = self.compute_pool();
        let nw = self.worker_mut(w)?;
        let NativeWorker { state: st, shards } = nw;
        self.run_shards(pool.as_deref(), &st.params, tokens, targets, shards, true);
        let loss = self.reduce_loss(shards);
        let lr = lr_schedule(st.step, &self.spec.train);
        self.adamw(st, shards, lr, pool.as_deref());
        st.step += 1;
        Ok(loss)
    }

    fn eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> anyhow::Result<f32> {
        self.check_batch(tokens, targets)?;
        anyhow::ensure!(params.len() == self.layout.total, "param vector length mismatch");
        let pool = self.compute_pool();
        let mut shards = self
            .eval_scratch
            .lock()
            .expect("eval scratch pool poisoned")
            .pop()
            .unwrap_or_else(|| make_shards(&self.spec.model, self.layout.total, false));
        self.run_shards(pool.as_deref(), params, tokens, targets, &mut shards, false);
        let loss = self.reduce_loss(&shards);
        self.eval_scratch
            .lock()
            .expect("eval scratch pool poisoned")
            .push(shards);
        Ok(loss)
    }

    fn read_fragment(&self, w: &WorkerHandle, frag: Fragment, out: &mut [f32]) -> anyhow::Result<()> {
        out.copy_from_slice(&self.worker(w)?.state.params[frag.range()]);
        Ok(())
    }

    fn write_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        data: &[f32],
    ) -> anyhow::Result<()> {
        self.worker_mut(w)?.state.params[frag.range()].copy_from_slice(data);
        Ok(())
    }

    fn delay_comp_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) -> anyhow::Result<()> {
        let local = &mut self.worker_mut(w)?.state.params[frag.range()];
        vecops::fused_delay_comp(local, theta_g, theta_tp, tau, h, lambda);
        Ok(())
    }

    fn alpha_blend_fragment(
        &self,
        w: &mut WorkerHandle,
        frag: Fragment,
        theta_g: &[f32],
        alpha: f32,
    ) -> anyhow::Result<()> {
        let local = &mut self.worker_mut(w)?.state.params[frag.range()];
        vecops::fused_alpha_blend(local, theta_g, alpha);
        Ok(())
    }

    fn mean_params(&self, ws: &[WorkerHandle], out: &mut [f32]) -> anyhow::Result<()> {
        let rows = validated_rows::<NativeWorker, _>(ws, |w| w.state.params.as_slice())?;
        vecops::fused_mean_iter(out, rows);
        Ok(())
    }

    fn pseudo_mean_fragment(
        &self,
        ws: &[WorkerHandle],
        frag: Fragment,
        theta_g: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let rows =
            validated_rows::<NativeWorker, _>(ws, move |w| &w.state.params[frag.range()])?;
        vecops::fused_pseudo_mean_iter(out, rows, theta_g);
        Ok(())
    }

    fn read_state(&self, w: &WorkerHandle, dst: &mut TrainState) -> anyhow::Result<()> {
        dst.clone_from(&self.worker(w)?.state);
        Ok(())
    }

    fn write_state(&self, w: &mut WorkerHandle, src: &TrainState) -> anyhow::Result<()> {
        self.worker_mut(w)?.state.clone_from(src);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_spec() -> NativeSpec {
        NativeSpec {
            name: "micro".into(),
            model: model_meta(8, 4, 1, 2, 8, 4, 1),
            train: train_meta(1e-2, 2, 100),
            n_fragments: 1,
            seed: 3,
        }
    }

    fn batch(b: &NativeBackend, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let m = b.model();
        let n = m.batch_size * m.seq_len;
        let mut rng = Rng::new(seed, 0);
        let tokens: Vec<i32> =
            (0..n).map(|_| rng.below(m.vocab_size as u64) as i32).collect();
        let mut targets = tokens.clone();
        targets.rotate_left(1);
        (tokens, targets)
    }

    /// Serial forward over every shard; returns the reduced mean loss.
    /// `grad` leaves dlogits in place for a following backward_shard.
    fn forward_all(
        be: &NativeBackend,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
        shards: &mut [ShardScratch],
        grad: bool,
    ) -> f32 {
        for sc in shards.iter_mut() {
            be.forward_shard(None, params, tokens, targets, sc, grad);
        }
        be.reduce_loss(shards)
    }

    #[test]
    fn layout_tiles_and_matches_param_count() {
        let b = NativeBackend::preset("tiny").unwrap();
        let frags = b.fragments();
        let total: usize = (0..frags.k()).map(|p| frags.get(p).size).sum();
        assert_eq!(total, b.param_count());
        let leaf_total: usize = b.leaves().iter().map(|l| l.size).sum();
        assert_eq!(leaf_total, b.param_count());
        // Leaves stay inside their fragments.
        for l in b.leaves() {
            let f = frags.get(l.fragment);
            assert!(l.offset >= f.offset && l.offset + l.size <= f.offset + f.size);
        }
    }

    #[test]
    fn init_is_deterministic_and_norms_are_one() {
        let a = NativeBackend::preset("tiny").unwrap();
        let b = NativeBackend::preset("tiny").unwrap();
        assert_eq!(a.init_params().unwrap(), b.init_params().unwrap());
        let init = a.init_params().unwrap();
        let norm = a.leaves().iter().find(|l| l.name.ends_with("attn_norm")).unwrap();
        assert!(init[norm.offset..norm.offset + norm.size].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn shard_partition_covers_batch_exactly() {
        for b in 1..=20usize {
            let s_count = row_shards(b);
            assert!(s_count >= 1 && s_count <= MAX_ROW_SHARDS && s_count <= b.max(1));
            let mut covered = 0;
            for s in 0..s_count {
                let seq0 = s * b / s_count;
                let seq1 = (s + 1) * b / s_count;
                assert_eq!(seq0, covered, "batch {b}: shard {s} not contiguous");
                assert!(seq1 > seq0, "batch {b}: empty shard {s}");
                covered = seq1;
            }
            assert_eq!(covered, b, "batch {b}: shards do not cover the batch");
        }
    }

    #[test]
    fn col_partition_covers_columns_exactly() {
        for cols in 1..=300usize {
            let shards = col_shards(cols);
            assert!(shards >= 1 && shards <= MAX_COL_SHARDS);
            assert!(shards == 1 || cols / shards >= MIN_COL_CHUNK, "cols {cols}: thin chunks");
            let mut covered = 0;
            for s in 0..shards {
                let (c0, c1) = col_chunk(cols, shards, s);
                assert_eq!(c0, covered, "cols {cols}: chunk {s} not contiguous");
                assert!(c1 > c0, "cols {cols}: empty chunk {s}");
                covered = c1;
            }
            assert_eq!(covered, cols, "cols {cols}: chunks do not cover the width");
        }
    }

    #[test]
    fn intra_step_units_scales_with_both_axes() {
        // tiny: batch 2 → 2 row shards; widest operator is vocab 64 → 4
        // column chunks.
        let tiny = NativeSpec::preset("tiny").unwrap();
        assert_eq!(intra_step_units(&tiny.model), 2 * 4);
        // batch-1 variant still exposes the column axis.
        let mut b1 = tiny.model.clone();
        b1.batch_size = 1;
        assert_eq!(intra_step_units(&b1), 4);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let be = NativeBackend::new(micro_spec()).unwrap();
        let (tokens, targets) = batch(&be, 5);
        let params = be.init_params().unwrap();
        let mut shards = make_shards(&be.spec.model, be.layout.total, true);
        let _ = forward_all(&be, &params, &tokens, &targets, &mut shards, true);
        for sc in shards.iter_mut() {
            be.backward_shard(None, &params, &tokens, sc);
        }
        // Fixed-order reduction of the per-shard gradients.
        let mut grad = vec![0.0f32; params.len()];
        for sc in shards.iter() {
            vecops::add_assign(&mut grad, &sc.grad);
        }
        let mut rng = Rng::new(11, 0);
        let eps = 3e-3f32;
        let mut checked = 0;
        while checked < 40 {
            let i = rng.below(params.len() as u64) as usize;
            let mut pp = params.clone();
            pp[i] += eps;
            let lp = forward_all(&be, &pp, &tokens, &targets, &mut shards, false);
            pp[i] = params[i] - eps;
            let lm = forward_all(&be, &pp, &tokens, &targets, &mut shards, false);
            let fd = (lp - lm) / (2.0 * eps);
            let tol = 2e-2 * (1.0 + fd.abs().max(grad[i].abs()));
            assert!(
                (fd - grad[i]).abs() < tol,
                "param {i}: fd {fd} vs analytic {}",
                grad[i]
            );
            checked += 1;
        }
    }

    #[test]
    fn train_step_learns_fixed_batch() {
        let be = NativeBackend::preset("tiny").unwrap();
        let mut w = be.create_worker().unwrap();
        let (tokens, targets) = batch(&be, 7);
        let first = be.train_step(&mut w, &tokens, &targets).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = be.train_step(&mut w, &tokens, &targets).unwrap();
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first - 0.1, "no learning: {first} -> {last}");
        assert_eq!(w.get::<NativeWorker>().unwrap().state.step, 31);
    }

    #[test]
    fn eval_at_init_is_near_uniform_and_deterministic() {
        let be = NativeBackend::preset("tiny").unwrap();
        let (tokens, targets) = batch(&be, 9);
        let params = be.init_params().unwrap();
        let a = be.eval_loss(&params, &tokens, &targets).unwrap();
        let b = be.eval_loss(&params, &tokens, &targets).unwrap();
        assert_eq!(a, b);
        let uniform = (be.model().vocab_size as f32).ln();
        assert!((a - uniform).abs() < 0.5, "init loss {a} vs ln V {uniform}");
    }

    #[test]
    fn train_steps_are_deterministic() {
        let run = || {
            let be = NativeBackend::preset("tiny").unwrap();
            let mut w = be.create_worker().unwrap();
            let (tokens, targets) = batch(&be, 13);
            let mut losses = Vec::new();
            for _ in 0..5 {
                losses.push(be.train_step(&mut w, &tokens, &targets).unwrap());
            }
            let mut st = TrainState::new(vec![0.0; be.param_count()]);
            be.read_state(&w, &mut st).unwrap();
            (losses, st.params)
        };
        let (l1, p1) = run();
        let (l2, p2) = run();
        assert_eq!(l1, l2);
        assert_eq!(p1, p2);
    }

    /// The tentpole guarantee at the backend level: installing a compute
    /// pool of any size changes nothing but wall-clock — losses, eval and
    /// final parameters are bit-identical to the serial path.
    #[test]
    fn pooled_train_and_eval_match_serial_bitwise() {
        let run = |threads: usize| {
            let be = NativeBackend::preset("tiny").unwrap();
            if threads > 1 {
                be.set_compute_pool(Some(Arc::new(WorkerPool::new(threads))));
            }
            let (tokens, targets) = batch(&be, 21);
            let mut w = be.create_worker().unwrap();
            let mut losses = Vec::new();
            for _ in 0..6 {
                losses.push(be.train_step(&mut w, &tokens, &targets).unwrap());
            }
            let mut st = TrainState::new(vec![0.0; be.param_count()]);
            be.read_state(&w, &mut st).unwrap();
            let eval = be.eval_loss(&st.params, &tokens, &targets).unwrap();
            (losses, eval, st.params)
        };
        let serial = run(1);
        for threads in [2, 4, 8] {
            assert_eq!(serial, run(threads), "threads={threads}");
        }
    }

    #[test]
    fn lr_schedule_warmup_then_cosine() {
        let t = train_meta(1e-3, 10, 100);
        assert!((lr_schedule(0, &t) - 1e-4).abs() < 1e-9);
        assert!((lr_schedule(9, &t) - 1e-3).abs() < 1e-9);
        // Past warmup the schedule decays toward min_lr_ratio·lr.
        assert!(lr_schedule(50, &t) < 1e-3);
        let end = lr_schedule(99, &t);
        assert!(end >= 1e-4 - 1e-9 && end < 2e-4, "end lr {end}");
    }

    #[test]
    fn batch_shape_and_vocab_validated() {
        let be = NativeBackend::preset("tiny").unwrap();
        let mut w = be.create_worker().unwrap();
        assert!(be.train_step(&mut w, &[0; 3], &[0; 3]).is_err());
        let n = be.model().batch_size * be.model().seq_len;
        let bad = vec![be.model().vocab_size as i32; n];
        assert!(be.train_step(&mut w, &bad, &bad).is_err());
    }
}
