//! Pseudo-gradient compression for the WAN path.
//!
//! The Streaming DiLoCo line of work ships pseudo-gradients in low
//! precision (the original paper uses 4-bit quantization with no loss
//! degradation); this module provides symmetric per-fragment int8 and int4
//! quantizers so CoCoDC's transfers can be charged (and verified) at
//! compressed size. Enabled via `RunConfig::compression`.
//!
//! Quantization is applied at initiation (what the wire would carry) and
//! dequantized before the outer step, so the optimizer always sees the
//! round-tripped values — the simulation is faithful to a real deployment,
//! including the quantization error.

/// Wire format for one compressed fragment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// No compression: 4 bytes/param.
    None,
    /// Symmetric int8: 1 byte/param + one f32 scale.
    Int8,
    /// Symmetric int4 (two params per byte): 0.5 bytes/param + scale.
    Int4,
}

impl Codec {
    pub fn parse(s: &str) -> anyhow::Result<Codec> {
        match s {
            "none" => Ok(Codec::None),
            "int8" => Ok(Codec::Int8),
            "int4" => Ok(Codec::Int4),
            _ => anyhow::bail!("unknown codec '{s}' (none|int8|int4)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::Int8 => "int8",
            Codec::Int4 => "int4",
        }
    }

    /// Bytes on the wire for `n` f32 parameters.
    pub fn wire_bytes(&self, n: usize) -> f64 {
        match self {
            Codec::None => n as f64 * 4.0,
            Codec::Int8 => n as f64 + 4.0,
            Codec::Int4 => (n as f64 / 2.0).ceil() + 4.0,
        }
    }

    fn levels(&self) -> Option<f32> {
        match self {
            Codec::None => None,
            Codec::Int8 => Some(127.0),
            Codec::Int4 => Some(7.0),
        }
    }

    /// Round-trip `x` through the wire format in place. Returns the max
    /// absolute quantization error introduced.
    pub fn round_trip(&self, x: &mut [f32]) -> f32 {
        let Some(levels) = self.levels() else { return 0.0 };
        let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if amax == 0.0 {
            return 0.0;
        }
        let scale = amax / levels;
        let inv = 1.0 / scale;
        let mut max_err = 0.0f32;
        for v in x.iter_mut() {
            let q = (*v * inv).round().clamp(-levels, levels);
            let back = q * scale;
            max_err = max_err.max((back - *v).abs());
            *v = back;
        }
        max_err
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn none_is_identity() {
        let mut x = vec![1.0f32, -2.5, 0.0];
        let orig = x.clone();
        assert_eq!(Codec::None.round_trip(&mut x), 0.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn wire_bytes_scale_correctly() {
        assert_eq!(Codec::None.wire_bytes(100), 400.0);
        assert_eq!(Codec::Int8.wire_bytes(100), 104.0);
        assert_eq!(Codec::Int4.wire_bytes(100), 54.0);
        assert_eq!(Codec::Int4.wire_bytes(101), 55.0); // odd count rounds up
    }

    #[test]
    fn prop_round_trip_error_bounded_by_half_step() {
        forall(40, |rng| {
            let n = rng.usize_in(1, 500);
            let scale = 10f32.powi(rng.usize_in(0, 4) as i32 - 2);
            let mut x = rng.f32_vec(n, scale);
            let orig = x.clone();
            for codec in [Codec::Int8, Codec::Int4] {
                let mut y = orig.clone();
                let err = codec.round_trip(&mut y);
                let amax = orig.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let step = amax / codec.levels().unwrap();
                if err > step * 0.5 + 1e-7 {
                    return Err(format!(
                        "{}: err {err} > half-step {}",
                        codec.name(),
                        step * 0.5
                    ));
                }
                // Every element within half a step of the original.
                for (a, b) in orig.iter().zip(&y) {
                    if (a - b).abs() > step * 0.5 + 1e-7 {
                        return Err("elementwise bound violated".into());
                    }
                }
            }
            x.clear();
            Ok(())
        });
    }

    #[test]
    fn zeros_stay_zeros() {
        let mut x = vec![0.0f32; 64];
        assert_eq!(Codec::Int8.round_trip(&mut x), 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn int4_odd_length_round_trip() {
        // Odd-length fragments exercise the half-byte tail of the packed
        // wire format: the size must ceil to a whole byte and every value
        // must still obey the half-step bound.
        for n in [1usize, 3, 7, 129] {
            let mut x: Vec<f32> = (0..n).map(|i| (i as f32 - n as f32 / 2.0) * 0.13).collect();
            let orig = x.clone();
            let err = Codec::Int4.round_trip(&mut x);
            let amax = orig.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let step = amax / 7.0;
            assert!(err <= step * 0.5 + 1e-7, "n={n}: err {err} > {}", step * 0.5);
            for (a, b) in orig.iter().zip(&x) {
                assert!((a - b).abs() <= step * 0.5 + 1e-7, "n={n}: {a} vs {b}");
            }
            assert_eq!(Codec::Int4.wire_bytes(n), (n as f64 / 2.0).ceil() + 4.0);
        }
    }

    #[test]
    fn int4_all_zero_fragment_is_exact() {
        // amax == 0 short-circuits before the 1/scale division — no NaNs,
        // and the odd length must not disturb the zero payload.
        let mut x = vec![0.0f32; 33];
        assert_eq!(Codec::Int4.round_trip(&mut x), 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn int4_single_value_fragment_is_exact() {
        // A lone value is its own amax, so it lands exactly on the top
        // quantization level and round-trips to within float rounding.
        for v in [1.0f32, -0.25, 3.5e-3] {
            let mut x = vec![v];
            let err = Codec::Int4.round_trip(&mut x);
            assert!(err <= v.abs() * 1e-6, "v={v}: err {err}");
            assert!((x[0] - v).abs() <= v.abs() * 1e-6, "v={v} -> {}", x[0]);
        }
        // Constant fragments behave identically: every element is amax.
        let mut x = vec![-0.75f32; 9];
        let err = Codec::Int4.round_trip(&mut x);
        assert!(err <= 0.75 * 1e-6);
        assert!(x.iter().all(|&v| (v + 0.75).abs() <= 0.75 * 1e-6));
    }

    #[test]
    fn parse_names() {
        for c in [Codec::None, Codec::Int8, Codec::Int4] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::parse("fp8").is_err());
    }
}
