//! Virtual wall-clock for the cross-region simulation.
//!
//! Local compute runs for real (PJRT executions), but WAN communication is
//! *simulated*: the trainer advances this clock by the measured/configured
//! per-step compute time and by whatever the [`crate::network`] model says
//! transfers cost. This is what lets a single-host run report the paper's
//! wall-clock comparisons (DiLoCo's blocking sync vs overlapped streaming)
//! faithfully — the same methodology the paper itself uses on its 4-GPU
//! testbed, with the network made explicit.

/// Virtual clock plus an account of where the time went.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: f64,
    compute_s: f64,
    comm_stall_s: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// All M workers step in parallel; one round costs the slowest worker's
    /// compute time (homogeneous capacity per paper §IV-A, so just T_c).
    pub fn advance_compute(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.now += dt;
        self.compute_s += dt;
    }

    /// Blocking communication: everyone waits until `t` (e.g. DiLoCo's
    /// all-reduce completion). No-op if `t` is already in the past.
    pub fn stall_until(&mut self, t: f64) {
        if t > self.now {
            self.comm_stall_s += t - self.now;
            self.now = t;
        }
    }

    /// Seconds spent computing (parallel across workers).
    pub fn compute_s(&self) -> f64 {
        self.compute_s
    }

    /// Seconds stalled on blocking communication.
    pub fn comm_stall_s(&self) -> f64 {
        self.comm_stall_s
    }

    /// (now, compute_s, comm_stall_s) — checkpointable run context, so a
    /// restored run continues the same wall-clock curve.
    pub fn state(&self) -> (f64, f64, f64) {
        (self.now, self.compute_s, self.comm_stall_s)
    }

    pub fn restore(&mut self, now: f64, compute_s: f64, comm_stall_s: f64) {
        self.now = now;
        self.compute_s = compute_s;
        self.comm_stall_s = comm_stall_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_and_stall_accounting() {
        let mut c = VirtualClock::new();
        c.advance_compute(1.5);
        assert_eq!(c.now(), 1.5);
        c.stall_until(2.0);
        assert_eq!(c.now(), 2.0);
        assert_eq!(c.comm_stall_s(), 0.5);
        // stall into the past is a no-op
        c.stall_until(1.0);
        assert_eq!(c.now(), 2.0);
        assert_eq!(c.compute_s(), 1.5);
    }
}
