//! Ring all-reduce: the analytic cost model plus a faithful data-path
//! implementation (reduce-scatter + all-gather over chunked slices).
//!
//! The trainer's strategies average pseudo-gradients with a direct mean
//! (numerically identical, see `ring_allreduce_matches_mean` below); the
//! chunked implementation here exists to validate that equivalence, to model
//! the exact per-round traffic the cost model charges for, and for
//! `bench_allreduce`.

/// Analytic completion time of a ring all-reduce of `bytes` over `m` nodes:
/// 2(m-1) rounds, each moving `bytes/m` per link at latency `l` and
/// bandwidth `b` ⇒ `2(m-1)·l + 2·((m-1)/m)·bytes/b`.
pub fn ring_allreduce_time(bytes: f64, m: usize, l: f64, b: f64) -> f64 {
    if m <= 1 {
        return 0.0;
    }
    let m_f = m as f64;
    2.0 * (m_f - 1.0) * l + 2.0 * ((m_f - 1.0) / m_f) * bytes / b
}

/// In-place ring all-reduce (average) over equal-length worker buffers.
///
/// Exactly the reduce-scatter + all-gather schedule: each of the `m` chunks
/// travels around the ring accumulating, then circulates again fully
/// reduced. After return every buffer holds the element-wise mean.
pub fn ring_allreduce_mean(buffers: &mut [Vec<f32>]) {
    let m = buffers.len();
    assert!(m >= 1);
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n), "equal lengths required");
    if m == 1 {
        return;
    }
    // Chunk boundaries: chunk c covers [bounds[c], bounds[c+1]).
    let bounds: Vec<usize> = (0..=m).map(|c| c * n / m).collect();

    // Reduce-scatter: round r, node i sends chunk (i - r) mod m to node i+1.
    for r in 0..m - 1 {
        // Compute the transfers of this round before mutating (the real
        // network does them concurrently).
        let sends: Vec<(usize, usize, Vec<f32>)> = (0..m)
            .map(|i| {
                let c = (i + m - r) % m;
                let (lo, hi) = (bounds[c], bounds[c + 1]);
                ((i + 1) % m, c, buffers[i][lo..hi].to_vec())
            })
            .collect();
        for (dst, c, data) in sends {
            let (lo, hi) = (bounds[c], bounds[c + 1]);
            for (x, y) in buffers[dst][lo..hi].iter_mut().zip(&data) {
                *x += *y;
            }
        }
    }
    // After reduce-scatter, node i owns fully-reduced chunk (i + 1) mod m.
    // Scale to mean, then all-gather.
    let inv = 1.0 / m as f32;
    for i in 0..m {
        let c = (i + 1) % m;
        let (lo, hi) = (bounds[c], bounds[c + 1]);
        for x in buffers[i][lo..hi].iter_mut() {
            *x *= inv;
        }
    }
    for r in 0..m - 1 {
        let sends: Vec<(usize, usize, Vec<f32>)> = (0..m)
            .map(|i| {
                let c = (i + 1 + m - r) % m;
                let (lo, hi) = (bounds[c], bounds[c + 1]);
                ((i + 1) % m, c, buffers[i][lo..hi].to_vec())
            })
            .collect();
        for (dst, c, data) in sends {
            let (lo, hi) = (bounds[c], bounds[c + 1]);
            buffers[dst][lo..hi].copy_from_slice(&data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn time_model_zero_for_single_node() {
        assert_eq!(ring_allreduce_time(1e9, 1, 0.05, 1e8), 0.0);
    }

    #[test]
    fn time_model_latency_and_bandwidth_terms() {
        // Pure latency: tiny payload.
        let t = ring_allreduce_time(1.0, 4, 0.05, 1e12);
        assert!((t - 2.0 * 3.0 * 0.05).abs() < 1e-6);
        // Pure bandwidth: zero latency.
        let t = ring_allreduce_time(1e8, 4, 0.0, 1e8);
        assert!((t - 2.0 * 0.75).abs() < 1e-9);
    }

    #[test]
    fn ring_allreduce_matches_mean() {
        let mut rng = Rng::new(11, 0);
        for &(m, n) in &[(2usize, 10usize), (3, 7), (4, 1000), (5, 13), (4, 3)] {
            let orig: Vec<Vec<f32>> = (0..m)
                .map(|_| (0..n).map(|_| rng.next_gaussian() as f32).collect())
                .collect();
            let mean: Vec<f32> = (0..n)
                .map(|j| orig.iter().map(|b| b[j]).sum::<f32>() / m as f32)
                .collect();
            let mut bufs = orig.clone();
            ring_allreduce_mean(&mut bufs);
            for b in &bufs {
                for (x, y) in b.iter().zip(&mean) {
                    assert!((x - y).abs() < 1e-5, "m={m} n={n}");
                }
            }
        }
    }

    #[test]
    fn single_worker_is_identity() {
        let mut bufs = vec![vec![1.0f32, 2.0, 3.0]];
        ring_allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0, 3.0]);
    }
}
