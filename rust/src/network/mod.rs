//! WAN simulator: ring all-reduce cost model + a serialized inter-DC link
//! timeline (transfers queue behind each other, matching the paper's
//! streaming schedule where one fragment is in flight at a time).
//!
//! With a multi-region [`TopologyConfig`] attached the simulator dispatches
//! to the hierarchical two-level model in [`topology`] — per-link serialized
//! WAN timelines behind an intra-region LAN tier — while flat runs take
//! exactly the legacy single-link path, bit for bit.

pub mod faults;
pub mod ring;
pub mod topology;

use crate::config::{FaultConfig, NetworkConfig, TopologyConfig};
use crate::util::{saturating_f64_to_u32, Rng};
use faults::FaultPlan;
use topology::{LinkObs, LinkUtil, TopoNet, TopoState};

/// A scheduled collective transfer on the simulated WAN.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    /// Virtual time the transfer was requested.
    pub requested: f64,
    /// Virtual time it actually started (>= requested; queueing).
    pub start: f64,
    /// Virtual time the all-reduce completes on every worker.
    pub finish: f64,
    pub bytes: f64,
}

impl Transfer {
    pub fn duration(&self) -> f64 {
        self.finish - self.start
    }
    pub fn queue_delay(&self) -> f64 {
        self.start - self.requested
    }
}

/// Result of a single failure-aware scheduling attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TransferOutcome {
    Delivered(Transfer),
    /// The transfer was lost in flight; the link time was still consumed
    /// and the loss is detected (missing all-reduce completion) at
    /// `detected_at` — the caller must handle this, typically by retrying.
    Dropped { requested: f64, detected_at: f64, bytes: f64 },
}

/// Outcome of a logical transfer driven through retry + exponential
/// backoff under the configured [`crate::config::RetryPolicy`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncSchedule {
    /// The delivered transfer, or `None` when the retry/timeout budget was
    /// exhausted (the fragment must be requeued by the strategy).
    pub transfer: Option<Transfer>,
    /// Transmission attempts made (1 on the loss-free fast path).
    pub attempts: u32,
    /// Attempts lost in flight (`attempts - 1` on success, `attempts` on
    /// exhaustion).
    pub drops: u32,
    /// Virtual time the final outcome was known: delivery time on success,
    /// last loss-detection time on exhaustion.
    pub resolved_at: f64,
    /// `Some(draw)` when the delivered payload was corrupted in flight
    /// (seeded bit-flip draw from the fault plan's corruption stream). The
    /// receiving strategy uses the draw to apply the flip against the
    /// payload checksum, then quarantines + retransmits. Always `None` on
    /// exhaustion (nothing was delivered).
    pub corruption: Option<u64>,
}

impl SyncSchedule {
    pub fn delivered(&self) -> bool {
        self.transfer.is_some()
    }
    pub fn retries(&self) -> u32 {
        self.attempts.saturating_sub(1)
    }
}

/// Simulated WAN shared by the M datacenters.
///
/// The model: all-reduce of S bytes over an M-node ring costs
/// `2(M-1)·L + 2·((M-1)/M)·S/B` (reduce-scatter + all-gather, each of the
/// 2(M-1) rounds moving S/M bytes per link at latency L). Concurrent
/// requests serialize on the inter-DC links — the bandwidth term queues,
/// which is exactly the congestion the paper's γ factor guards against.
#[derive(Debug)]
pub struct WanSimulator {
    cfg: NetworkConfig,
    workers: usize,
    busy_until: f64,
    rng: Rng,
    faults: FaultPlan,
    /// Region graph + per-link timelines; `None` = legacy flat single link.
    topo: Option<TopoNet>,
    /// Worker liveness mirrored from the trainer (leaders fail over, dead
    /// regions drop out of the WAN ring). All-true when faults are off.
    live: Vec<bool>,
    /// Total bytes moved per link (for utilization reporting).
    pub bytes_sent: f64,
    pub transfers: usize,
    /// Transfers lost in flight by the fault plan.
    pub drops: usize,
}

/// Checkpointable simulator state (see [`WanSimulator::state`]). The `topo`
/// vectors are empty on flat runs, keeping the legacy layout intact.
#[derive(Debug, Clone, PartialEq)]
pub struct NetState {
    pub busy_until: f64,
    pub bytes_sent: f64,
    pub transfers: usize,
    pub drops: usize,
    pub jitter_rng: [u64; 4],
    pub fault_rng: [u64; 4],
    pub corrupt_rng: [u64; 4],
    pub topo: TopoState,
}

impl WanSimulator {
    pub fn new(cfg: NetworkConfig, workers: usize, seed: u64) -> Self {
        Self::with_faults(cfg, workers, seed, FaultConfig::default())
    }

    /// Simulator with a scripted fault plan. The loss RNG stream is forked
    /// from the same seed as the jitter stream but never shares draws, so
    /// enabling faults leaves jitter sequences untouched.
    pub fn with_faults(cfg: NetworkConfig, workers: usize, seed: u64, faults: FaultConfig) -> Self {
        WanSimulator {
            cfg,
            workers,
            busy_until: 0.0,
            rng: Rng::new(seed, 0xC0C0),
            faults: FaultPlan::new(faults, seed),
            topo: None,
            live: vec![true; workers],
            bytes_sent: 0.0,
            transfers: 0,
            drops: 0,
        }
    }

    /// Simulator with a region graph attached: a flat topology is a no-op
    /// (the legacy single-link path runs bit-identically); a multi-region
    /// one routes every collective through the hierarchical two-level model.
    pub fn with_topology(
        cfg: NetworkConfig,
        topo: &TopologyConfig,
        workers: usize,
        seed: u64,
        faults: FaultConfig,
    ) -> anyhow::Result<Self> {
        let mut w = Self::with_faults(cfg, workers, seed, faults);
        if !topo.is_flat() {
            w.topo = Some(TopoNet::new(topo.clone(), workers)?);
        }
        Ok(w)
    }

    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// The attached region graph, if any.
    pub fn topology(&self) -> Option<&TopoNet> {
        self.topo.as_ref()
    }

    /// Mirror the trainer's per-worker liveness into the topology layer
    /// (leader failover + dead-region dropout). No-op on flat runs.
    pub fn set_liveness(&mut self, live: &[bool]) {
        if self.live.len() == live.len() {
            self.live.copy_from_slice(live);
        }
    }

    /// Pure cost of one ring all-reduce of `bytes` (no queueing/jitter).
    pub fn ring_time(&self, bytes: f64) -> f64 {
        ring::ring_allreduce_time(
            bytes,
            self.workers,
            self.cfg.latency_s,
            self.cfg.bandwidth_bps,
        )
    }

    /// Schedule an all-reduce at virtual time `now`; returns its timeline.
    /// Infallible: scripted outages and bandwidth degradation apply (they
    /// only stretch the timeline), but probabilistic loss does not — use
    /// [`WanSimulator::try_schedule_allreduce`] or
    /// [`WanSimulator::schedule_with_retries`] for the failure-aware path.
    pub fn schedule_allreduce(&mut self, now: f64, bytes: f64) -> Transfer {
        self.schedule_allreduce_routed(now, bytes, None)
    }

    /// Like [`WanSimulator::schedule_allreduce`], optionally pinning the
    /// inter-region phase to an explicit cycle of link ids (CoCoDC's
    /// adaptive per-link scheduler builds one; `None` = canonical ring).
    /// The route is ignored on flat runs.
    pub fn schedule_allreduce_routed(
        &mut self,
        now: f64,
        bytes: f64,
        route: Option<&[usize]>,
    ) -> Transfer {
        if let Some(topo) = self.topo.as_mut() {
            let (start, finish) =
                topo.schedule(now, bytes, route, &self.live, &self.faults, &mut self.rng);
            let t = Transfer { requested: now, start, finish, bytes };
            // The aggregate timeline stays monotone for diagnostics; the
            // real queueing lives on the per-link timelines.
            self.busy_until = self.busy_until.max(finish);
            self.bytes_sent += bytes;
            self.transfers += 1;
            return t;
        }
        let mut start = now.max(self.busy_until);
        // A transfer requested during a scripted outage queues behind its
        // end (chained windows are chased by `outage_end`).
        if let Some(end) = self.faults.outage_end(start) {
            start = end;
        }
        let bw_factor = self.faults.bandwidth_factor(start);
        let mut dur = ring::ring_allreduce_time(
            bytes,
            self.workers,
            self.cfg.latency_s,
            self.cfg.bandwidth_bps * bw_factor,
        );
        if self.cfg.jitter > 0.0 {
            // Multiplicative jitter in [1-j, 1+j], deterministic per seed.
            let u = 2.0 * self.rng.next_f64() - 1.0;
            dur *= 1.0 + self.cfg.jitter * u;
        }
        let t = Transfer {
            requested: now,
            start,
            finish: start + dur,
            bytes,
        };
        self.busy_until = t.finish;
        self.bytes_sent += bytes;
        self.transfers += 1;
        t
    }

    /// Failure-aware scheduling: the transfer may be lost in flight
    /// (consuming link time either way), surfacing as
    /// [`TransferOutcome::Dropped`] that the caller must handle.
    pub fn try_schedule_allreduce(&mut self, now: f64, bytes: f64) -> TransferOutcome {
        self.try_schedule_allreduce_routed(now, bytes, None)
    }

    /// Failure-aware routed scheduling (see
    /// [`WanSimulator::schedule_allreduce_routed`]).
    pub fn try_schedule_allreduce_routed(
        &mut self,
        now: f64,
        bytes: f64,
        route: Option<&[usize]>,
    ) -> TransferOutcome {
        let t = self.schedule_allreduce_routed(now, bytes, route);
        if self.faults.draw_loss() {
            self.drops += 1;
            TransferOutcome::Dropped { requested: now, detected_at: t.finish, bytes }
        } else {
            TransferOutcome::Delivered(t)
        }
    }

    /// Drive one logical transfer through retry + exponential backoff under
    /// the plan's [`crate::config::RetryPolicy`], all accounted on the
    /// virtual clock: each retry re-enters the link queue after a backoff
    /// of `base · factor^(drops-1)` seconds from loss detection, bounded by
    /// `max_attempts` and a total `timeout_budget_s` from `now`.
    pub fn schedule_with_retries(&mut self, now: f64, bytes: f64) -> SyncSchedule {
        self.schedule_with_retries_routed(now, bytes, None)
    }

    /// Retry-driven routed scheduling (see
    /// [`WanSimulator::schedule_allreduce_routed`]); every retry re-enters
    /// the same route.
    pub fn schedule_with_retries_routed(
        &mut self,
        now: f64,
        bytes: f64,
        route: Option<&[usize]>,
    ) -> SyncSchedule {
        let policy = self.faults.retry();
        let deadline = now + policy.timeout_budget_s;
        let mut request_at = now;
        let mut attempts = 0u32;
        let mut drops = 0u32;
        loop {
            attempts += 1;
            match self.try_schedule_allreduce_routed(request_at, bytes, route) {
                TransferOutcome::Delivered(t) => {
                    // Corruption is drawn at departure time on a dedicated
                    // stream, so loss-only plans replay identically.
                    let corruption = self.faults.draw_corruption(t.start);
                    return SyncSchedule {
                        transfer: Some(t),
                        attempts,
                        drops,
                        resolved_at: t.finish,
                        corruption,
                    };
                }
                TransferOutcome::Dropped { detected_at, .. } => {
                    drops += 1;
                    if attempts >= policy.max_attempts {
                        return SyncSchedule {
                            transfer: None,
                            attempts,
                            drops,
                            resolved_at: detected_at,
                            corruption: None,
                        };
                    }
                    let backoff =
                        policy.backoff_base_s * policy.backoff_factor.powi(drops as i32 - 1);
                    request_at = detected_at + backoff;
                    if request_at > deadline {
                        return SyncSchedule {
                            transfer: None,
                            attempts,
                            drops,
                            resolved_at: detected_at,
                            corruption: None,
                        };
                    }
                }
            }
        }
    }

    /// Effective overlap depth in steps for a transfer completing at
    /// `finish`, given per-step compute time: τ_eff = ceil((finish-now)/T_c),
    /// saturating explicitly on huge `finish` or degenerate inputs.
    pub fn tau_steps(&self, now: f64, finish: f64, step_compute_s: f64) -> u32 {
        saturating_f64_to_u32((((finish - now) / step_compute_s).ceil()).max(1.0)).max(1)
    }

    /// Average single-fragment sync time T_s for the adaptive scheduler
    /// (Eq. 9): the pure ring time of a fragment of `bytes` on flat runs,
    /// or the queue-free hierarchical estimate with a topology attached.
    pub fn t_sync(&self, bytes: f64) -> f64 {
        match &self.topo {
            Some(t) => t.t_sync_estimate(bytes),
            None => self.ring_time(bytes),
        }
    }

    /// Per-link observations from the most recent hierarchical schedule
    /// (empty on flat runs); feeds CoCoDC's per-link EWMA estimates.
    pub fn link_observations(&self) -> &[LinkObs] {
        self.topo.as_ref().map(|t| t.last_obs()).unwrap_or(&[])
    }

    /// Per-link utilization counters (empty on flat runs).
    pub fn link_utils(&self) -> Vec<LinkUtil> {
        self.topo.as_ref().map(|t| t.link_utils()).unwrap_or_default()
    }

    /// Failure injection: take the inter-DC links down until `until`
    /// (virtual time). Transfers requested during the outage queue behind
    /// it — with TauMode::Network the effective τ stretches, and blocking
    /// methods stall; used by robustness tests.
    pub fn inject_outage_until(&mut self, until: f64) {
        self.busy_until = self.busy_until.max(until);
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn config(&self) -> &NetworkConfig {
        &self.cfg
    }

    /// Checkpointable simulator state: link timeline, counters and all three
    /// RNG streams (jitter + transfer loss + payload corruption). With this
    /// restored, a resumed run schedules — and loses, and corrupts —
    /// transfers identically to the uninterrupted one, even mid fault window.
    pub fn state(&self) -> NetState {
        NetState {
            busy_until: self.busy_until,
            bytes_sent: self.bytes_sent,
            transfers: self.transfers,
            drops: self.drops,
            jitter_rng: self.rng.state(),
            fault_rng: self.faults.rng_state(),
            corrupt_rng: self.faults.corrupt_rng_state(),
            topo: self.topo.as_ref().map(|t| t.snapshot()).unwrap_or_default(),
        }
    }

    pub fn restore(&mut self, st: &NetState) {
        self.busy_until = st.busy_until;
        self.bytes_sent = st.bytes_sent;
        self.transfers = st.transfers;
        self.drops = st.drops;
        self.rng = Rng::from_state(st.jitter_rng);
        self.faults.restore_rng(st.fault_rng);
        self.faults.restore_corrupt_rng(st.corrupt_rng);
        if let Some(t) = self.topo.as_mut() {
            if st.topo.link_busy.len() == t.n_links()
                && st.topo.intra_busy.len() == t.n_regions()
            {
                t.restore(&st.topo);
            } else {
                // Legacy flat checkpoint restored into a topology run:
                // timelines start fresh.
                t.reset();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> NetworkConfig {
        NetworkConfig {
            latency_s: 0.05,
            bandwidth_bps: 125e6,
            jitter: 0.0,
            step_compute_s: 0.1,
        }
    }

    #[test]
    fn ring_time_monotone_in_size_latency_and_inverse_bandwidth() {
        let w = WanSimulator::new(net(), 4, 0);
        assert!(w.ring_time(2e6) > w.ring_time(1e6));
        let mut hi_lat = net();
        hi_lat.latency_s = 0.2;
        let w2 = WanSimulator::new(hi_lat, 4, 0);
        assert!(w2.ring_time(1e6) > w.ring_time(1e6));
        let mut lo_bw = net();
        lo_bw.bandwidth_bps = 10e6;
        let w3 = WanSimulator::new(lo_bw, 4, 0);
        assert!(w3.ring_time(1e6) > w.ring_time(1e6));
    }

    #[test]
    fn transfers_queue_on_the_link() {
        let mut w = WanSimulator::new(net(), 4, 0);
        let t1 = w.schedule_allreduce(0.0, 1e6);
        let t2 = w.schedule_allreduce(0.0, 1e6);
        assert_eq!(t1.start, 0.0);
        assert!((t2.start - t1.finish).abs() < 1e-12);
        assert!(t2.queue_delay() > 0.0);
        assert_eq!(w.transfers, 2);
    }

    #[test]
    fn idle_link_starts_immediately() {
        let mut w = WanSimulator::new(net(), 4, 0);
        let t1 = w.schedule_allreduce(0.0, 1e3);
        let t2 = w.schedule_allreduce(t1.finish + 10.0, 1e3);
        assert_eq!(t2.start, t2.requested);
        assert_eq!(t2.queue_delay(), 0.0);
    }

    #[test]
    fn tau_steps_ceil() {
        let w = WanSimulator::new(net(), 4, 0);
        assert_eq!(w.tau_steps(0.0, 0.45, 0.1), 5);
        assert_eq!(w.tau_steps(0.0, 0.5, 0.1), 5);
        assert_eq!(w.tau_steps(0.0, 0.0001, 0.1), 1);
    }

    #[test]
    fn outage_queues_transfers_behind_it() {
        let mut w = WanSimulator::new(net(), 4, 0);
        w.inject_outage_until(100.0);
        let t = w.schedule_allreduce(10.0, 1e6);
        assert_eq!(t.start, 100.0);
        assert!(t.queue_delay() >= 90.0);
        // Outage never shortens an existing queue.
        w.inject_outage_until(50.0);
        let t2 = w.schedule_allreduce(10.0, 1e6);
        assert!(t2.start >= t.finish);
    }

    #[test]
    fn tau_steps_saturates_on_degenerate_inputs() {
        let w = WanSimulator::new(net(), 4, 0);
        // Huge finish / tiny step compute must clamp, not wrap.
        assert_eq!(w.tau_steps(0.0, 1e300, 1e-9), u32::MAX);
        assert_eq!(w.tau_steps(0.0, f64::INFINITY, 0.1), u32::MAX);
        // NaN propagation (0/0-style inputs) falls back to the τ>=1 floor.
        assert_eq!(w.tau_steps(0.0, f64::NAN, 0.1), 1);
        assert_eq!(w.tau_steps(0.0, 1.0, 0.0), u32::MAX); // 1/0 = inf
        // Transfers finishing in the past still cost one step.
        assert_eq!(w.tau_steps(100.0, 0.0, 0.1), 1);
    }

    fn fault_cfg() -> crate::config::FaultConfig {
        crate::config::FaultConfig::default()
    }

    #[test]
    fn scripted_outage_queues_transfers_behind_it() {
        use crate::config::FaultWindow;
        let mut f = fault_cfg();
        f.outages.push(FaultWindow { start_s: 10.0, duration_s: 20.0 });
        let mut w = WanSimulator::with_faults(net(), 4, 0, f);
        let before = w.schedule_allreduce(0.0, 1e6);
        assert_eq!(before.start, 0.0);
        let during = w.schedule_allreduce(15.0, 1e6);
        assert_eq!(during.start, 30.0);
        assert!(during.queue_delay() >= 15.0);
        let after = w.schedule_allreduce(40.0, 1e6);
        assert_eq!(after.start, 40.0);
    }

    #[test]
    fn degradation_window_stretches_transfers() {
        use crate::config::{Degradation, FaultWindow};
        let mut f = fault_cfg();
        f.degradations.push(Degradation {
            window: FaultWindow { start_s: 100.0, duration_s: 100.0 },
            bandwidth_factor: 0.25,
        });
        let mut w = WanSimulator::with_faults(net(), 4, 0, f);
        let clean = w.schedule_allreduce(0.0, 8e6);
        let slow = w.schedule_allreduce(150.0, 8e6);
        assert!(
            slow.duration() > 2.0 * clean.duration(),
            "degraded window must stretch the bandwidth term"
        );
        let recovered = w.schedule_allreduce(300.0, 8e6);
        assert!((recovered.duration() - clean.duration()).abs() < 1e-9);
    }

    #[test]
    fn transfer_loss_is_deterministic_and_counted() {
        let mut f = fault_cfg();
        f.transfer_loss_prob = 0.5;
        let mut a = WanSimulator::with_faults(net(), 4, 11, f.clone());
        let mut b = WanSimulator::with_faults(net(), 4, 11, f);
        let mut dropped = 0;
        for i in 0..100 {
            let now = i as f64 * 10.0;
            let oa = a.try_schedule_allreduce(now, 1e6);
            let ob = b.try_schedule_allreduce(now, 1e6);
            assert_eq!(oa, ob);
            if let TransferOutcome::Dropped { detected_at, requested, .. } = oa {
                dropped += 1;
                // Loss is detected when the missing completion is noticed.
                assert!(detected_at > requested);
            }
        }
        assert!(dropped > 20 && dropped < 80, "dropped={dropped}");
        assert_eq!(a.drops, dropped);
        // A loss-free plan never consumes the loss stream or drops.
        let mut c = WanSimulator::new(net(), 4, 11);
        for i in 0..100 {
            assert!(matches!(
                c.try_schedule_allreduce(i as f64 * 10.0, 1e6),
                TransferOutcome::Delivered(_)
            ));
        }
        assert_eq!(c.drops, 0);
    }

    #[test]
    fn retries_back_off_exponentially_and_respect_budget() {
        let mut f = fault_cfg();
        f.transfer_loss_prob = 0.9;
        f.retry.max_attempts = 3;
        f.retry.backoff_base_s = 1.0;
        f.retry.backoff_factor = 2.0;
        f.retry.timeout_budget_s = 1e6;
        let mut w = WanSimulator::with_faults(net(), 4, 5, f);
        // Drive many logical transfers; at 90% loss with 3 attempts some
        // exhaust their budget.
        let mut exhausted = 0;
        let mut delivered = 0;
        let mut now = 0.0;
        for _ in 0..200 {
            let s = w.schedule_with_retries(now, 1e6);
            assert!(s.attempts <= 3);
            assert_eq!(s.drops, if s.delivered() { s.attempts - 1 } else { s.attempts });
            if s.delivered() {
                delivered += 1;
                assert_eq!(s.resolved_at, s.transfer.unwrap().finish);
            } else {
                exhausted += 1;
            }
            now = s.resolved_at + 5.0;
        }
        assert!(exhausted > 0 && delivered > 0, "exhausted={exhausted} delivered={delivered}");

        // Backoff spacing: with deterministic timing, a retried attempt may
        // not re-enter the queue earlier than detection + base backoff.
        let mut f2 = fault_cfg();
        f2.transfer_loss_prob = 0.9;
        f2.retry.backoff_base_s = 7.0;
        f2.retry.max_attempts = 2;
        let mut w2 = WanSimulator::with_faults(net(), 4, 6, f2);
        for i in 0..50 {
            let now = i as f64 * 1000.0;
            let s = w2.schedule_with_retries(now, 1e3);
            if s.attempts == 2 {
                if let Some(t) = s.transfer {
                    assert!(t.start >= now + 7.0, "retry at {} ignores backoff", t.start);
                }
            }
        }
    }

    #[test]
    fn tight_timeout_budget_gives_up_before_max_attempts() {
        let mut f = fault_cfg();
        f.transfer_loss_prob = 0.999;
        f.retry.max_attempts = 100;
        f.retry.backoff_base_s = 10.0;
        f.retry.timeout_budget_s = 15.0;
        let mut w = WanSimulator::with_faults(net(), 4, 3, f);
        let s = w.schedule_with_retries(0.0, 1e6);
        assert!(!s.delivered());
        // First loss detected ~0.36s in; first retry would start at ~10.4s
        // (inside budget), second at ~30s (outside) — far fewer than 100.
        assert!(s.attempts < 5, "attempts={}", s.attempts);
    }

    #[test]
    fn net_state_round_trip_replays_losses() {
        let mut f = fault_cfg();
        f.transfer_loss_prob = 0.4;
        let mut a = WanSimulator::with_faults(net(), 4, 21, f.clone());
        for i in 0..37 {
            a.try_schedule_allreduce(i as f64 * 3.0, 1e5);
        }
        let snap = a.state();
        let mut b = WanSimulator::with_faults(net(), 4, 999, f); // wrong seed on purpose
        b.restore(&snap);
        assert_eq!(b.state(), snap);
        for i in 37..80 {
            let now = i as f64 * 3.0;
            assert_eq!(a.try_schedule_allreduce(now, 1e5), b.try_schedule_allreduce(now, 1e5));
        }
        assert_eq!(a.drops, b.drops);
    }

    #[test]
    fn corruption_draws_flow_through_retries_and_replay_from_state() {
        use crate::config::{Corruption, FaultWindow};
        let mut f = fault_cfg();
        f.corruptions.push(Corruption {
            window: FaultWindow { start_s: 0.0, duration_s: 1e9 },
            prob: 0.5,
        });
        let mut a = WanSimulator::with_faults(net(), 4, 31, f.clone());
        let mut b = WanSimulator::with_faults(net(), 4, 31, f.clone());
        let mut corrupted = 0;
        for i in 0..60 {
            let now = i as f64 * 10.0;
            let sa = a.schedule_with_retries(now, 1e6);
            assert_eq!(sa, b.schedule_with_retries(now, 1e6));
            assert!(sa.delivered());
            corrupted += sa.corruption.is_some() as usize;
        }
        assert!(corrupted > 10 && corrupted < 50, "corrupted={corrupted}");
        // State round trip replays the same corruption draws.
        let snap = a.state();
        let mut c = WanSimulator::with_faults(net(), 4, 777, f);
        c.restore(&snap);
        for i in 60..120 {
            let now = i as f64 * 10.0;
            assert_eq!(a.schedule_with_retries(now, 1e6), c.schedule_with_retries(now, 1e6));
        }
        // Corruption-free plans never touch the stream or flag deliveries.
        let mut clean = WanSimulator::new(net(), 4, 31);
        let before = clean.state().corrupt_rng;
        for i in 0..30 {
            let s = clean.schedule_with_retries(i as f64 * 10.0, 1e6);
            assert_eq!(s.corruption, None);
        }
        assert_eq!(clean.state().corrupt_rng, before);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let mut cfg = net();
        cfg.jitter = 0.2;
        let mut a = WanSimulator::new(cfg, 4, 9);
        let mut b = WanSimulator::new(cfg, 4, 9);
        let base = a.ring_time(1e6);
        for i in 0..50 {
            let ta = a.schedule_allreduce(i as f64 * 100.0, 1e6);
            let tb = b.schedule_allreduce(i as f64 * 100.0, 1e6);
            assert_eq!(ta, tb);
            assert!(ta.duration() >= base * 0.8 - 1e-9);
            assert!(ta.duration() <= base * 1.2 + 1e-9);
        }
    }

    #[test]
    fn flat_topology_attaches_nothing() {
        use crate::config::TopologyConfig;
        let mut flat =
            WanSimulator::with_topology(net(), &TopologyConfig::flat(), 4, 0, fault_cfg()).unwrap();
        let mut legacy = WanSimulator::new(net(), 4, 0);
        assert!(flat.topology().is_none());
        assert!(flat.link_utils().is_empty());
        assert!(flat.link_observations().is_empty());
        for i in 0..20 {
            let now = i as f64 * 0.3;
            assert_eq!(flat.schedule_allreduce(now, 1e6), legacy.schedule_allreduce(now, 1e6));
        }
        assert_eq!(flat.state(), legacy.state());
    }

    #[test]
    fn hierarchical_sync_is_faster_than_flat_at_matched_budget() {
        use crate::config::net_preset;
        let (cfg, topo) = net_preset("global-4").unwrap();
        let mut hier =
            WanSimulator::with_topology(cfg, &topo, 8, 0, fault_cfg()).unwrap();
        let mut flat = WanSimulator::with_faults(cfg, 8, 0, fault_cfg());
        let th = hier.schedule_allreduce(0.0, 4e6);
        let tf = flat.schedule_allreduce(0.0, 4e6);
        assert!(
            th.finish < tf.finish,
            "hierarchical {} should beat flat {} on global-4",
            th.finish,
            tf.finish
        );
        assert!(hier.t_sync(4e6) < flat.t_sync(4e6));
        assert_eq!(hier.link_utils().len(), 12);
        assert!(!hier.link_observations().is_empty());
    }

    #[test]
    fn topology_state_round_trips_through_netstate() {
        use crate::config::net_preset;
        let (cfg, topo) = net_preset("us-eu").unwrap();
        let mut a = WanSimulator::with_topology(cfg, &topo, 8, 3, fault_cfg()).unwrap();
        for i in 0..7 {
            a.schedule_allreduce(i as f64 * 0.1, 1e6);
        }
        let snap = a.state();
        assert!(!snap.topo.link_busy.is_empty());
        let mut b = WanSimulator::with_topology(cfg, &topo, 8, 99, fault_cfg()).unwrap();
        b.restore(&snap);
        assert_eq!(b.state(), snap);
        for i in 7..20 {
            let now = i as f64 * 0.1;
            assert_eq!(a.schedule_allreduce(now, 1e6), b.schedule_allreduce(now, 1e6));
        }
        // A flat (legacy) NetState restored into a topology run resets the
        // per-link timelines instead of erroring.
        let mut flat_state = a.state();
        flat_state.topo = Default::default();
        let mut c = WanSimulator::with_topology(cfg, &topo, 8, 3, fault_cfg()).unwrap();
        c.schedule_allreduce(0.0, 1e6);
        c.restore(&flat_state);
        assert!(c.state().topo.link_busy.iter().all(|&b| b == 0.0));
    }
}
