//! WAN simulator: ring all-reduce cost model + a serialized inter-DC link
//! timeline (transfers queue behind each other, matching the paper's
//! streaming schedule where one fragment is in flight at a time).

pub mod ring;

use crate::config::NetworkConfig;
use crate::util::Rng;

/// A scheduled collective transfer on the simulated WAN.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    /// Virtual time the transfer was requested.
    pub requested: f64,
    /// Virtual time it actually started (>= requested; queueing).
    pub start: f64,
    /// Virtual time the all-reduce completes on every worker.
    pub finish: f64,
    pub bytes: f64,
}

impl Transfer {
    pub fn duration(&self) -> f64 {
        self.finish - self.start
    }
    pub fn queue_delay(&self) -> f64 {
        self.start - self.requested
    }
}

/// Simulated WAN shared by the M datacenters.
///
/// The model: all-reduce of S bytes over an M-node ring costs
/// `2(M-1)·L + 2·((M-1)/M)·S/B` (reduce-scatter + all-gather, each of the
/// 2(M-1) rounds moving S/M bytes per link at latency L). Concurrent
/// requests serialize on the inter-DC links — the bandwidth term queues,
/// which is exactly the congestion the paper's γ factor guards against.
#[derive(Debug)]
pub struct WanSimulator {
    cfg: NetworkConfig,
    workers: usize,
    busy_until: f64,
    rng: Rng,
    /// Total bytes moved per link (for utilization reporting).
    pub bytes_sent: f64,
    pub transfers: usize,
}

impl WanSimulator {
    pub fn new(cfg: NetworkConfig, workers: usize, seed: u64) -> Self {
        WanSimulator {
            cfg,
            workers,
            busy_until: 0.0,
            rng: Rng::new(seed, 0xC0C0),
            bytes_sent: 0.0,
            transfers: 0,
        }
    }

    /// Pure cost of one ring all-reduce of `bytes` (no queueing/jitter).
    pub fn ring_time(&self, bytes: f64) -> f64 {
        ring::ring_allreduce_time(
            bytes,
            self.workers,
            self.cfg.latency_s,
            self.cfg.bandwidth_bps,
        )
    }

    /// Schedule an all-reduce at virtual time `now`; returns its timeline.
    pub fn schedule_allreduce(&mut self, now: f64, bytes: f64) -> Transfer {
        let start = now.max(self.busy_until);
        let mut dur = self.ring_time(bytes);
        if self.cfg.jitter > 0.0 {
            // Multiplicative jitter in [1-j, 1+j], deterministic per seed.
            let u = 2.0 * self.rng.next_f64() - 1.0;
            dur *= 1.0 + self.cfg.jitter * u;
        }
        let t = Transfer {
            requested: now,
            start,
            finish: start + dur,
            bytes,
        };
        self.busy_until = t.finish;
        self.bytes_sent += bytes;
        self.transfers += 1;
        t
    }

    /// Effective overlap depth in steps for a transfer completing at
    /// `finish`, given per-step compute time: τ_eff = ceil((finish-now)/T_c).
    pub fn tau_steps(&self, now: f64, finish: f64, step_compute_s: f64) -> u32 {
        (((finish - now) / step_compute_s).ceil()).max(1.0) as u32
    }

    /// Average single-fragment sync time T_s for the adaptive scheduler
    /// (Eq. 9): the pure ring time of a fragment of `bytes`.
    pub fn t_sync(&self, bytes: f64) -> f64 {
        self.ring_time(bytes)
    }

    /// Failure injection: take the inter-DC links down until `until`
    /// (virtual time). Transfers requested during the outage queue behind
    /// it — with TauMode::Network the effective τ stretches, and blocking
    /// methods stall; used by robustness tests.
    pub fn inject_outage_until(&mut self, until: f64) {
        self.busy_until = self.busy_until.max(until);
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn config(&self) -> &NetworkConfig {
        &self.cfg
    }

    /// Checkpointable simulator state: (busy_until, bytes_sent, transfers,
    /// jitter-RNG state). With this restored, a resumed run schedules
    /// transfers identically to the uninterrupted one.
    pub fn state(&self) -> (f64, f64, usize, [u64; 4]) {
        (self.busy_until, self.bytes_sent, self.transfers, self.rng.state())
    }

    pub fn restore(&mut self, busy_until: f64, bytes_sent: f64, transfers: usize, rng: [u64; 4]) {
        self.busy_until = busy_until;
        self.bytes_sent = bytes_sent;
        self.transfers = transfers;
        self.rng = Rng::from_state(rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> NetworkConfig {
        NetworkConfig {
            latency_s: 0.05,
            bandwidth_bps: 125e6,
            jitter: 0.0,
            step_compute_s: 0.1,
        }
    }

    #[test]
    fn ring_time_monotone_in_size_latency_and_inverse_bandwidth() {
        let w = WanSimulator::new(net(), 4, 0);
        assert!(w.ring_time(2e6) > w.ring_time(1e6));
        let mut hi_lat = net();
        hi_lat.latency_s = 0.2;
        let w2 = WanSimulator::new(hi_lat, 4, 0);
        assert!(w2.ring_time(1e6) > w.ring_time(1e6));
        let mut lo_bw = net();
        lo_bw.bandwidth_bps = 10e6;
        let w3 = WanSimulator::new(lo_bw, 4, 0);
        assert!(w3.ring_time(1e6) > w.ring_time(1e6));
    }

    #[test]
    fn transfers_queue_on_the_link() {
        let mut w = WanSimulator::new(net(), 4, 0);
        let t1 = w.schedule_allreduce(0.0, 1e6);
        let t2 = w.schedule_allreduce(0.0, 1e6);
        assert_eq!(t1.start, 0.0);
        assert!((t2.start - t1.finish).abs() < 1e-12);
        assert!(t2.queue_delay() > 0.0);
        assert_eq!(w.transfers, 2);
    }

    #[test]
    fn idle_link_starts_immediately() {
        let mut w = WanSimulator::new(net(), 4, 0);
        let t1 = w.schedule_allreduce(0.0, 1e3);
        let t2 = w.schedule_allreduce(t1.finish + 10.0, 1e3);
        assert_eq!(t2.start, t2.requested);
        assert_eq!(t2.queue_delay(), 0.0);
    }

    #[test]
    fn tau_steps_ceil() {
        let w = WanSimulator::new(net(), 4, 0);
        assert_eq!(w.tau_steps(0.0, 0.45, 0.1), 5);
        assert_eq!(w.tau_steps(0.0, 0.5, 0.1), 5);
        assert_eq!(w.tau_steps(0.0, 0.0001, 0.1), 1);
    }

    #[test]
    fn outage_queues_transfers_behind_it() {
        let mut w = WanSimulator::new(net(), 4, 0);
        w.inject_outage_until(100.0);
        let t = w.schedule_allreduce(10.0, 1e6);
        assert_eq!(t.start, 100.0);
        assert!(t.queue_delay() >= 90.0);
        // Outage never shortens an existing queue.
        w.inject_outage_until(50.0);
        let t2 = w.schedule_allreduce(10.0, 1e6);
        assert!(t2.start >= t.finish);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let mut cfg = net();
        cfg.jitter = 0.2;
        let mut a = WanSimulator::new(cfg, 4, 9);
        let mut b = WanSimulator::new(cfg, 4, 9);
        let base = a.ring_time(1e6);
        for i in 0..50 {
            let ta = a.schedule_allreduce(i as f64 * 100.0, 1e6);
            let tb = b.schedule_allreduce(i as f64 * 100.0, 1e6);
            assert_eq!(ta, tb);
            assert!(ta.duration() >= base * 0.8 - 1e-9);
            assert!(ta.duration() <= base * 1.2 + 1e-9);
        }
    }
}
