//! Multi-region network topology runtime (DESIGN.md §Topology).
//!
//! The region graph from [`TopologyConfig`] becomes a set of directed
//! inter-region [`Link`]s, each owning its own serialized transfer timeline
//! (replacing the single shared-link queue of the flat model), plus one LAN
//! timeline per region. A hierarchical sync runs in three phases on the
//! virtual clock:
//!
//! 1. **Intra all-reduce** — workers inside each participating region ring
//!    all-reduce the payload at LAN cost on the region's own timeline.
//! 2. **Inter ring over leaders** — only region leaders (the lowest-index
//!    live worker per region) move data over the WAN: a ring over the R'
//!    participating regions, `2(R'-1)` rounds of `bytes/R'` per hop, where
//!    each round is paced by the slowest hop. All traversed links are
//!    occupied for the whole inter phase.
//! 3. **Intra broadcast** — leaders fan the result back out over the LAN.
//!
//! Per-link jitter draws come from the simulator's jitter stream and are
//! only consumed when a link's `jitter > 0`, preserving the determinism
//! contract. Regional outages sever exactly the links touching the region
//! (transfers queue behind the window end); a fully-crashed region drops
//! out of the ring, and missing direct links fall back to relaying over the
//! canonical region ring (validated to exist).

use crate::config::{LinkSpec, TopologyConfig};
use crate::network::faults::FaultPlan;
use crate::network::ring;
use crate::util::Rng;

/// One directed inter-region link with its own serialized timeline.
#[derive(Debug, Clone)]
pub struct Link {
    pub from: usize,
    pub to: usize,
    pub spec: LinkSpec,
    /// A transfer routed over this link occupies it until here.
    pub busy_until: f64,
    /// Total bytes moved over this link (utilization reporting).
    pub bytes: f64,
    /// Total seconds this link spent occupied.
    pub busy_s: f64,
    pub transfers: u64,
}

/// One per-link observation from the latest hierarchical schedule; feeds
/// CoCoDC's per-link EWMA bandwidth/latency estimates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkObs {
    pub link: usize,
    /// Observed per-round occupancy of this link, seconds.
    pub hop_s: f64,
    /// Bytes moved over this link per round.
    pub chunk_bytes: f64,
}

/// Per-link utilization summary reported in `SyncStats`/`TrainOutcome`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LinkUtil {
    /// "us->eu"-style directed link name.
    pub name: String,
    pub bytes: f64,
    pub busy_s: f64,
    pub transfers: u64,
}

/// Checkpointable per-link/per-region timeline state (joins the flat fields
/// in `NetState`; empty vectors on flat runs).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TopoState {
    pub link_busy: Vec<f64>,
    pub link_bytes: Vec<f64>,
    pub link_busy_s: Vec<f64>,
    pub link_transfers: Vec<u64>,
    pub intra_busy: Vec<f64>,
}

/// Region graph + per-link state driving hierarchical two-level sync.
#[derive(Debug)]
pub struct TopoNet {
    cfg: TopologyConfig,
    /// worker index → region index.
    region_of: Vec<usize>,
    /// Region → sorted member worker indices (leader = first live member).
    members: Vec<Vec<usize>>,
    links: Vec<Link>,
    /// index[from][to] → link id.
    index: Vec<Vec<Option<usize>>>,
    /// Canonical region ring r→(r+1)%R as link ids (empty when R < 2).
    canonical: Vec<usize>,
    /// Per-region LAN timeline.
    intra_busy: Vec<f64>,
    /// Observations from the latest hierarchical schedule (reused buffer).
    last_obs: Vec<LinkObs>,
    /// Scratch: participating regions / hop link ids of the current schedule.
    parts: Vec<usize>,
    hops: Vec<usize>,
}

impl TopoNet {
    pub fn new(cfg: TopologyConfig, workers: usize) -> anyhow::Result<TopoNet> {
        anyhow::ensure!(!cfg.is_flat(), "TopoNet requires a multi-region topology");
        cfg.validate(workers)?;
        let r = cfg.n_regions();
        let region_of: Vec<usize> = (0..workers).map(|w| cfg.region_of(w, workers)).collect();
        let mut members = vec![Vec::new(); r];
        for (w, &reg) in region_of.iter().enumerate() {
            members[reg].push(w);
        }
        let mut links = Vec::new();
        let mut index = vec![vec![None; r]; r];
        for a in 0..r {
            for b in 0..r {
                if let Some(spec) = cfg.links[a][b] {
                    index[a][b] = Some(links.len());
                    links.push(Link {
                        from: a,
                        to: b,
                        spec,
                        busy_until: 0.0,
                        bytes: 0.0,
                        busy_s: 0.0,
                        transfers: 0,
                    });
                }
            }
        }
        let canonical: Vec<usize> = if r >= 2 {
            (0..r)
                .map(|i| index[i][(i + 1) % r].expect("canonical ring validated"))
                .collect()
        } else {
            Vec::new()
        };
        Ok(TopoNet {
            cfg,
            region_of,
            members,
            links,
            index,
            canonical,
            intra_busy: vec![0.0; r],
            last_obs: Vec::new(),
            parts: Vec::new(),
            hops: Vec::new(),
        })
    }

    pub fn n_regions(&self) -> usize {
        self.cfg.n_regions()
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    pub fn link_spec(&self, id: usize) -> &LinkSpec {
        &self.links[id].spec
    }

    pub fn link_busy(&self, id: usize) -> f64 {
        self.links[id].busy_until
    }

    pub fn link_between(&self, from: usize, to: usize) -> Option<usize> {
        self.index[from][to]
    }

    pub fn region_of_worker(&self, worker: usize) -> usize {
        self.region_of[worker]
    }

    /// "us->eu"-style directed link name.
    pub fn link_name(&self, id: usize) -> String {
        let l = &self.links[id];
        format!("{}->{}", self.cfg.regions[l.from], self.cfg.regions[l.to])
    }

    /// The region's leader: its lowest-index live worker. A crashed leader
    /// fails over to the next live member; `None` when the whole region is
    /// down (it then drops out of the WAN ring entirely).
    pub fn leader(&self, region: usize, live: &[bool]) -> Option<usize> {
        self.members[region]
            .iter()
            .copied()
            .find(|&w| live.get(w).copied().unwrap_or(true))
    }

    /// Regions with at least one live worker, ascending. `None` = all live.
    pub fn participating_into(&self, live: Option<&[bool]>, out: &mut Vec<usize>) {
        out.clear();
        for (r, members) in self.members.iter().enumerate() {
            let any = match live {
                Some(lv) => members.iter().any(|&w| lv.get(w).copied().unwrap_or(true)),
                None => true,
            };
            if any {
                out.push(r);
            }
        }
    }

    /// Is `link` severed at `t` by a regional outage on either endpoint?
    pub fn severed(&self, link: usize, faults: &FaultPlan, t: f64) -> bool {
        let l = &self.links[link];
        faults.regional_outage_end(l.from, t).is_some()
            || faults.regional_outage_end(l.to, t).is_some()
    }

    /// Append the link ids carrying traffic from region `a` to `b`: the
    /// direct link when present, otherwise a relay walk over the canonical
    /// region ring (the traffic traverses intermediate POPs).
    fn push_hops(&mut self, a: usize, b: usize) {
        if let Some(l) = self.index[a][b] {
            self.hops.push(l);
            return;
        }
        let r = self.cfg.n_regions();
        let mut cur = a;
        while cur != b {
            let next = (cur + 1) % r;
            if let Some(l) = self.index[cur][next] {
                self.hops.push(l);
            }
            cur = next;
        }
    }

    /// Schedule one hierarchical all-reduce of `bytes` requested at `now`.
    /// `route`, when given, is the cycle of link ids to use for the inter
    /// phase (CoCoDC's adaptive per-link scheduler builds it); otherwise the
    /// canonical ring over the participating regions is used. Returns
    /// (start, finish) of the whole three-phase operation.
    pub fn schedule(
        &mut self,
        now: f64,
        bytes: f64,
        route: Option<&[usize]>,
        live: &[bool],
        faults: &FaultPlan,
        jitter: &mut Rng,
    ) -> (f64, f64) {
        self.parts.clear();
        for (r, members) in self.members.iter().enumerate() {
            if members.iter().any(|&w| live.get(w).copied().unwrap_or(true)) {
                self.parts.push(r);
            }
        }
        self.last_obs.clear();
        if self.parts.is_empty() {
            return (now, now);
        }

        // Phase 1: intra-region ring all-reduce on each region's LAN.
        let mut first_start = f64::INFINITY;
        let mut intra_done = now;
        for &r in &self.parts {
            let m_live = self.live_members(r, live);
            let spec = self.cfg.intra[r];
            let start_r = now.max(self.intra_busy[r]);
            let mut dur =
                ring::ring_allreduce_time(bytes, m_live, spec.latency_s, spec.bandwidth_bps);
            if spec.jitter > 0.0 && dur > 0.0 {
                let u = 2.0 * jitter.next_f64() - 1.0;
                dur *= 1.0 + spec.jitter * u;
            }
            self.intra_busy[r] = start_r + dur;
            first_start = first_start.min(start_r);
            intra_done = intra_done.max(start_r + dur);
        }
        if self.parts.len() < 2 {
            return (first_start, intra_done);
        }

        // Phase 2: ring over the region leaders on per-link WAN timelines.
        self.hops.clear();
        match route {
            Some(r) => self.hops.extend_from_slice(r),
            None => {
                let k = self.parts.len();
                for i in 0..k {
                    let a = self.parts[i];
                    let b = self.parts[(i + 1) % k];
                    self.push_hops(a, b);
                }
            }
        }
        // The phase starts once the slowest intra phase is done, every
        // routed link is free, and no outage (global or regional, chained
        // windows chased to a fixpoint) covers the start.
        let mut start = intra_done;
        loop {
            let mut t = start;
            for &l in &self.hops {
                t = t.max(self.links[l].busy_until);
            }
            if let Some(e) = faults.outage_end(t) {
                t = t.max(e);
            }
            for &l in &self.hops {
                let (a, b) = (self.links[l].from, self.links[l].to);
                if let Some(e) = faults.regional_outage_end(a, t) {
                    t = t.max(e);
                }
                if let Some(e) = faults.regional_outage_end(b, t) {
                    t = t.max(e);
                }
            }
            if t == start {
                break;
            }
            start = t;
        }
        let rr = self.parts.len() as f64;
        let chunk = bytes / rr;
        let rounds = 2.0 * (rr - 1.0);
        let bw_factor = faults.bandwidth_factor(start);
        let mut round_time = 0.0f64;
        for &l in &self.hops {
            let spec = self.links[l].spec;
            let mut hop = spec.latency_s + chunk / (spec.bandwidth_bps * bw_factor);
            if spec.jitter > 0.0 {
                let u = 2.0 * jitter.next_f64() - 1.0;
                hop *= 1.0 + spec.jitter * u;
            }
            round_time = round_time.max(hop);
            self.last_obs.push(LinkObs { link: l, hop_s: hop, chunk_bytes: chunk });
        }
        let finish = start + rounds * round_time;
        for &l in &self.hops {
            let link = &mut self.links[l];
            link.busy_s += finish - start;
            link.busy_until = link.busy_until.max(finish);
            link.bytes += chunk * rounds;
            link.transfers += 1;
        }

        // Phase 3: leaders broadcast the reduced payload over the LAN.
        let mut done = finish;
        for &r in &self.parts {
            if self.live_members(r, live) <= 1 {
                continue;
            }
            let spec = self.cfg.intra[r];
            let start_b = finish.max(self.intra_busy[r]);
            let mut dur = spec.latency_s + bytes / spec.bandwidth_bps;
            if spec.jitter > 0.0 {
                let u = 2.0 * jitter.next_f64() - 1.0;
                dur *= 1.0 + spec.jitter * u;
            }
            self.intra_busy[r] = start_b + dur;
            done = done.max(start_b + dur);
        }
        (first_start.min(start), done)
    }

    fn live_members(&self, region: usize, live: &[bool]) -> usize {
        self.members[region]
            .iter()
            .filter(|&&w| live.get(w).copied().unwrap_or(true))
            .count()
    }

    /// Pure (queue-free, fault-free, all-live) cost of one hierarchical
    /// all-reduce: slowest intra all-reduce + canonical-ring inter phase +
    /// slowest broadcast. The topology-mode analogue of the flat ring time.
    pub fn t_sync_estimate(&self, bytes: f64) -> f64 {
        let r = self.cfg.n_regions();
        let mut intra_max = 0.0f64;
        let mut bcast_max = 0.0f64;
        for (i, m) in self.members.iter().enumerate() {
            let spec = self.cfg.intra[i];
            let t = ring::ring_allreduce_time(bytes, m.len(), spec.latency_s, spec.bandwidth_bps);
            intra_max = intra_max.max(t);
            if m.len() > 1 {
                bcast_max = bcast_max.max(spec.latency_s + bytes / spec.bandwidth_bps);
            }
        }
        let mut inter = 0.0;
        if r >= 2 {
            let chunk = bytes / r as f64;
            let mut round = 0.0f64;
            for &l in &self.canonical {
                let spec = self.links[l].spec;
                round = round.max(spec.latency_s + chunk / spec.bandwidth_bps);
            }
            inter = 2.0 * (r as f64 - 1.0) * round;
        }
        intra_max + inter + bcast_max
    }

    /// Per-link observations from the most recent [`TopoNet::schedule`].
    pub fn last_obs(&self) -> &[LinkObs] {
        &self.last_obs
    }

    /// Per-link utilization counters for end-of-run reporting.
    pub fn link_utils(&self) -> Vec<LinkUtil> {
        (0..self.links.len())
            .map(|i| LinkUtil {
                name: self.link_name(i),
                bytes: self.links[i].bytes,
                busy_s: self.links[i].busy_s,
                transfers: self.links[i].transfers,
            })
            .collect()
    }

    pub fn snapshot(&self) -> TopoState {
        TopoState {
            link_busy: self.links.iter().map(|l| l.busy_until).collect(),
            link_bytes: self.links.iter().map(|l| l.bytes).collect(),
            link_busy_s: self.links.iter().map(|l| l.busy_s).collect(),
            link_transfers: self.links.iter().map(|l| l.transfers).collect(),
            intra_busy: self.intra_busy.clone(),
        }
    }

    /// Restore per-link timelines from a snapshot of matching shape.
    pub fn restore(&mut self, st: &TopoState) {
        debug_assert_eq!(st.link_busy.len(), self.links.len());
        for (i, l) in self.links.iter_mut().enumerate() {
            l.busy_until = st.link_busy[i];
            l.bytes = st.link_bytes[i];
            l.busy_s = st.link_busy_s[i];
            l.transfers = st.link_transfers[i];
        }
        self.intra_busy.copy_from_slice(&st.intra_busy);
    }

    /// Zero every timeline/counter (used when restoring a legacy flat
    /// checkpoint that carries no per-link section).
    pub fn reset(&mut self) {
        for l in &mut self.links {
            l.busy_until = 0.0;
            l.bytes = 0.0;
            l.busy_s = 0.0;
            l.transfers = 0;
        }
        self.intra_busy.fill(0.0);
        self.last_obs.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultConfig, FaultWindow, RegionalOutage};

    fn topo(name: &str) -> TopoNet {
        TopoNet::new(TopologyConfig::preset(name).unwrap(), 8).unwrap()
    }

    fn no_faults() -> FaultPlan {
        FaultPlan::new(FaultConfig::default(), 1)
    }

    #[test]
    fn leader_is_lowest_live_member_and_fails_over() {
        let t = topo("us-eu");
        // 8 workers over 2 regions: us = {0..3}, eu = {4..7}.
        assert_eq!(t.leader(0, &[true; 8]), Some(0));
        assert_eq!(t.leader(1, &[true; 8]), Some(4));
        let mut live = [true; 8];
        live[0] = false;
        assert_eq!(t.leader(0, &live), Some(1));
        live[1] = false;
        live[2] = false;
        live[3] = false;
        assert_eq!(t.leader(0, &live), None);
        let mut parts = Vec::new();
        t.participating_into(Some(&live), &mut parts);
        assert_eq!(parts, vec![1]);
    }

    #[test]
    fn hierarchical_schedule_beats_flat_ring_on_global4() {
        let mut t = topo("global-4");
        let faults = no_faults();
        let mut rng = Rng::new(1, 0xC0C0);
        let bytes = 4e6;
        let (start, finish) = t.schedule(0.0, bytes, None, &[true; 8], &faults, &mut rng);
        assert_eq!(start, 0.0);
        // Flat single-link equivalent at the matched mean budget.
        let (net, _) = crate::config::net_preset("global-4").unwrap();
        let flat = ring::ring_allreduce_time(bytes, 8, net.latency_s, net.bandwidth_bps);
        assert!(
            finish < flat,
            "hierarchical {finish} should beat flat {flat} on global-4"
        );
        // Estimate agrees with the queue-free schedule.
        assert!((t.t_sync_estimate(bytes) - finish).abs() < 1e-9);
    }

    #[test]
    fn links_own_serialized_timelines() {
        let mut t = topo("us-eu");
        let faults = no_faults();
        let mut rng = Rng::new(1, 0xC0C0);
        let (_, f1) = t.schedule(0.0, 1e6, None, &[true; 8], &faults, &mut rng);
        let (s2, f2) = t.schedule(0.0, 1e6, None, &[true; 8], &faults, &mut rng);
        // Second transfer queues behind the first on the same links (the
        // intra tier overlaps, but the WAN phase serializes).
        assert!(f2 > f1);
        assert!(s2 <= f1);
        for l in t.links() {
            assert_eq!(l.transfers, 2);
            assert!(l.busy_s > 0.0);
            assert!(l.bytes > 0.0);
        }
    }

    #[test]
    fn regional_outage_delays_the_wan_phase_only() {
        let mut plan = FaultConfig::default();
        plan.regional_outages.push(RegionalOutage {
            region: 1,
            window: FaultWindow { start_s: 0.0, duration_s: 50.0 },
        });
        let faults = FaultPlan::new(plan, 1);
        let mut t = topo("us-eu");
        let mut rng = Rng::new(1, 0xC0C0);
        let (start, finish) = t.schedule(0.0, 1e6, None, &[true; 8], &faults, &mut rng);
        // Intra phase starts immediately; the WAN ring waits out the window.
        assert_eq!(start, 0.0);
        assert!(finish > 50.0);
        assert!(t.severed(0, &faults, 10.0));
        assert!(!t.severed(0, &faults, 60.0));
    }

    #[test]
    fn dead_region_drops_out_and_single_region_skips_wan() {
        let mut t = topo("us-eu");
        let faults = no_faults();
        let mut rng = Rng::new(1, 0xC0C0);
        // eu fully down: only us participates, no WAN traffic at all.
        let live = [true, true, true, true, false, false, false, false];
        let (_, finish) = t.schedule(0.0, 1e6, None, &live, &faults, &mut rng);
        let spec = TopologyConfig::preset("us-eu").unwrap().intra[0];
        let lan = ring::ring_allreduce_time(1e6, 4, spec.latency_s, spec.bandwidth_bps);
        assert!((finish - lan).abs() < 1e-9);
        for l in t.links() {
            assert_eq!(l.transfers, 0);
        }
    }

    #[test]
    fn relay_fallback_routes_over_the_canonical_ring() {
        let mut cfg = TopologyConfig::preset("global-4").unwrap();
        // Remove the direct us↔ap links; the canonical ring stays intact.
        cfg.links[0][2] = None;
        cfg.links[2][0] = None;
        let mut t = TopoNet::new(cfg, 8).unwrap();
        let faults = no_faults();
        let mut rng = Rng::new(1, 0xC0C0);
        // Kill eu and sa so the ring must connect us and ap without a
        // direct link.
        let live = [true, true, false, false, true, true, false, false];
        let (_, finish) = t.schedule(0.0, 1e6, None, &live, &faults, &mut rng);
        assert!(finish > 0.0);
        // Relay traffic showed up on canonical-ring links.
        let moved: u64 = t.links().iter().map(|l| l.transfers).sum();
        assert!(moved > 0);
    }

    #[test]
    fn snapshot_restore_round_trips_and_reset_zeroes() {
        let mut t = topo("global-4");
        let faults = no_faults();
        let mut rng = Rng::new(1, 0xC0C0);
        t.schedule(0.0, 2e6, None, &[true; 8], &faults, &mut rng);
        t.schedule(1.0, 2e6, None, &[true; 8], &faults, &mut rng);
        let snap = t.snapshot();
        let mut fresh = topo("global-4");
        fresh.restore(&snap);
        assert_eq!(fresh.snapshot(), snap);
        let mut rng2 = Rng::new(1, 0xC0C0);
        let a = t.schedule(5.0, 1e6, None, &[true; 8], &faults, &mut rng);
        let b = fresh.schedule(5.0, 1e6, None, &[true; 8], &faults, &mut rng2);
        assert_eq!(a, b);
        fresh.reset();
        assert_eq!(fresh.snapshot(), topo("global-4").snapshot());
    }

    #[test]
    fn explicit_route_uses_exactly_those_links() {
        let mut t = topo("global-4");
        let faults = no_faults();
        let mut rng = Rng::new(1, 0xC0C0);
        // Reverse cycle 0→3→2→1→0 instead of the canonical 0→1→2→3→0.
        let route: Vec<usize> = [(0usize, 3usize), (3, 2), (2, 1), (1, 0)]
            .iter()
            .map(|&(a, b)| t.link_between(a, b).unwrap())
            .collect();
        t.schedule(0.0, 1e6, Some(&route), &[true; 8], &faults, &mut rng);
        for &l in &route {
            assert_eq!(t.links()[l].transfers, 1);
        }
        let unused = t.link_between(0, 1).unwrap();
        assert_eq!(t.links()[unused].transfers, 0);
    }
}
