//! Runtime fault plan: evaluates the scripted [`FaultConfig`] against the
//! virtual clock and owns the transfer-loss RNG stream.
//!
//! Determinism contract (DESIGN.md §Faults): every probabilistic draw flows
//! through a dedicated seeded xoshiro stream (`0xFA17`), separate from the
//! jitter stream, so adding faults never perturbs jitter sequences and a
//! (seed, plan) pair fully determines which transfers are lost. The stream
//! is only consumed when `transfer_loss_prob > 0`, and its position is
//! checkpointable alongside the jitter RNG so resumed runs replay the same
//! losses.

use crate::config::{FaultConfig, RetryPolicy};
use crate::util::Rng;

#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    rng: Rng,
    /// Payload-corruption stream (`0xB17F`), separate from the loss stream
    /// so enabling corruption never perturbs which transfers are dropped.
    corrupt_rng: Rng,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig, seed: u64) -> Self {
        FaultPlan {
            cfg,
            rng: Rng::new(seed, 0xFA17),
            corrupt_rng: Rng::new(seed, 0xB17F),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn is_active(&self) -> bool {
        self.cfg.is_active()
    }

    pub fn retry(&self) -> RetryPolicy {
        self.cfg.retry
    }

    /// If `t` falls inside an outage, the end of the *latest* outage window
    /// covering it (adjacent/overlapping windows chain).
    pub fn outage_end(&self, t: f64) -> Option<f64> {
        let mut cursor = t;
        let mut end = None;
        // Chase chained windows: an outage ending inside another extends it.
        loop {
            let mut advanced = false;
            for o in &self.cfg.outages {
                if o.contains(cursor) && o.end_s() > cursor {
                    cursor = o.end_s();
                    end = Some(cursor);
                    advanced = true;
                }
            }
            if !advanced {
                return end;
            }
        }
    }

    /// If region `r`'s WAN links are severed at `t`, the end of the latest
    /// regional-outage window covering it (chained windows chase like
    /// [`FaultPlan::outage_end`]). Only meaningful with a region topology;
    /// flat plans have no regional outages (config validation enforces it).
    pub fn regional_outage_end(&self, region: usize, t: f64) -> Option<f64> {
        let mut cursor = t;
        let mut end = None;
        loop {
            let mut advanced = false;
            for o in &self.cfg.regional_outages {
                if o.region == region && o.window.contains(cursor) && o.window.end_s() > cursor {
                    cursor = o.window.end_s();
                    end = Some(cursor);
                    advanced = true;
                }
            }
            if !advanced {
                return end;
            }
        }
    }

    /// Effective-bandwidth multiplier at time `t` (stacked degradation
    /// windows multiply).
    pub fn bandwidth_factor(&self, t: f64) -> f64 {
        let mut f = 1.0;
        for d in &self.cfg.degradations {
            if d.window.contains(t) {
                f *= d.bandwidth_factor;
            }
        }
        f
    }

    /// Draw whether the next transfer is lost in flight. Consumes the RNG
    /// stream only when loss is enabled, so fault-free plans (and plans with
    /// outages but no loss) stay bit-identical to builds without this call.
    pub fn draw_loss(&mut self) -> bool {
        self.cfg.transfer_loss_prob > 0.0 && self.rng.next_f64() < self.cfg.transfer_loss_prob
    }

    /// Corruption probability at time `t`: overlapping windows combine as
    /// independent corruption events, `1 − Π(1 − p_i)`.
    pub fn corruption_prob(&self, t: f64) -> f64 {
        let mut survive = 1.0;
        for c in &self.cfg.corruptions {
            if c.window.contains(t) {
                survive *= 1.0 - c.prob;
            }
        }
        1.0 - survive
    }

    /// Draw whether a transfer *departing* at `t` is corrupted in flight.
    /// `Some(draw)` carries a seeded u64 the receiver uses to pick which
    /// payload bit to flip; `None` means the payload arrives intact. The
    /// stream is only consumed when a corruption window covers `t`, so runs
    /// without corruption faults stay bit-identical.
    pub fn draw_corruption(&mut self, t: f64) -> Option<u64> {
        let p = self.corruption_prob(t);
        if p <= 0.0 {
            return None;
        }
        if self.corrupt_rng.next_f64() < p {
            Some(self.corrupt_rng.next_u64())
        } else {
            None
        }
    }

    /// Is `worker` inside one of its crash windows at time `t`?
    pub fn is_crashed(&self, worker: usize, t: f64) -> bool {
        self.cfg
            .crashes
            .iter()
            .any(|c| c.worker == worker && c.window.contains(t))
    }

    /// Per-step compute-time multiplier: the synchronous inner loop paces at
    /// the slowest *live* worker, so this is the max straggler multiplier
    /// over workers marked live (1.0 with no stragglers or all crashed).
    pub fn compute_multiplier(&self, live: &[bool]) -> f64 {
        if self.cfg.stragglers.is_empty() {
            return 1.0;
        }
        let mut m = 1.0f64;
        for (w, &alive) in live.iter().enumerate() {
            if alive {
                if let Some(&s) = self.cfg.stragglers.get(w) {
                    m = m.max(s);
                }
            }
        }
        m
    }

    /// Loss-RNG state for checkpointing (jitter RNG is captured separately
    /// by the simulator).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    pub fn restore_rng(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    pub fn corrupt_rng_state(&self) -> [u64; 4] {
        self.corrupt_rng.state()
    }

    pub fn restore_corrupt_rng(&mut self, s: [u64; 4]) {
        self.corrupt_rng = Rng::from_state(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CrashWindow, Degradation, FaultWindow};

    fn window(start: f64, dur: f64) -> FaultWindow {
        FaultWindow { start_s: start, duration_s: dur }
    }

    #[test]
    fn outage_end_chases_chained_windows() {
        let cfg = FaultConfig {
            outages: vec![window(10.0, 5.0), window(14.0, 10.0)],
            ..Default::default()
        };
        let p = FaultPlan::new(cfg, 1);
        assert_eq!(p.outage_end(5.0), None);
        assert_eq!(p.outage_end(11.0), Some(24.0)); // 10→15 chains into 14→24
        assert_eq!(p.outage_end(20.0), Some(24.0));
        assert_eq!(p.outage_end(24.0), None);
    }

    #[test]
    fn regional_outage_end_is_per_region_and_chases_chains() {
        use crate::config::RegionalOutage;
        let cfg = FaultConfig {
            regional_outages: vec![
                RegionalOutage { region: 1, window: window(10.0, 5.0) },
                RegionalOutage { region: 1, window: window(14.0, 10.0) },
                RegionalOutage { region: 2, window: window(0.0, 3.0) },
            ],
            ..Default::default()
        };
        let p = FaultPlan::new(cfg, 1);
        assert_eq!(p.regional_outage_end(1, 5.0), None);
        assert_eq!(p.regional_outage_end(1, 11.0), Some(24.0));
        assert_eq!(p.regional_outage_end(2, 11.0), None);
        assert_eq!(p.regional_outage_end(2, 1.0), Some(3.0));
        assert_eq!(p.regional_outage_end(0, 11.0), None);
        assert!(p.is_active());
    }

    #[test]
    fn degradations_stack_multiplicatively() {
        let cfg = FaultConfig {
            degradations: vec![
                Degradation { window: window(0.0, 100.0), bandwidth_factor: 0.5 },
                Degradation { window: window(50.0, 10.0), bandwidth_factor: 0.4 },
            ],
            ..Default::default()
        };
        let p = FaultPlan::new(cfg, 1);
        assert!((p.bandwidth_factor(10.0) - 0.5).abs() < 1e-12);
        assert!((p.bandwidth_factor(55.0) - 0.2).abs() < 1e-12);
        assert!((p.bandwidth_factor(200.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn loss_draws_are_deterministic_and_skip_rng_when_disabled() {
        let cfg = FaultConfig { transfer_loss_prob: 0.5, ..Default::default() };
        let mut a = FaultPlan::new(cfg.clone(), 7);
        let mut b = FaultPlan::new(cfg, 7);
        for _ in 0..64 {
            assert_eq!(a.draw_loss(), b.draw_loss());
        }
        // Disabled loss must not consume the stream.
        let mut c = FaultPlan::new(FaultConfig::default(), 7);
        let before = c.rng_state();
        for _ in 0..64 {
            assert!(!c.draw_loss());
        }
        assert_eq!(c.rng_state(), before);
    }

    #[test]
    fn loss_rng_state_round_trips() {
        let cfg = FaultConfig { transfer_loss_prob: 0.3, ..Default::default() };
        let mut a = FaultPlan::new(cfg.clone(), 9);
        for _ in 0..17 {
            a.draw_loss();
        }
        let mut b = FaultPlan::new(cfg, 1234);
        b.restore_rng(a.rng_state());
        for _ in 0..50 {
            assert_eq!(a.draw_loss(), b.draw_loss());
        }
    }

    #[test]
    fn corruption_draws_are_windowed_deterministic_and_skip_rng_when_off() {
        use crate::config::Corruption;
        let cfg = FaultConfig {
            corruptions: vec![
                Corruption { window: window(10.0, 10.0), prob: 0.5 },
                Corruption { window: window(15.0, 10.0), prob: 0.5 },
            ],
            ..Default::default()
        };
        let mut a = FaultPlan::new(cfg.clone(), 7);
        let mut b = FaultPlan::new(cfg.clone(), 7);
        // Overlap combines as independent events: 1 − 0.5·0.5 = 0.75.
        assert!((a.corruption_prob(17.0) - 0.75).abs() < 1e-12);
        assert!((a.corruption_prob(12.0) - 0.5).abs() < 1e-12);
        assert!((a.corruption_prob(30.0)).abs() < 1e-12);
        let mut hits = 0;
        for i in 0..64 {
            let t = 10.0 + (i as f64) * 0.2;
            let da = a.draw_corruption(t);
            assert_eq!(da, b.draw_corruption(t));
            hits += da.is_some() as usize;
        }
        assert!(hits > 0, "a 0.5+ prob window should corrupt something");
        // Outside every window (or with no corruption configured) the
        // stream must not advance.
        let before = a.corrupt_rng_state();
        assert_eq!(a.draw_corruption(99.0), None);
        assert_eq!(a.corrupt_rng_state(), before);
        let mut off = FaultPlan::new(FaultConfig::default(), 7);
        let before = off.corrupt_rng_state();
        for i in 0..32 {
            assert_eq!(off.draw_corruption(i as f64), None);
        }
        assert_eq!(off.corrupt_rng_state(), before);
    }

    #[test]
    fn corruption_rng_state_round_trips() {
        use crate::config::Corruption;
        let cfg = FaultConfig {
            corruptions: vec![Corruption { window: window(0.0, 1e9), prob: 0.4 }],
            ..Default::default()
        };
        let mut a = FaultPlan::new(cfg.clone(), 9);
        for i in 0..17 {
            a.draw_corruption(i as f64);
        }
        let mut b = FaultPlan::new(cfg, 1234);
        b.restore_corrupt_rng(a.corrupt_rng_state());
        for i in 0..50 {
            assert_eq!(a.draw_corruption(i as f64), b.draw_corruption(i as f64));
        }
    }

    #[test]
    fn crash_windows_and_straggler_pacing() {
        let cfg = FaultConfig {
            stragglers: vec![1.0, 1.8, 1.0, 1.2],
            crashes: vec![CrashWindow { worker: 3, window: window(10.0, 5.0) }],
            ..Default::default()
        };
        let p = FaultPlan::new(cfg, 1);
        assert!(!p.is_crashed(3, 9.0));
        assert!(p.is_crashed(3, 12.0));
        assert!(!p.is_crashed(3, 15.0));
        assert!(!p.is_crashed(0, 12.0));
        assert!((p.compute_multiplier(&[true; 4]) - 1.8).abs() < 1e-12);
        // Slowest worker crashed → pace at the next-slowest live one.
        assert!((p.compute_multiplier(&[true, false, true, true]) - 1.2).abs() < 1e-12);
        let none = FaultPlan::new(FaultConfig::default(), 1);
        assert!((none.compute_multiplier(&[true; 4]) - 1.0).abs() < 1e-12);
    }
}
