//! `cocodc` CLI — leader entrypoint for cross-region training runs.
//!
//! ```text
//! cocodc train --preset exp --method cocodc --steps 1200       # one run
//! cocodc compare --preset exp --steps 1200                     # all three
//! cocodc info --preset exp                                     # artifacts
//! cocodc emit-config > run.json                                # template
//! cocodc train --config run.json                               # from file
//! ```

use std::path::PathBuf;

use cocodc::config::{Corruption, FaultConfig, FaultWindow, MethodKind, RunConfig, TauMode};
use cocodc::metrics::{table1, write_curves_csv};
use cocodc::runtime::{load_backend, Backend, BackendKind};
use cocodc::util::cli::Args;
use cocodc::Trainer;

const USAGE: &str = "\
cocodc — CoCoDC cross-region training coordinator

USAGE: cocodc <train|compare|info|emit-config> [flags]

common flags:
  --artifacts DIR     artifacts directory (default: artifacts)
  --preset NAME       preset (tiny|exp|e2e; default: exp)
  --backend B         execution backend: auto|pjrt|native (default auto —
                      pjrt when the preset's artifacts exist, else the
                      pure-rust native transformer; native needs no
                      artifacts at all)

train/compare flags:
  --config FILE       load RunConfig JSON (other flags override)
  --method M          diloco|streaming|cocodc (train only; default cocodc)
  --steps N           total local steps
  --workers M         number of simulated datacenters (default 4)
  --h N               local computation period H (default 100)
  --tau N             fixed overlap depth (default 5)
  --tau-network       derive tau from the WAN simulator
  --alpha X --lambda X --gamma X --seed N --eval-every N
  --threads N         thread budget for the shared worker/compute pool:
                      0 = auto (host parallelism), 1 = fully serial, N > 1
                      pins the pool size; results are bit-identical for
                      every N (row shards are a function of the model shape,
                      not the thread count)
  --codec C           pseudo-gradient wire codec: none|int8|int4
  --net-preset P      WAN shape: flat|us-eu|global-4 — expands to a matched
                      flat NetworkConfig + multi-region TopologyConfig
                      (hierarchical two-level sync over per-link timelines);
                      conflicts with the raw link overrides below
  --latency S         flat WAN link one-way latency, seconds
  --bandwidth BPS     flat WAN link bandwidth, bytes/second
  --jitter X          multiplicative jitter fraction on the flat link
  --fault-severity X  scripted WAN fault scenario of severity X in (0,1]:
                      link outage + bandwidth degradation + transfer loss
                      + straggler + worker crash/recover, scaled by X
  --fault-corruption P  corrupt each delivered fragment payload with
                      probability P in (0,1] (in-flight bit flips; corrupt
                      payloads are quarantined and retransmitted)
  --snapshot-every N  snapshot the full run state into a durable checkpoint
                      ring every N steps (0 = off; enables the divergence
                      sentinel + rollback)
  --snapshot-ring K   keep the last K ring snapshots (default 4)
  --snapshot-dir DIR  ring directory (default: checkpoints/ring)
  --resume            resume from the newest loadable ring snapshot
                      (train only; torn/corrupt snapshots are skipped)
  --hlo-fragment-ops  run outer/delay-comp through Pallas artifacts
  --out FILE          write validation curve CSV
  --save FILE         write final checkpoint (train only)
  --ppl X             PPL threshold for the comparison table (default 20)
  --quiet             suppress per-eval logging
";

const BOOL_FLAGS: &[&str] = &["tau-network", "hlo-fragment-ops", "quiet", "resume"];

fn build_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_json_file(path)?,
        None => RunConfig::paper(
            args.get("preset").unwrap_or("exp"),
            MethodKind::parse(args.get("method").unwrap_or("cocodc"))?,
        ),
    };
    if args.get("config").is_some() {
        if let Some(p) = args.get("preset") {
            cfg.preset = p.to_string();
        }
        if let Some(m) = args.get("method") {
            cfg.method = MethodKind::parse(m)?;
        }
    }
    if let Some(v) = args.get_parse::<u32>("steps")? {
        cfg.total_steps = v;
    }
    if let Some(v) = args.get_parse::<usize>("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_parse::<u32>("h")? {
        cfg.h_steps = v;
    }
    if args.switch("tau-network") {
        cfg.tau = TauMode::Network;
    } else if let Some(v) = args.get_parse::<u32>("tau")? {
        cfg.tau = TauMode::Fixed { tau: v };
    }
    if let Some(v) = args.get_parse::<f32>("alpha")? {
        cfg.alpha = v;
    }
    if let Some(v) = args.get_parse::<f32>("lambda")? {
        cfg.lambda = v;
    }
    if let Some(v) = args.get_parse::<f64>("gamma")? {
        cfg.gamma = v;
    }
    if let Some(v) = args.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get_parse::<u32>("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = args.get_parse::<usize>("threads")? {
        // 1 means fully serial: no pool at all, the strongest baseline for
        // the bit-identity guarantee. 0 and N>1 size the shared pool.
        cfg.threads = v;
        cfg.parallel_workers = v != 1;
    }
    if args.switch("hlo-fragment-ops") {
        cfg.use_hlo_fragment_ops = true;
    }
    if let Some(c) = args.get("codec") {
        cfg.compression = cocodc::compression::Codec::parse(c)?;
    }
    // WAN shape: a named preset expands to its matched network + topology
    // pair; raw flags tune the flat link directly. Mixing the two would
    // silently skew the preset's matched WAN budget, so it is an error.
    if let Some(name) = args.get("net-preset") {
        let raw: Vec<&str> = ["latency", "bandwidth", "jitter"]
            .iter()
            .copied()
            .filter(|f| args.get(f).is_some())
            .collect();
        anyhow::ensure!(
            raw.is_empty(),
            "--net-preset {name} conflicts with raw link overrides (--{}); use one or the other",
            raw.join(", --")
        );
        let (net, topo) = cocodc::config::net_preset(name)?;
        let step = cfg.network.step_compute_s;
        cfg.network = net;
        cfg.network.step_compute_s = step;
        cfg.topology = topo;
    } else {
        if let Some(v) = args.get_parse::<f64>("latency")? {
            cfg.network.latency_s = v;
        }
        if let Some(v) = args.get_parse::<f64>("bandwidth")? {
            cfg.network.bandwidth_bps = v;
        }
        if let Some(v) = args.get_parse::<f64>("jitter")? {
            cfg.network.jitter = v;
        }
    }
    if let Some(sev) = args.get_parse::<f64>("fault-severity")? {
        // Scenario windows are placed relative to the compute-only horizon;
        // stalls only push the run further past them.
        let horizon = cfg.total_steps as f64 * cfg.network.step_compute_s;
        cfg.faults = FaultConfig::scenario(sev, horizon, cfg.workers);
    }
    if let Some(prob) = args.get_parse::<f64>("fault-corruption")? {
        // Whole-run corruption window (composes with --fault-severity's
        // scenario, which replaces cfg.faults wholesale above).
        cfg.faults.corruptions.push(Corruption {
            window: FaultWindow { start_s: 0.0, duration_s: f64::INFINITY },
            prob,
        });
    }
    if let Some(v) = args.get_parse::<u32>("snapshot-every")? {
        cfg.recovery.snapshot_every = v;
    }
    if let Some(v) = args.get_parse::<usize>("snapshot-ring")? {
        cfg.recovery.snapshot_ring = v;
    }
    if let Some(d) = args.get("snapshot-dir") {
        cfg.recovery.snapshot_dir = d.to_string();
    }
    if cfg.recovery.snapshot_every > 0 && cfg.recovery.snapshot_dir.is_empty() {
        cfg.recovery.snapshot_dir = "checkpoints/ring".to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

fn build_backend(
    args: &Args,
    artifacts: &std::path::Path,
    preset: &str,
    use_hlo_fragment_ops: bool,
) -> anyhow::Result<Box<dyn Backend>> {
    let kind = BackendKind::parse(args.get("backend").unwrap_or("auto"))?;
    let backend = load_backend(kind, artifacts, preset, use_hlo_fragment_ops)?;
    eprintln!(
        "backend: '{preset}' on {} ({} params, K={})",
        backend.platform(),
        backend.param_count(),
        backend.fragments().k()
    );
    Ok(backend)
}

fn summarize(o: &cocodc::TrainOutcome) {
    println!(
        "[{}] steps={} wall={:.1}s (compute {:.1}s, stall {:.1}s) syncs={}/{} \
         guard_hits={} stalls={} sent={:.1}MB final_val_ppl={:.3} real={:.1}s",
        o.method,
        o.curve.points.last().map(|p| p.step).unwrap_or(0),
        o.wall_s,
        o.compute_s,
        o.comm_stall_s,
        o.syncs_completed,
        o.syncs_initiated,
        o.staleness_guard_hits,
        o.apply_stalls,
        o.bytes_sent / 1e6,
        o.curve.final_ppl().unwrap_or(f64::NAN),
        o.real_s,
    );
    if o.retries + o.drops + o.timeouts + o.requeues > 0 {
        println!(
            "[{}] faults: retries={} drops={} timeouts={} requeues={} \
             tau mean={:.1} max={:.0} queue_delay mean={:.2}s max={:.2}s",
            o.method,
            o.retries,
            o.drops,
            o.timeouts,
            o.requeues,
            o.tau_dist.mean(),
            o.tau_dist.max_or_zero(),
            o.queue_delay_dist.mean(),
            o.queue_delay_dist.max_or_zero(),
        );
    }
    if o.rollbacks > 0
        || o.fallback_loads > 0
        || o.corrupt_fragments > 0
        || o.nonfinite_losses > 0
    {
        println!(
            "[{}] recovery: rollbacks={} fallback_loads={} corrupt_fragments={} \
             quarantined={} nonfinite_losses={}",
            o.method,
            o.rollbacks,
            o.fallback_loads,
            o.corrupt_fragments,
            o.quarantined,
            o.nonfinite_losses,
        );
    }
    if !o.link_util.is_empty() {
        println!("[{}] WAN link utilization ({} links):", o.method, o.link_util.len());
        for l in &o.link_util {
            println!(
                "  {:>16} {:>9.1}MB busy={:>8.1}s transfers={}",
                l.name,
                l.bytes / 1e6,
                l.busy_s,
                l.transfers
            );
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(BOOL_FLAGS)?;
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    match cmd.as_str() {
        "train" => {
            let cfg = build_config(&args)?;
            let backend = build_backend(&args, &artifacts, &cfg.preset, cfg.use_hlo_fragment_ops)?;
            let mut tr = Trainer::new(backend.as_ref(), cfg)?;
            tr.verbose = !args.switch("quiet");
            if args.switch("resume") {
                match tr.resume_from_ring()? {
                    Some(step) => eprintln!("resumed from ring snapshot at step {step}"),
                    None => eprintln!("no ring snapshot to resume from; starting fresh"),
                }
            }
            let out = tr.run()?;
            summarize(&out);
            if let Some(path) = args.get("out") {
                write_curves_csv(path, std::slice::from_ref(&out.curve))?;
                eprintln!("curve written to {path}");
            }
            if let Some(path) = args.get("save") {
                tr.save_checkpoint(
                    path,
                    out.curve.points.last().map(|p| p.step).unwrap_or(0),
                )?;
                eprintln!("checkpoint written to {path}");
            }
            args.finish()?;
        }
        "compare" => {
            let base = build_config(&args)?;
            let ppl = args.get_or::<f64>("ppl", 20.0)?;
            let backend =
                build_backend(&args, &artifacts, &base.preset, base.use_hlo_fragment_ops)?;
            let mut curves = Vec::new();
            for method in MethodKind::all() {
                let mut cfg = base.clone();
                cfg.method = method;
                let mut tr = Trainer::new(backend.as_ref(), cfg)?;
                tr.verbose = !args.switch("quiet");
                let out = tr.run()?;
                summarize(&out);
                curves.push(out.curve);
            }
            println!("\n{}", table1(&curves, ppl));
            if let Some(path) = args.get("out") {
                write_curves_csv(path, &curves)?;
                eprintln!("curves written to {path}");
            }
            args.finish()?;
        }
        "info" => {
            let preset = args.get("preset").unwrap_or("exp").to_string();
            let backend = build_backend(&args, &artifacts, &preset, false)?;
            args.finish()?;
            let model = backend.model();
            println!("preset:     {preset}");
            println!("platform:   {}", backend.platform());
            println!(
                "model:      {} layers, d={}, heads={}, vocab={}, seq={}, batch={}",
                model.n_layers, model.d_model, model.n_heads,
                model.vocab_size, model.seq_len, model.batch_size
            );
            println!("params:     {}", backend.param_count());
            println!("fragments:  K={}", backend.fragments().k());
            for f in backend.fragments().iter() {
                println!(
                    "  [{}] offset={:>9} size={:>9} ({:.2} MB)",
                    f.index, f.offset, f.size,
                    f.size as f64 * 4.0 / 1e6
                );
            }
        }
        "emit-config" => {
            args.finish()?;
            println!("{}", RunConfig::default().to_json_string());
        }
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
