//! The training orchestrator: drives M simulated datacenter workers in
//! lockstep local steps (each a PJRT execution of the train_step artifact),
//! hands control to the configured [`SyncStrategy`] after every step, and
//! accounts virtual wall-clock through the WAN simulator.
//!
//! Worker steps run on a *persistent* worker thread pool (the XLA CPU
//! client supports concurrent executions) instead of spawning fresh OS
//! threads every round; the same pool serves CoCoDC's per-worker
//! delay-compensation fan-out and parallel validation batches.
//! Communication never runs Python — the entire hot loop is rust +
//! compiled HLO, and the sync path recycles all fragment-sized buffers
//! through a [`BufferPool`] (zero steady-state allocations).

use std::path::Path;
use std::time::Instant;

use crate::checkpoint::Checkpoint;
use crate::config::RunConfig;
use crate::coordinator::{
    make_strategy, FragmentTable, GlobalState, SyncStats, SyncStrategy,
};
use crate::coordinator::strategy::SyncCtx;
use crate::data::batches::{Batch, BatchStream};
use crate::data::Split;
use crate::metrics::Curve;
use crate::network::WanSimulator;
use crate::runtime::{Engine, TrainState};
use crate::simclock::VirtualClock;
use crate::util::pool::BufferPool;
use crate::util::threadpool::{ScopedTask, WorkerPool};
use crate::util::vecops;

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub method: String,
    pub curve: Curve,
    pub syncs_initiated: usize,
    pub syncs_completed: usize,
    pub per_fragment_syncs: Vec<usize>,
    pub staleness_guard_hits: usize,
    pub apply_stalls: usize,
    pub bytes_sent: f64,
    /// Virtual (WAN-accounted) seconds.
    pub wall_s: f64,
    pub compute_s: f64,
    pub comm_stall_s: f64,
    /// Real elapsed seconds of the simulation itself.
    pub real_s: f64,
    pub final_train_loss: f32,
}

/// One full cross-region training run.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    workers: Vec<TrainState>,
    global: GlobalState,
    frags: FragmentTable,
    net: WanSimulator,
    clock: VirtualClock,
    strategy: Box<dyn SyncStrategy>,
    streams: Vec<BatchStream>,
    val_batches: Vec<Batch>,
    stats: SyncStats,
    /// Recycled fragment-sized buffers for the sync hot path.
    bufs: BufferPool,
    /// Persistent worker threads (None when `cfg.parallel_workers` is off
    /// or the host/run has nothing to parallelize).
    threads: Option<WorkerPool>,
    pub verbose: bool,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: RunConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let meta = engine.meta();
        let frags = FragmentTable::from_meta(meta);
        let init = engine.init_params()?;
        let workers: Vec<TrainState> =
            (0..cfg.workers).map(|_| TrainState::new(init.clone())).collect();
        let global = GlobalState::new(&init);
        let net = WanSimulator::new(cfg.network, cfg.workers, cfg.seed);
        let strategy = make_strategy(&cfg, &frags);
        let streams: Vec<BatchStream> = (0..cfg.workers)
            .map(|m| {
                BatchStream::new(
                    meta.model.vocab_size,
                    cfg.data,
                    cfg.seed,
                    Split::Train { worker: m, workers: cfg.workers },
                    meta.model.batch_size,
                    meta.model.seq_len,
                )
            })
            .collect();
        let mut val_stream = BatchStream::new(
            meta.model.vocab_size,
            cfg.data,
            cfg.seed,
            Split::Validation,
            meta.model.batch_size,
            meta.model.seq_len,
        );
        let val_batches = val_stream.take_batches(cfg.eval_batches);
        let stats = SyncStats::new(frags.k());
        let threads = if cfg.parallel_workers {
            let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            let want = cfg.workers.max(cfg.eval_batches).min(hw).min(32);
            if want > 1 {
                Some(WorkerPool::new(want))
            } else {
                None
            }
        } else {
            None
        };
        Ok(Trainer {
            engine,
            cfg,
            workers,
            global,
            frags,
            net,
            clock: VirtualClock::new(),
            strategy,
            streams,
            val_batches,
            stats,
            bufs: BufferPool::new(),
            threads,
            verbose: false,
        })
    }

    /// Validation loss of the current consensus (mean of worker params).
    /// Eval batches fan out on the persistent pool; losses are summed in
    /// batch order, so the result is identical to the serial path.
    pub fn validation_loss(&self) -> anyhow::Result<f64> {
        let engine = self.engine;
        let n = self.workers[0].params.len();
        let mut mean = vec![0.0f32; n];
        {
            let rows: Vec<&[f32]> =
                self.workers.iter().map(|w| w.params.as_slice()).collect();
            vecops::mean_of(&mut mean, &rows);
        }
        let mut losses: Vec<Option<anyhow::Result<f32>>> =
            self.val_batches.iter().map(|_| None).collect();
        match &self.threads {
            Some(tp) if self.val_batches.len() > 1 => {
                let mean_ref: &[f32] = &mean;
                let tasks: Vec<ScopedTask<'_>> = self
                    .val_batches
                    .iter()
                    .zip(losses.iter_mut())
                    .map(|(b, slot)| {
                        Box::new(move || {
                            *slot = Some(engine.eval_loss(mean_ref, &b.tokens, &b.targets));
                        }) as ScopedTask<'_>
                    })
                    .collect();
                tp.scoped(tasks);
            }
            _ => {
                for (b, slot) in self.val_batches.iter().zip(losses.iter_mut()) {
                    *slot = Some(engine.eval_loss(&mean, &b.tokens, &b.targets));
                }
            }
        }
        let mut total = 0.0f64;
        for l in losses {
            total += l.expect("eval ran for every batch")? as f64;
        }
        Ok(total / self.val_batches.len() as f64)
    }

    /// Execute one lockstep round of local steps on all workers, reusing
    /// the persistent worker pool (no per-step thread spawn).
    fn step_all(&mut self) -> anyhow::Result<f32> {
        let engine = self.engine;
        let m = self.workers.len();
        let batches: Vec<Batch> =
            self.streams.iter_mut().map(|s| s.next_batch()).collect();
        let mut losses: Vec<Option<anyhow::Result<f32>>> =
            (0..m).map(|_| None).collect();
        match &self.threads {
            Some(tp) if m > 1 => {
                let tasks: Vec<ScopedTask<'_>> = self
                    .workers
                    .iter_mut()
                    .zip(&batches)
                    .zip(losses.iter_mut())
                    .map(|((w, b), slot)| {
                        Box::new(move || {
                            *slot = Some(engine.train_step(w, &b.tokens, &b.targets));
                        }) as ScopedTask<'_>
                    })
                    .collect();
                tp.scoped(tasks);
            }
            _ => {
                for ((w, b), slot) in
                    self.workers.iter_mut().zip(&batches).zip(losses.iter_mut())
                {
                    *slot = Some(engine.train_step(w, &b.tokens, &b.targets));
                }
            }
        }
        let mut mean = 0.0f32;
        for l in losses {
            mean += l.expect("every worker stepped")? / m as f32;
        }
        Ok(mean)
    }

    /// Run `cfg.total_steps` local steps; returns the outcome with the
    /// validation curve (evaluated every `cfg.eval_every` steps).
    pub fn run(&mut self) -> anyhow::Result<TrainOutcome> {
        let t0 = Instant::now();
        let mut curve = Curve::new(self.strategy.name());
        let v0 = self.validation_loss()?;
        curve.push(0, 0.0, v0);
        if self.verbose {
            eprintln!(
                "[{}] step 0 val_loss={v0:.4} ppl={:.2}",
                self.strategy.name(),
                v0.exp()
            );
        }
        let mut last_train_loss = f32::NAN;
        for step in 1..=self.cfg.total_steps {
            last_train_loss = self.step_all()?;
            self.clock.advance_compute(self.cfg.network.step_compute_s);
            let mut ctx = SyncCtx {
                workers: &mut self.workers,
                global: &mut self.global,
                net: &mut self.net,
                clock: &mut self.clock,
                engine: Some(self.engine),
                cfg: &self.cfg,
                frags: &self.frags,
                stats: &mut self.stats,
                pool: &mut self.bufs,
                threads: self.threads.as_ref(),
            };
            self.strategy.post_step(step, &mut ctx)?;
            if step % self.cfg.eval_every == 0 || step == self.cfg.total_steps {
                let v = self.validation_loss()?;
                curve.push(step, self.clock.now(), v);
                if self.verbose {
                    eprintln!(
                        "[{}] step {step} wall={:.1}s train_loss={last_train_loss:.4} val_loss={v:.4} ppl={:.2}",
                        self.strategy.name(),
                        self.clock.now(),
                        v.exp()
                    );
                }
            }
        }
        Ok(TrainOutcome {
            method: self.strategy.name().to_string(),
            curve,
            syncs_initiated: self.stats.syncs_initiated,
            syncs_completed: self.stats.syncs_completed,
            per_fragment_syncs: self.stats.per_fragment.clone(),
            staleness_guard_hits: self.stats.staleness_guard_hits,
            apply_stalls: self.stats.apply_stalls,
            bytes_sent: self.stats.bytes,
            wall_s: self.clock.now(),
            compute_s: self.clock.compute_s(),
            comm_stall_s: self.clock.comm_stall_s(),
            real_s: t0.elapsed().as_secs_f64(),
            final_train_loss: last_train_loss,
        })
    }

    /// Snapshot the full training state.
    pub fn checkpoint(&self, step: u32) -> Checkpoint {
        let mut ck = Checkpoint::new(step);
        ck.insert("global/theta_g", self.global.theta_g.clone());
        ck.insert("global/outer_momentum", self.global.outer_momentum.clone());
        for (i, w) in self.workers.iter().enumerate() {
            ck.insert(&format!("worker{i}/params"), w.params.clone());
            ck.insert(&format!("worker{i}/m"), w.m.clone());
            ck.insert(&format!("worker{i}/v"), w.v.clone());
            ck.insert(&format!("worker{i}/step"), vec![w.step as f32]);
        }
        ck
    }

    /// Restore from a checkpoint produced by [`Trainer::checkpoint`].
    pub fn restore(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        let need = |name: &str| {
            ck.get(name)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing section {name}"))
        };
        self.global.theta_g = need("global/theta_g")?.to_vec();
        self.global.outer_momentum = need("global/outer_momentum")?.to_vec();
        for (i, w) in self.workers.iter_mut().enumerate() {
            w.params = need(&format!("worker{i}/params"))?.to_vec();
            w.m = need(&format!("worker{i}/m"))?.to_vec();
            w.v = need(&format!("worker{i}/v"))?.to_vec();
            w.step = need(&format!("worker{i}/step"))?[0] as u32;
        }
        Ok(())
    }

    pub fn save_checkpoint<P: AsRef<Path>>(&self, path: P, step: u32) -> anyhow::Result<()> {
        self.checkpoint(step).save(path)
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn workers(&self) -> &[TrainState] {
        &self.workers
    }
}
