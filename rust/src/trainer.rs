//! The training orchestrator: drives M simulated datacenter workers in
//! lockstep local steps, hands control to the configured [`SyncStrategy`]
//! after every step, and accounts virtual wall-clock through the WAN
//! simulator.
//!
//! Worker training state is *resident in the execution backend* behind
//! opaque [`WorkerHandle`]s (see `runtime::backend`): the trainer never
//! touches flat parameter vectors on the hot path — local steps run
//! entirely backend-side and return only the loss, and the sync path moves
//! exactly the synchronized fragments through pooled buffers.
//!
//! Worker steps fan out on a *persistent* thread pool; the same pool serves
//! CoCoDC's per-worker delay-compensation fan-out and parallel validation
//! batches. The entire outer loop is allocation-free in steady state:
//! batches refill in place, per-round loss slots and the consensus-mean
//! buffer are trainer-owned scratch, and the sync path recycles all
//! fragment-sized buffers through a [`BufferPool`]
//! (tests/alloc_steady_state.rs proves both properties with a counting
//! global allocator).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::checkpoint::ring::CheckpointRing;
use crate::checkpoint::{
    pack_f64, pack_f64s, pack_u64, pack_u64s, unpack_f64, unpack_f64s, unpack_u64, unpack_u64s,
    Checkpoint,
};
use crate::config::RunConfig;
use crate::coordinator::{
    make_strategy, FragmentTable, GlobalState, SyncStats, SyncStrategy,
};
use crate::coordinator::strategy::SyncCtx;
use crate::data::batches::{Batch, BatchStream};
use crate::data::Split;
use crate::metrics::{Curve, Dist};
use crate::network::topology::LinkUtil;
use crate::network::WanSimulator;
use crate::runtime::{intra_step_units, Backend, TrainState, WorkerHandle};
use crate::simclock::VirtualClock;
use crate::util::pool::BufferPool;
use crate::util::threadpool::{ScopedTask, WorkerPool};

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub method: String,
    pub curve: Curve,
    pub syncs_initiated: usize,
    pub syncs_completed: usize,
    pub per_fragment_syncs: Vec<usize>,
    pub staleness_guard_hits: usize,
    pub apply_stalls: usize,
    pub bytes_sent: f64,
    /// Virtual (WAN-accounted) seconds.
    pub wall_s: f64,
    pub compute_s: f64,
    pub comm_stall_s: f64,
    /// Real elapsed seconds of the simulation itself.
    pub real_s: f64,
    pub final_train_loss: f32,
    /// Retransmission attempts after in-flight transfer losses.
    pub retries: usize,
    /// Transfer attempts lost in flight by the fault plan.
    pub drops: usize,
    /// Logical transfers that exhausted their retry/timeout budget.
    pub timeouts: usize,
    /// Timed-out fragments re-entered into the pending queue.
    pub requeues: usize,
    /// Distribution of effective overlap depths τ over delivered syncs.
    pub tau_dist: Dist,
    /// Distribution of transfer queue delays (s) over delivered syncs.
    pub queue_delay_dist: Dist,
    /// Divergence-sentinel rollbacks to the last good snapshot.
    pub rollbacks: u32,
    /// Newer ring snapshots skipped as torn/corrupt while loading.
    pub fallback_loads: usize,
    /// Fragment payloads that arrived with a checksum mismatch.
    pub corrupt_fragments: usize,
    /// Corrupt fragments quarantined and requeued instead of applied
    /// (always equals `corrupt_fragments`).
    pub quarantined: usize,
    /// Non-finite per-worker/per-batch losses observed (train + eval).
    pub nonfinite_losses: usize,
    /// Per-WAN-link utilization (topology runs; empty on flat runs).
    pub link_util: Vec<LinkUtil>,
}

/// One full cross-region training run.
pub struct Trainer<'b> {
    backend: &'b dyn Backend,
    cfg: RunConfig,
    workers: Vec<WorkerHandle>,
    global: GlobalState,
    frags: FragmentTable,
    net: WanSimulator,
    clock: VirtualClock,
    strategy: Box<dyn SyncStrategy>,
    streams: Vec<BatchStream>,
    val_batches: Vec<Batch>,
    stats: SyncStats,
    /// Recycled fragment-sized buffers for the sync hot path (and the
    /// full-size consensus-mean buffer for evaluation).
    bufs: BufferPool,
    /// Persistent worker threads (None when `cfg.parallel_workers` is off
    /// or the host/run has nothing to parallelize). Shared with the backend
    /// (`set_compute_pool`) so worker fan-out and intra-step row sharding
    /// split one pool via nested scopes instead of oversubscribing.
    threads: Option<Arc<WorkerPool>>,
    /// Next local step to execute (1-based; advanced by [`Trainer::step_once`],
    /// restored from checkpoints).
    next_step: u32,
    /// Per-worker liveness under the fault plan's crash windows (all true
    /// when no faults are scripted). Refreshed at the top of every step.
    live: Vec<bool>,
    // Reused per-round scratch (zero steady-state allocations).
    step_batches: Vec<Batch>,
    step_losses: Vec<Option<anyhow::Result<f32>>>,
    eval_losses: Vec<Option<anyhow::Result<f32>>>,
    /// Durable snapshot ring (Some when `cfg.recovery` is active): last-K
    /// atomically written checkpoints the divergence sentinel can roll back
    /// to and `resume_from_ring` can restart from.
    ring: Option<CheckpointRing>,
    /// Divergence-sentinel EWMA of the mean train loss (checkpointed, so a
    /// rollback replays the same detector trajectory).
    loss_ewma: f64,
    /// EWMA estimate of the loss variance (same cadence as `loss_ewma`).
    loss_var: f64,
    /// Healthy loss observations folded into the sentinel so far.
    loss_obs: u64,
    /// Rollbacks performed this process (not checkpointed: the budget
    /// guards the *current* run, not the trajectory's history).
    rollbacks: u32,
    /// Torn/corrupt ring snapshots skipped while loading (not checkpointed).
    fallback_loads: usize,
    /// Non-finite losses observed (not checkpointed; surfaced in the
    /// outcome so silent NaN/Inf batches are visible even without a ring).
    nonfinite_losses: usize,
    /// Test hook: override the mean train loss seen by the sentinel at the
    /// given step (consumed once; never touches worker state, so a
    /// post-rollback replay produces the genuine loss).
    pub inject_loss_spike: Option<(u32, f32)>,
    pub verbose: bool,
}

/// EWMA smoothing for the divergence sentinel's loss mean/variance.
const SENTINEL_BETA: f64 = 0.1;

impl<'b> Trainer<'b> {
    pub fn new(backend: &'b dyn Backend, cfg: RunConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        // The HLO-fragment-op flag is consumed at backend construction; a
        // mismatch here would silently run a different kernel path than the
        // config (and any results serialized from it) claims.
        anyhow::ensure!(
            cfg.use_hlo_fragment_ops == backend.hlo_fragment_ops(),
            "use_hlo_fragment_ops mismatch: RunConfig says {} but the backend was \
             constructed with {}",
            cfg.use_hlo_fragment_ops,
            backend.hlo_fragment_ops()
        );
        let model = backend.model();
        let frags = backend.fragments().clone();
        let init = backend.init_params()?;
        let workers: Vec<WorkerHandle> = (0..cfg.workers)
            .map(|_| backend.create_worker())
            .collect::<anyhow::Result<_>>()?;
        let global = GlobalState::new(&init);
        let net = WanSimulator::with_topology(
            cfg.network,
            &cfg.topology,
            cfg.workers,
            cfg.seed,
            cfg.faults.clone(),
        )?;
        let strategy = make_strategy(&cfg, &frags);
        let streams: Vec<BatchStream> = (0..cfg.workers)
            .map(|m| {
                BatchStream::new(
                    model.vocab_size,
                    cfg.data,
                    cfg.seed,
                    Split::Train { worker: m, workers: cfg.workers },
                    model.batch_size,
                    model.seq_len,
                )
            })
            .collect();
        let mut val_stream = BatchStream::new(
            model.vocab_size,
            cfg.data,
            cfg.seed,
            Split::Validation,
            model.batch_size,
            model.seq_len,
        );
        let val_batches = val_stream.take_batches(cfg.eval_batches);
        let stats = SyncStats::new(frags.k());
        let threads = if cfg.parallel_workers {
            let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            // Thread budget (DESIGN.md §Parallelism): an explicit
            // `--threads N` wins, 0 means auto (host parallelism). Cap at
            // what worker fan-out × intra-worker 2D (row × column) shards
            // can actually keep busy — nested scopes then split this one
            // pool instead of oversubscribing the host with a second layer
            // of threads. Column shards keep batch-1 runs scaling past the
            // row-shard ceiling.
            let budget = if cfg.threads > 0 { cfg.threads } else { hw.min(32) };
            let useful = cfg.workers.max(cfg.eval_batches) * intra_step_units(model);
            let size = budget.min(useful);
            if size > 1 {
                Some(Arc::new(WorkerPool::new(size)))
            } else {
                None
            }
        } else {
            None
        };
        // Hand the same pool to the backend for intra-step sharding; None
        // resets whatever a previous trainer installed on a shared backend.
        backend.set_compute_pool(threads.clone());
        let live = vec![true; cfg.workers];
        let step_batches =
            (0..cfg.workers).map(|_| Batch::empty(model.batch_size, model.seq_len)).collect();
        let step_losses = (0..cfg.workers).map(|_| None).collect();
        let eval_losses = (0..cfg.eval_batches).map(|_| None).collect();
        let ring = if cfg.recovery.is_active() {
            Some(CheckpointRing::new(
                Path::new(&cfg.recovery.snapshot_dir),
                cfg.recovery.snapshot_ring,
            )?)
        } else {
            None
        };
        Ok(Trainer {
            backend,
            cfg,
            workers,
            global,
            frags,
            net,
            clock: VirtualClock::new(),
            strategy,
            streams,
            val_batches,
            stats,
            bufs: BufferPool::new(),
            threads,
            next_step: 1,
            live,
            step_batches,
            step_losses,
            eval_losses,
            ring,
            loss_ewma: 0.0,
            loss_var: 0.0,
            loss_obs: 0,
            rollbacks: 0,
            fallback_loads: 0,
            nonfinite_losses: 0,
            inject_loss_spike: None,
            verbose: false,
        })
    }

    /// Validation loss of the current consensus (mean of worker params).
    /// The mean lives in a pooled buffer; eval batches fan out on the
    /// persistent pool, and losses are summed in batch order, so the result
    /// is identical to the serial path.
    pub fn validation_loss(&mut self) -> anyhow::Result<f64> {
        let backend = self.backend;
        let mut mean = self.bufs.take(self.backend.param_count());
        backend.mean_params(&self.workers, &mut mean)?;
        for slot in self.eval_losses.iter_mut() {
            *slot = None;
        }
        match &self.threads {
            Some(tp) if self.val_batches.len() > 1 => {
                let mean_ref: &[f32] = &mean;
                let tasks: Vec<ScopedTask<'_>> = self
                    .val_batches
                    .iter()
                    .zip(self.eval_losses.iter_mut())
                    .map(|(b, slot)| {
                        Box::new(move || {
                            *slot = Some(backend.eval_loss(mean_ref, &b.tokens, &b.targets));
                        }) as ScopedTask<'_>
                    })
                    .collect();
                tp.scoped(tasks);
            }
            _ => {
                for (b, slot) in self.val_batches.iter().zip(self.eval_losses.iter_mut()) {
                    *slot = Some(backend.eval_loss(&mean, &b.tokens, &b.targets));
                }
            }
        }
        self.bufs.put(mean);
        let mut total = 0.0f64;
        let mut bad = 0usize;
        for l in self.eval_losses.iter_mut() {
            let x = l.take().expect("eval ran for every batch")? as f64;
            if !x.is_finite() {
                bad += 1;
            }
            total += x;
        }
        // A NaN/Inf batch loss used to vanish silently into the mean; count
        // it so the outcome (and the divergence sentinel, via the poisoned
        // mean) surfaces it.
        self.nonfinite_losses += bad;
        Ok(total / self.val_batches.len() as f64)
    }

    /// Execute one lockstep round of local steps on all *live* workers,
    /// reusing the persistent worker pool (no per-step thread spawn) and
    /// trainer scratch (no per-round allocations). Crashed workers neither
    /// consume batches nor step — their streams and resident state freeze
    /// until they rejoin.
    fn step_all(&mut self) -> anyhow::Result<f32> {
        let backend = self.backend;
        let m = self.workers.len();
        let live = &self.live;
        let n_live = live.iter().filter(|&&x| x).count();
        for ((s, b), &alive) in self
            .streams
            .iter_mut()
            .zip(self.step_batches.iter_mut())
            .zip(live.iter())
        {
            if alive {
                s.next_batch_into(b);
            }
        }
        for slot in self.step_losses.iter_mut() {
            *slot = None;
        }
        match &self.threads {
            Some(tp) if m > 1 => {
                let tasks: Vec<ScopedTask<'_>> = self
                    .workers
                    .iter_mut()
                    .zip(&self.step_batches)
                    .zip(self.step_losses.iter_mut())
                    .zip(live.iter())
                    .filter(|(_, &alive)| alive)
                    .map(|(((w, b), slot), _)| {
                        Box::new(move || {
                            *slot = Some(backend.train_step(w, &b.tokens, &b.targets));
                        }) as ScopedTask<'_>
                    })
                    .collect();
                tp.scoped(tasks);
            }
            _ => {
                for (((w, b), slot), &alive) in self
                    .workers
                    .iter_mut()
                    .zip(&self.step_batches)
                    .zip(self.step_losses.iter_mut())
                    .zip(live.iter())
                {
                    if alive {
                        *slot = Some(backend.train_step(w, &b.tokens, &b.targets));
                    }
                }
            }
        }
        let mut mean = 0.0f32;
        let mut bad = 0usize;
        for l in self.step_losses.iter_mut() {
            if let Some(r) = l.take() {
                let x = r?;
                if !x.is_finite() {
                    bad += 1;
                }
                // Dividing each term (not the sum) keeps the all-live path
                // bit-identical to the pre-fault builds.
                mean += x / n_live as f32;
            }
        }
        self.nonfinite_losses += bad;
        Ok(mean)
    }

    /// Reconcile the liveness mask with the fault plan's crash windows at
    /// the current virtual time. A worker whose crash window just ended
    /// rejoins by adopting the current global fragment state θ^g wholesale
    /// (its inner-optimizer moments stay frozen from before the crash).
    fn refresh_live(&mut self) -> anyhow::Result<()> {
        if !self.net.faults().is_active() {
            return Ok(());
        }
        let now = self.clock.now();
        for m in 0..self.workers.len() {
            let crashed = self.net.faults().is_crashed(m, now);
            if crashed {
                self.live[m] = false;
            } else if !self.live[m] {
                for p in 0..self.frags.k() {
                    let frag = self.frags.get(p);
                    let new_g = &self.global.theta_g[frag.range()];
                    self.backend.write_fragment(&mut self.workers[m], frag, new_g)?;
                }
                self.live[m] = true;
            }
        }
        anyhow::ensure!(
            self.live.iter().any(|&x| x),
            "fault plan crashed every worker at t={now:.3}s"
        );
        // Mirror liveness into the WAN so the topology layer re-elects
        // leaders and drops fully-dead regions out of the inter-region ring.
        self.net.set_liveness(&self.live);
        Ok(())
    }

    /// One full training step: lockstep local steps, clock accounting and
    /// the strategy's post-step sync work. Returns (step, mean train loss).
    pub fn step_once(&mut self) -> anyhow::Result<(u32, f32)> {
        let step = self.next_step;
        self.refresh_live()?;
        let loss = self.step_all()?;
        // Lockstep: the slowest live worker paces the round (straggler
        // multipliers from the fault plan; 1.0 when none are scripted).
        let pace = self.net.faults().compute_multiplier(&self.live);
        self.clock.advance_compute(self.cfg.network.step_compute_s * pace);
        let mut ctx = SyncCtx {
            workers: &mut self.workers,
            global: &mut self.global,
            net: &mut self.net,
            clock: &mut self.clock,
            backend: self.backend,
            cfg: &self.cfg,
            frags: &self.frags,
            stats: &mut self.stats,
            pool: &mut self.bufs,
            threads: self.threads.as_deref(),
            live: Some(&self.live),
        };
        self.strategy.post_step(step, &mut ctx)?;
        self.next_step = step + 1;
        Ok((step, loss))
    }

    /// Fold one mean train loss into the divergence sentinel and report
    /// whether it signals divergence. Non-finite losses are always a
    /// divergence; finite losses diverge when their z-score against the
    /// EWMA mean/variance exceeds `recovery.sentinel_zscore` after
    /// `recovery.sentinel_warmup` healthy observations. A divergent loss is
    /// *not* folded in, so the detector's baseline stays healthy for the
    /// post-rollback replay.
    fn observe_loss(&mut self, loss: f32) -> bool {
        let x = loss as f64;
        if !x.is_finite() {
            return true;
        }
        if self.loss_obs == 0 {
            self.loss_obs = 1;
            self.loss_ewma = x;
            self.loss_var = 0.0;
            return false;
        }
        let rc = &self.cfg.recovery;
        let d = x - self.loss_ewma;
        let z = d / (self.loss_var.sqrt() + 1e-6);
        let spike = self.loss_obs >= rc.sentinel_warmup as u64 && z > rc.sentinel_zscore;
        if !spike {
            self.loss_ewma += SENTINEL_BETA * d;
            self.loss_var = (1.0 - SENTINEL_BETA) * (self.loss_var + SENTINEL_BETA * d * d);
            self.loss_obs += 1;
        }
        spike
    }

    /// Snapshot the full run state into the ring (atomic write + manifest).
    /// No-op when no ring is configured.
    fn snapshot(&mut self, step: u32) -> anyhow::Result<()> {
        if self.ring.is_none() {
            return Ok(());
        }
        let ck = self.checkpoint(step)?;
        if let Some(ring) = self.ring.as_mut() {
            ring.save(&ck)?;
        }
        Ok(())
    }

    /// Roll back to the newest loadable ring snapshot after the sentinel
    /// flagged `step` as divergent. Returns the step rolled back to; errors
    /// once the `recovery.max_rollbacks` budget is exhausted (repeated
    /// divergence means the trajectory itself is sick, not the state).
    fn rollback(&mut self, step: u32, loss: f32) -> anyhow::Result<u32> {
        anyhow::ensure!(
            self.rollbacks < self.cfg.recovery.max_rollbacks,
            "divergence at step {step} (train_loss={loss}): rollback budget {} exhausted",
            self.cfg.recovery.max_rollbacks
        );
        let (ck, skipped) = match self.ring.as_mut() {
            Some(ring) => ring.load_newest_valid()?,
            None => anyhow::bail!(
                "divergence at step {step} (train_loss={loss}) but no snapshot ring is configured"
            ),
        };
        self.fallback_loads += skipped;
        self.restore(&ck)?;
        self.rollbacks += 1;
        if self.verbose {
            eprintln!(
                "[{}] divergence at step {step} (train_loss={loss:.4}); rolled back to step {}",
                self.strategy.name(),
                ck.step
            );
        }
        Ok(ck.step)
    }

    /// Restore from the newest loadable snapshot in the configured ring, if
    /// any. Returns the restored step (run continues at step + 1), or None
    /// when no ring is configured or it is empty. Torn/corrupt newer
    /// snapshots are skipped (counted as fallback loads), so a run killed
    /// mid-save resumes from the previous good snapshot.
    pub fn resume_from_ring(&mut self) -> anyhow::Result<Option<u32>> {
        let (ck, skipped) = match self.ring.as_mut() {
            Some(ring) if !ring.is_empty() => ring.load_newest_valid()?,
            _ => return Ok(None),
        };
        self.fallback_loads += skipped;
        self.restore(&ck)?;
        Ok(Some(ck.step))
    }

    /// Run local steps up to `cfg.total_steps` (continuing from a restored
    /// checkpoint if any); returns the outcome with the validation curve
    /// (evaluated every `cfg.eval_every` steps).
    pub fn run(&mut self) -> anyhow::Result<TrainOutcome> {
        let t0 = Instant::now();
        let mut curve = Curve::new(self.strategy.name());
        let start = self.next_step - 1;
        let v0 = self.validation_loss()?;
        curve.push(start, self.clock.now(), v0);
        if self.verbose {
            eprintln!(
                "[{}] step {start} val_loss={v0:.4} ppl={:.2}",
                self.strategy.name(),
                v0.exp()
            );
        }
        // Seed the ring so a rollback target exists before the first
        // cadence snapshot (and so a freshly resumed run re-anchors its
        // "last known good" at the restored step).
        self.snapshot(start)?;
        let mut last_train_loss = f32::NAN;
        while self.next_step <= self.cfg.total_steps {
            let (step, mut loss) = self.step_once()?;
            if let Some((at, v)) = self.inject_loss_spike {
                if at == step {
                    self.inject_loss_spike = None;
                    loss = v;
                }
            }
            if self.ring.is_some() && self.observe_loss(loss) {
                let to = self.rollback(step, loss)?;
                // Drop eval points past the rollback target; the replay
                // regenerates them from the restored state, so the curve
                // stays the single deterministic trajectory.
                curve.points.retain(|p| p.step <= to);
                continue;
            }
            last_train_loss = loss;
            if step % self.cfg.eval_every == 0 || step == self.cfg.total_steps {
                let v = self.validation_loss()?;
                curve.push(step, self.clock.now(), v);
                if self.verbose {
                    eprintln!(
                        "[{}] step {step} wall={:.1}s train_loss={last_train_loss:.4} val_loss={v:.4} ppl={:.2}",
                        self.strategy.name(),
                        self.clock.now(),
                        v.exp()
                    );
                }
            }
            let every = self.cfg.recovery.snapshot_every;
            if every > 0 && step % every == 0 {
                // Snapshot only after the sentinel called the step healthy,
                // so a divergent state never becomes "last known good".
                self.snapshot(step)?;
            }
        }
        self.stats.link_util = self.net.link_utils();
        Ok(TrainOutcome {
            method: self.strategy.name().to_string(),
            curve,
            syncs_initiated: self.stats.syncs_initiated,
            syncs_completed: self.stats.syncs_completed,
            per_fragment_syncs: self.stats.per_fragment.clone(),
            staleness_guard_hits: self.stats.staleness_guard_hits,
            apply_stalls: self.stats.apply_stalls,
            bytes_sent: self.stats.bytes,
            wall_s: self.clock.now(),
            compute_s: self.clock.compute_s(),
            comm_stall_s: self.clock.comm_stall_s(),
            real_s: t0.elapsed().as_secs_f64(),
            final_train_loss: last_train_loss,
            retries: self.stats.retries,
            drops: self.stats.drops,
            timeouts: self.stats.timeouts,
            requeues: self.stats.requeues,
            tau_dist: self.stats.tau_dist,
            queue_delay_dist: self.stats.queue_delay_dist,
            rollbacks: self.rollbacks,
            fallback_loads: self.fallback_loads,
            corrupt_fragments: self.stats.corrupt_fragments,
            quarantined: self.stats.quarantined,
            nonfinite_losses: self.nonfinite_losses,
            link_util: self.stats.link_util.clone(),
        })
    }

    /// Snapshot the full training state *and* run context: worker states,
    /// global consensus, virtual clock, sync statistics, divergence
    /// sentinel, WAN simulator (all three RNG streams), liveness mask,
    /// strategy-internal schedule state
    /// (including in-flight fragment syncs) and data-stream cursors —
    /// everything a resumed run needs to continue the same trajectory, even
    /// from the middle of an active fault window with transfers in flight.
    pub fn checkpoint(&self, step: u32) -> anyhow::Result<Checkpoint> {
        let mut ck = Checkpoint::new(step);
        ck.insert("global/theta_g", self.global.theta_g.clone());
        ck.insert("global/outer_momentum", self.global.outer_momentum.clone());
        let mut st = TrainState::new(vec![0.0; self.backend.param_count()]);
        for (i, w) in self.workers.iter().enumerate() {
            self.backend.read_state(w, &mut st)?;
            ck.insert(&format!("worker{i}/params"), st.params.clone());
            ck.insert(&format!("worker{i}/m"), st.m.clone());
            ck.insert(&format!("worker{i}/v"), st.v.clone());
            // Bit-exact (an f32 cast would round step counts above 2^24,
            // shifting the restored LR schedule / bias correction).
            ck.insert(&format!("worker{i}/step"), pack_u64(st.step as u64).to_vec());
        }
        // Run context (bit-exact packing; see checkpoint::pack_u64).
        let (now, compute, stall) = self.clock.state();
        let mut clock = Vec::with_capacity(6);
        clock.extend(pack_f64(now));
        clock.extend(pack_f64(compute));
        clock.extend(pack_f64(stall));
        ck.insert("run/clock", clock);
        let s = &self.stats;
        let mut stats = Vec::new();
        for c in [s.syncs_initiated, s.syncs_completed, s.staleness_guard_hits, s.apply_stalls] {
            stats.extend(pack_u64(c as u64));
        }
        stats.extend(pack_f64(s.bytes));
        pack_u64s(
            &mut stats,
            &[s.retries as u64, s.drops as u64, s.timeouts as u64, s.requeues as u64],
        );
        pack_u64s(&mut stats, &[s.corrupt_fragments as u64, s.quarantined as u64]);
        for d in [&s.tau_dist, &s.queue_delay_dist] {
            pack_u64s(&mut stats, &[d.count]);
            pack_f64s(&mut stats, &[d.sum, d.min, d.max]);
        }
        for &c in &s.per_fragment {
            stats.extend(pack_u64(c as u64));
        }
        ck.insert("run/stats", stats);
        let nst = self.net.state();
        let mut net = Vec::with_capacity(32);
        pack_f64s(&mut net, &[nst.busy_until, nst.bytes_sent]);
        pack_u64s(&mut net, &[nst.transfers as u64, nst.drops as u64]);
        pack_u64s(&mut net, &nst.jitter_rng);
        pack_u64s(&mut net, &nst.fault_rng);
        pack_u64s(&mut net, &nst.corrupt_rng);
        // Topology runs append a [links, regions] header plus the per-link
        // and per-region timelines; flat runs keep the exact legacy layout.
        if !nst.topo.link_busy.is_empty() {
            let l = nst.topo.link_busy.len() as u64;
            let r = nst.topo.intra_busy.len() as u64;
            pack_u64s(&mut net, &[l, r]);
            pack_f64s(&mut net, &nst.topo.link_busy);
            pack_f64s(&mut net, &nst.topo.link_bytes);
            pack_f64s(&mut net, &nst.topo.link_busy_s);
            pack_u64s(&mut net, &nst.topo.link_transfers);
            pack_f64s(&mut net, &nst.topo.intra_busy);
        }
        ck.insert("run/net", net);
        let mut sen = Vec::with_capacity(6);
        pack_u64s(&mut sen, &[self.loss_obs]);
        pack_f64s(&mut sen, &[self.loss_ewma, self.loss_var]);
        ck.insert("run/sentinel", sen);
        ck.insert("run/live", self.live.iter().map(|&x| x as u32 as f32).collect());
        self.strategy.save_state(&mut ck);
        for (i, stream) in self.streams.iter().enumerate() {
            let mut cur = Vec::with_capacity(8);
            for x in stream.cursor() {
                cur.extend(pack_u64(x));
            }
            ck.insert(&format!("run/stream{i}"), cur);
        }
        Ok(ck)
    }

    /// Restore from a checkpoint produced by [`Trainer::checkpoint`]:
    /// training state always; run context (clock, stats, WAN, stream
    /// cursors) when present, so `run()` continues at `ck.step + 1` on the
    /// same trajectory. Older checkpoints without run context restore the
    /// state only.
    pub fn restore(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        let need = |name: &str| {
            ck.get(name)
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing section {name}"))
        };
        self.global.theta_g = need("global/theta_g")?.to_vec();
        self.global.outer_momentum = need("global/outer_momentum")?.to_vec();
        let n = self.backend.param_count();
        anyhow::ensure!(
            self.global.theta_g.len() == n && self.global.outer_momentum.len() == n,
            "checkpoint global state does not match this backend's {n} params"
        );
        for (i, w) in self.workers.iter_mut().enumerate() {
            let mut st = TrainState::new(vec![0.0; n]);
            for (dst, name) in [
                (&mut st.params, format!("worker{i}/params")),
                (&mut st.m, format!("worker{i}/m")),
                (&mut st.v, format!("worker{i}/v")),
            ] {
                let src = need(&name)?;
                anyhow::ensure!(src.len() == n, "checkpoint section {name} length mismatch");
                dst.copy_from_slice(src);
            }
            let step_sec = need(&format!("worker{i}/step"))?;
            st.step = match step_sec.len() {
                // Bit-exact packing (current format).
                2 => unpack_u64(step_sec[0], step_sec[1]) as u32,
                // Legacy checkpoints stored the counter as a plain f32.
                1 => step_sec[0] as u32,
                n => anyhow::bail!("worker{i}/step section malformed ({n} values)"),
            };
            self.backend.write_state(w, &st)?;
        }
        if let Some(c) = ck.get("run/clock") {
            anyhow::ensure!(c.len() == 6, "run/clock section malformed");
            self.clock.restore(
                unpack_f64(c[0], c[1]),
                unpack_f64(c[2], c[3]),
                unpack_f64(c[4], c[5]),
            );
        }
        if let Some(s) = ck.get("run/stats") {
            let k = self.frags.k();
            // Legacy layout (10 + 2k): counters + bytes + per-fragment.
            // The 34 + 2k layout adds fault counters and the τ /
            // queue-delay distributions between bytes and per-fragment;
            // current (38 + 2k) inserts the corruption counters before the
            // distributions.
            anyhow::ensure!(
                s.len() == 10 + 2 * k || s.len() == 34 + 2 * k || s.len() == 38 + 2 * k,
                "run/stats section malformed"
            );
            self.stats.syncs_initiated = unpack_u64(s[0], s[1]) as usize;
            self.stats.syncs_completed = unpack_u64(s[2], s[3]) as usize;
            self.stats.staleness_guard_hits = unpack_u64(s[4], s[5]) as usize;
            self.stats.apply_stalls = unpack_u64(s[6], s[7]) as usize;
            self.stats.bytes = unpack_f64(s[8], s[9]);
            let mut off = 10;
            if s.len() >= 34 + 2 * k {
                self.stats.retries = unpack_u64(s[10], s[11]) as usize;
                self.stats.drops = unpack_u64(s[12], s[13]) as usize;
                self.stats.timeouts = unpack_u64(s[14], s[15]) as usize;
                self.stats.requeues = unpack_u64(s[16], s[17]) as usize;
                let mut base = 18;
                if s.len() == 38 + 2 * k {
                    self.stats.corrupt_fragments = unpack_u64(s[18], s[19]) as usize;
                    self.stats.quarantined = unpack_u64(s[20], s[21]) as usize;
                    base = 22;
                }
                let mut dists = [Dist::default(); 2];
                for (i, d) in dists.iter_mut().enumerate() {
                    let b = base + 8 * i;
                    *d = Dist {
                        count: unpack_u64(s[b], s[b + 1]),
                        sum: unpack_f64(s[b + 2], s[b + 3]),
                        min: unpack_f64(s[b + 4], s[b + 5]),
                        max: unpack_f64(s[b + 6], s[b + 7]),
                    };
                }
                self.stats.tau_dist = dists[0];
                self.stats.queue_delay_dist = dists[1];
                off = base + 16;
            }
            for p in 0..k {
                self.stats.per_fragment[p] =
                    unpack_u64(s[off + 2 * p], s[off + 1 + 2 * p]) as usize;
            }
        }
        if let Some(nst) = ck.get("run/net") {
            // Legacy layout (14): busy, bytes, transfers, jitter RNG. The
            // 24-value layout adds the drop counter and the fault-loss RNG
            // stream; 32 appends the corruption RNG stream; topology runs
            // (36 + 8·links + 2·regions) append a [links, regions] header
            // plus the per-link/per-region timelines. Checkpoints predating
            // a stream leave its freshly seeded state in place, which is
            // exact (the stream was never drawn from).
            anyhow::ensure!(
                nst.len() == 14 || nst.len() == 24 || nst.len() == 32 || nst.len() >= 36,
                "run/net section malformed"
            );
            let mut st = self.net.state();
            // Cleared so a checkpoint without a topology block restores
            // fresh per-link timelines instead of keeping the current ones.
            st.topo = Default::default();
            st.busy_until = unpack_f64(nst[0], nst[1]);
            st.bytes_sent = unpack_f64(nst[2], nst[3]);
            st.transfers = unpack_u64(nst[4], nst[5]) as usize;
            if nst.len() == 14 {
                st.drops = 0;
                let u = unpack_u64s(&nst[6..14]);
                st.jitter_rng = [u[0], u[1], u[2], u[3]];
            } else {
                st.drops = unpack_u64(nst[6], nst[7]) as usize;
                let u = unpack_u64s(&nst[8..24]);
                st.jitter_rng = [u[0], u[1], u[2], u[3]];
                st.fault_rng = [u[4], u[5], u[6], u[7]];
                if nst.len() >= 32 {
                    let c = unpack_u64s(&nst[24..32]);
                    st.corrupt_rng = [c[0], c[1], c[2], c[3]];
                }
            }
            if nst.len() >= 36 {
                let hdr = unpack_u64s(&nst[32..36]);
                let (l, r) = (hdr[0] as usize, hdr[1] as usize);
                anyhow::ensure!(
                    nst.len() == 36 + 8 * l + 2 * r,
                    "run/net topology block malformed"
                );
                if let Some(t) = self.net.topology() {
                    anyhow::ensure!(
                        l == t.n_links() && r == t.n_regions(),
                        "run/net topology block ({l} links, {r} regions) does not match \
                         the configured topology ({} links, {} regions)",
                        t.n_links(),
                        t.n_regions()
                    );
                }
                let mut off = 36;
                st.topo.link_busy = unpack_f64s(&nst[off..off + 2 * l]);
                off += 2 * l;
                st.topo.link_bytes = unpack_f64s(&nst[off..off + 2 * l]);
                off += 2 * l;
                st.topo.link_busy_s = unpack_f64s(&nst[off..off + 2 * l]);
                off += 2 * l;
                st.topo.link_transfers = unpack_u64s(&nst[off..off + 2 * l]);
                off += 2 * l;
                st.topo.intra_busy = unpack_f64s(&nst[off..off + 2 * r]);
            }
            self.net.restore(&st);
        }
        if let Some(sen) = ck.get("run/sentinel") {
            anyhow::ensure!(sen.len() == 6, "run/sentinel section malformed");
            self.loss_obs = unpack_u64(sen[0], sen[1]);
            self.loss_ewma = unpack_f64(sen[2], sen[3]);
            self.loss_var = unpack_f64(sen[4], sen[5]);
        }
        if let Some(lv) = ck.get("run/live") {
            anyhow::ensure!(lv.len() == self.workers.len(), "run/live section malformed");
            for (dst, &x) in self.live.iter_mut().zip(lv) {
                *dst = x != 0.0;
            }
        }
        self.strategy.load_state(ck, &mut self.bufs)?;
        for (i, stream) in self.streams.iter_mut().enumerate() {
            if let Some(cur) = ck.get(&format!("run/stream{i}")) {
                anyhow::ensure!(cur.len() == 8, "run/stream{i} section malformed");
                stream.set_cursor([
                    unpack_u64(cur[0], cur[1]),
                    unpack_u64(cur[2], cur[3]),
                    unpack_u64(cur[4], cur[5]),
                    unpack_u64(cur[6], cur[7]),
                ]);
            }
        }
        self.next_step = ck.step + 1;
        Ok(())
    }

    pub fn save_checkpoint<P: AsRef<Path>>(&self, path: P, step: u32) -> anyhow::Result<()> {
        self.checkpoint(step)?.save(path)
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn backend(&self) -> &'b dyn Backend {
        self.backend
    }

    pub fn workers(&self) -> &[WorkerHandle] {
        &self.workers
    }

    /// Full flat parameter vector of worker `i` (diagnostics/tests; copies).
    pub fn worker_params(&self, i: usize) -> anyhow::Result<Vec<f32>> {
        let mut st = TrainState::new(vec![0.0; self.backend.param_count()]);
        self.backend.read_state(&self.workers[i], &mut st)?;
        Ok(st.params)
    }
}
