//! Dense f32 vector kernels used by the coordinator hot paths (pseudo-
//! gradient computation, averaging, outer optimization, delay compensation).
//!
//! These are written as straight slice loops: LLVM auto-vectorizes them, and
//! the delay-comp/outer-step loops have HLO-artifact twins (Pallas kernels
//! dispatched via PJRT) that `bench_delay_comp` compares against.

/// out[i] = a[i] - b[i]
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// acc[i] += x[i]
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

/// acc[i] *= s
pub fn scale(acc: &mut [f32], s: f32) {
    for a in acc.iter_mut() {
        *a *= s;
    }
}

/// Euclidean norm (f64 accumulation for stability on large fragments).
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Mean of `rows` (equal-length slices) written into `out`.
pub fn mean_of(out: &mut [f32], rows: &[&[f32]]) {
    assert!(!rows.is_empty());
    let inv = 1.0 / rows.len() as f32;
    out.copy_from_slice(rows[0]);
    for r in &rows[1..] {
        add_assign(out, r);
    }
    scale(out, inv);
}

/// max_i |a[i] - b[i]|
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sub_and_add_roundtrip() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![0.5f32, 1.0, -1.0];
        let mut d = vec![0.0; 3];
        sub(&mut d, &a, &b);
        assert_eq!(d, vec![0.5, 1.0, 4.0]);
        let mut acc = b.clone();
        add_assign(&mut acc, &d);
        assert_eq!(acc, a);
    }

    #[test]
    fn mean_matches_manual() {
        let r1 = vec![1.0f32, 2.0];
        let r2 = vec![3.0f32, 6.0];
        let mut out = vec![0.0; 2];
        mean_of(&mut out, &[&r1, &r2]);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn l2_norm_known_value() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }
}
