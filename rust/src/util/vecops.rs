//! Dense f32 vector kernels for the coordinator hot paths (pseudo-gradient
//! averaging, outer optimization, delay compensation, α-blending).
//!
//! Everything here is written as 8-lane unrolled slice loops over
//! `chunks_exact` with a scalar remainder — the shape LLVM reliably turns
//! into plain SIMD without bounds checks — plus *fused* kernels that do in
//! one memory pass what the seed implementation did in several:
//!
//! * [`fused_pseudo_mean`] — sub + accumulate + scale over all worker rows
//!   (replaces the per-worker loops behind `allreduce::mean_pseudo_gradients*`),
//! * [`fused_delay_comp`] / [`fused_delay_comp_into`] — Alg. 1 (Eqs. 4/7/8),
//! * [`fused_outer_step`] — the Nesterov outer update (Eq. 2),
//! * [`fused_alpha_blend`] — Streaming DiLoCo's mixing step (Eq. 3),
//!
//! plus the native backend's dense kernels: [`matmul`], [`matmul_bt`] and
//! [`matmul_at_acc`] are register-blocked, cache-tiled rewrites of the
//! seed triple loops (kept in [`reference`]), constrained to the exact
//! per-element accumulation order of the originals so they are
//! bit-identical — tests/native_parallel.rs asserts exact equality at
//! odd (non-tile-multiple) shapes. Each has a column-range core
//! (`*_cols_ptr`) computing only output columns [c0, c1), the unit of the
//! native backend's 2D partition: the per-element sequence is independent
//! of the column grid, so any chunking is bit-identical to the full-width
//! call. [`softmax_xent`] fuses the logits→softmax→loss→dlogits passes
//! into one vocab sweep pair, with a column-chunked three-phase variant
//! ([`softmax_colmax`]/[`softmax_expsum_ptr`]/[`softmax_grad_ptr`]) whose
//! fixed-order f64 combines keep it within 1 ulp for any shape-determined
//! grid.
//!
//! Numerical contract: every fused/unrolled kernel performs the *same
//! per-element operation sequence* as its scalar reference in
//! [`reference`], so results agree bit-for-bit (tests/hotpath.rs asserts
//! ≤ 1 ulp, and in practice exact equality). The one deliberate
//! reassociation versus the seed code is pseudo-gradient averaging:
//! `(Σ_m θ_m)·M⁻¹ − θ_g` instead of `Σ_m (θ_m − θ_g)·M⁻¹` — one pass per
//! worker row instead of re-reading `θ_g` M times; the difference is a few
//! ulps per element (documented tolerance, see DESIGN.md §Hot path).
//!
//! [`l2_norm`] stays a sequential f64 accumulation on purpose: it feeds the
//! CoCoDC change-rate ranking (Eq. 11), where any reassociation could flip
//! `total_cmp` ties and change fragment selection across builds.

/// Unroll width of the fused kernels (8 f32 lanes = one AVX2 vector).
pub const LANES: usize = 8;

/// out[i] = a[i] - b[i]
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((o, x), y) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for l in 0..LANES {
            o[l] = x[l] - y[l];
        }
    }
    for ((o, x), y) in oc.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder()) {
        *o = x - y;
    }
}

/// acc[i] += x[i]
pub fn add_assign(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (a, b) in (&mut ac).zip(&mut xc) {
        for l in 0..LANES {
            a[l] += b[l];
        }
    }
    for (a, b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += b;
    }
}

/// acc[i] *= s
pub fn scale(acc: &mut [f32], s: f32) {
    let mut ac = acc.chunks_exact_mut(LANES);
    for chunk in &mut ac {
        for v in chunk.iter_mut() {
            *v *= s;
        }
    }
    for v in ac.into_remainder() {
        *v *= s;
    }
}

/// acc[i] = (acc[i] + x[i]) * s — fused tail pass of a mean reduction.
fn add_scale(acc: &mut [f32], x: &[f32], s: f32) {
    debug_assert_eq!(acc.len(), x.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (a, b) in (&mut ac).zip(&mut xc) {
        for l in 0..LANES {
            a[l] = (a[l] + b[l]) * s;
        }
    }
    for (a, b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a = (*a + b) * s;
    }
}

/// out[i] = row[i] * s - g[i] — single-row tail of [`fused_pseudo_mean`].
fn scale_sub_from(out: &mut [f32], row: &[f32], s: f32, g: &[f32]) {
    debug_assert_eq!(out.len(), row.len());
    debug_assert_eq!(out.len(), g.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut rc = row.chunks_exact(LANES);
    let mut gc = g.chunks_exact(LANES);
    for ((o, r), gg) in (&mut oc).zip(&mut rc).zip(&mut gc) {
        for l in 0..LANES {
            o[l] = r[l] * s - gg[l];
        }
    }
    for ((o, r), gg) in oc.into_remainder().iter_mut().zip(rc.remainder()).zip(gc.remainder()) {
        *o = r * s - gg;
    }
}

/// acc[i] = (acc[i] + x[i]) * s - g[i] — fused final pass of
/// [`fused_pseudo_mean`]: last accumulate, mean scale and θ_g subtraction
/// in one sweep.
fn add_scale_sub(acc: &mut [f32], x: &[f32], s: f32, g: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    debug_assert_eq!(acc.len(), g.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    let mut gc = g.chunks_exact(LANES);
    for ((a, b), gg) in (&mut ac).zip(&mut xc).zip(&mut gc) {
        for l in 0..LANES {
            a[l] = (a[l] + b[l]) * s - gg[l];
        }
    }
    for ((a, b), gg) in ac.into_remainder().iter_mut().zip(xc.remainder()).zip(gc.remainder()) {
        *a = (*a + b) * s - gg;
    }
}

/// acc[i] += s * x[i] — the row-major matmul inner loop of the native
/// backend's transformer kernels (out_row += a[n][k] * b_row_k).
pub fn axpy(acc: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (a, b) in (&mut ac).zip(&mut xc) {
        for l in 0..LANES {
            a[l] += s * b[l];
        }
    }
    for (a, b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += s * b;
    }
}

/// Σ_i a[i]·b[i] with 8 independent f32 accumulator lanes (the shape LLVM
/// turns into a vertical SIMD reduction); used by the native backend for
/// attention scores and dA = dOut·Bᵀ rows.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (x, y) in (&mut ac).zip(&mut bc) {
        for l in 0..LANES {
            lanes[l] += x[l] * y[l];
        }
    }
    let mut total: f32 = lanes.iter().sum();
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        total += x * y;
    }
    total
}

/// out[n,p] = a[n,m] @ b[m,p] — register-blocked, cache-tiled.
///
/// MR×NR output tiles accumulate in registers with a k-ascending inner
/// loop, so each `b` row chunk is reused across MR output rows instead of
/// re-streaming the whole `out` row once per k (the [`reference::matmul`]
/// axpy form). Bit-identical to the reference: every output element is a
/// single f32 accumulator summed over k in ascending order, exactly the
/// per-element sequence `fill(0.0)` + repeated axpy produces.
///
/// Full-width wrapper over [`matmul_cols_ptr`] — the column partition does
/// not change any per-element sequence, so the bit pattern is identical
/// for every column grid (DESIGN.md §Parallelism).
pub fn matmul(out: &mut [f32], a: &[f32], b: &[f32], n: usize, m: usize, p: usize) {
    debug_assert_eq!(out.len(), n * p);
    // SAFETY: exclusive access to all of `out` for the whole call.
    unsafe { matmul_cols_ptr(out.as_mut_ptr(), a, b, n, m, p, 0, p) }
}

/// Bounds-checked column-range matmul: writes only out[:, c0..c1). Used by
/// the serial column-chunk loops and the property tests; the concurrent
/// dispatch path goes through [`matmul_cols_ptr`] directly.
pub fn matmul_cols(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    p: usize,
    c0: usize,
    c1: usize,
) {
    assert_eq!(out.len(), n * p);
    assert!(c0 <= c1 && c1 <= p, "column range {c0}..{c1} out of 0..{p}");
    // SAFETY: exclusive access to all of `out` for the whole call.
    unsafe { matmul_cols_ptr(out.as_mut_ptr(), a, b, n, m, p, c0, c1) }
}

/// Column-range core of [`matmul`]: computes out[:, c0..c1) only, through a
/// raw base pointer so disjoint column chunks of one output can run on
/// different threads (native backend 2D partition). Every output element is
/// still a single f32 accumulator summed over k in ascending order — the
/// per-element sequence is independent of the column grid, so any chunking
/// (including the full-width one) produces identical bits.
///
/// # Safety
///
/// `out` must point to an n×p f32 buffer that outlives the call, and no
/// other thread may read or write columns [c0, c1) of it while the call
/// runs. Concurrent calls on the same buffer are sound iff their column
/// ranges are disjoint: the only references materialized inside are
/// per-row sub-slices of this call's own column range.
pub unsafe fn matmul_cols_ptr(
    out: *mut f32,
    a: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    p: usize,
    c0: usize,
    c1: usize,
) {
    debug_assert!(c0 <= c1 && c1 <= p);
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(b.len(), m * p);
    const MR: usize = 4;
    const NR: usize = 16;
    let w = c1 - c0;
    let n_main = n - n % MR;
    let c_main = c0 + (w - w % NR);
    for i0 in (0..n_main).step_by(MR) {
        for cc in (c0..c_main).step_by(NR) {
            let mut acc = [[0.0f32; NR]; MR];
            for j in 0..m {
                let brow = &b[j * p + cc..j * p + cc + NR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let av = a[(i0 + r) * m + j];
                    for c in 0..NR {
                        accr[c] += av * brow[c];
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                std::slice::from_raw_parts_mut(out.add((i0 + r) * p + cc), NR)
                    .copy_from_slice(accr);
            }
        }
        // Column remainder: scalar k-ascending accumulators (same order).
        for r in 0..MR {
            let i = i0 + r;
            for c in c_main..c1 {
                let mut acc = 0.0f32;
                for j in 0..m {
                    acc += a[i * m + j] * b[j * p + c];
                }
                *out.add(i * p + c) = acc;
            }
        }
    }
    // Row remainder: the reference axpy form (identical per-element order).
    for i in n_main..n {
        let row = std::slice::from_raw_parts_mut(out.add(i * p + c0), w);
        row.fill(0.0);
        for j in 0..m {
            axpy(row, a[i * m + j], &b[j * p + c0..j * p + c1]);
        }
    }
}

/// out[n,m] = dout[n,p] @ bᵀ where b is [m,p] — blocked [`dot`] kernel.
///
/// MB×NB blocks of 8-lane accumulators walk the shared p dimension once,
/// reusing every loaded `dout`/`b` chunk across the block. Bit-identical
/// to [`reference::matmul_bt`]: each element keeps `LANES` independent
/// lane accumulators over the `chunks_exact` prefix, sums them with
/// `lanes.iter().sum()`, then adds the scalar remainder — exactly what
/// [`dot`] computes.
pub fn matmul_bt(out: &mut [f32], dout: &[f32], b: &[f32], n: usize, m: usize, p: usize) {
    debug_assert_eq!(out.len(), n * m);
    // SAFETY: exclusive access to all of `out` for the whole call.
    unsafe { matmul_bt_cols_ptr(out.as_mut_ptr(), dout, b, n, m, p, 0, m) }
}

/// Bounds-checked column-range matmul_bt: writes only out[:, j0..j1).
pub fn matmul_bt_cols(
    out: &mut [f32],
    dout: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    p: usize,
    j0: usize,
    j1: usize,
) {
    assert_eq!(out.len(), n * m);
    assert!(j0 <= j1 && j1 <= m, "column range {j0}..{j1} out of 0..{m}");
    // SAFETY: exclusive access to all of `out` for the whole call.
    unsafe { matmul_bt_cols_ptr(out.as_mut_ptr(), dout, b, n, m, p, j0, j1) }
}

/// Column-range core of [`matmul_bt`]: computes out[:, j0..j1) — i.e. the
/// dot products against rows [j0, j1) of `b` only. Each element keeps the
/// exact [`dot`] lane sequence regardless of the column grid.
///
/// # Safety
///
/// Same contract as [`matmul_cols_ptr`]: `out` points to an n×m buffer, and
/// concurrent calls must use disjoint [j0, j1) ranges.
pub unsafe fn matmul_bt_cols_ptr(
    out: *mut f32,
    dout: &[f32],
    b: &[f32],
    n: usize,
    m: usize,
    p: usize,
    j0: usize,
    j1: usize,
) {
    debug_assert!(j0 <= j1 && j1 <= m);
    debug_assert_eq!(dout.len(), n * p);
    debug_assert_eq!(b.len(), m * p);
    const MB: usize = 2;
    const NB: usize = 4;
    let w = j1 - j0;
    let n_main = n - n % MB;
    let j_main = j0 + (w - w % NB);
    let p_chunks = p - p % LANES;
    for i0 in (0..n_main).step_by(MB) {
        for jj in (j0..j_main).step_by(NB) {
            let mut lanes = [[[0.0f32; LANES]; NB]; MB];
            for k0 in (0..p_chunks).step_by(LANES) {
                for (r, lr) in lanes.iter_mut().enumerate() {
                    let dch = &dout[(i0 + r) * p + k0..(i0 + r) * p + k0 + LANES];
                    for (c, lc) in lr.iter_mut().enumerate() {
                        let bch = &b[(jj + c) * p + k0..(jj + c) * p + k0 + LANES];
                        for l in 0..LANES {
                            lc[l] += dch[l] * bch[l];
                        }
                    }
                }
            }
            for (r, lr) in lanes.iter().enumerate() {
                for (c, lc) in lr.iter().enumerate() {
                    let mut total: f32 = lc.iter().sum();
                    for k in p_chunks..p {
                        total += dout[(i0 + r) * p + k] * b[(jj + c) * p + k];
                    }
                    *out.add((i0 + r) * m + jj + c) = total;
                }
            }
        }
        // Column remainder rows of b: plain dot (same element sequence).
        for r in 0..MB {
            let i = i0 + r;
            let drow = &dout[i * p..(i + 1) * p];
            for j in j_main..j1 {
                *out.add(i * m + j) = dot(drow, &b[j * p..(j + 1) * p]);
            }
        }
    }
    for i in n_main..n {
        let drow = &dout[i * p..(i + 1) * p];
        for j in j0..j1 {
            *out.add(i * m + j) = dot(drow, &b[j * p..(j + 1) * p]);
        }
    }
}

/// gb[m,p] += aᵀ[m,n] @ dout[n,p] — register-blocked weight-gradient
/// accumulation. The MR×NR gb tile is loaded once, accumulated over i in
/// ascending order, and stored once. Bit-identical to
/// [`reference::matmul_at_acc`]: per element the sequence is the initial
/// gb value plus `a[i,j]·dout[i,c]` for i ascending — the same order the
/// reference's repeated axpy performs against memory.
pub fn matmul_at_acc(gb: &mut [f32], a: &[f32], dout: &[f32], n: usize, m: usize, p: usize) {
    debug_assert_eq!(gb.len(), m * p);
    // SAFETY: exclusive access to all of `gb` for the whole call.
    unsafe { matmul_at_acc_cols_ptr(gb.as_mut_ptr(), a, dout, n, m, p, 0, p) }
}

/// Bounds-checked column-range matmul_at_acc: accumulates into
/// gb[:, c0..c1) only.
pub fn matmul_at_acc_cols(
    gb: &mut [f32],
    a: &[f32],
    dout: &[f32],
    n: usize,
    m: usize,
    p: usize,
    c0: usize,
    c1: usize,
) {
    assert_eq!(gb.len(), m * p);
    assert!(c0 <= c1 && c1 <= p, "column range {c0}..{c1} out of 0..{p}");
    // SAFETY: exclusive access to all of `gb` for the whole call.
    unsafe { matmul_at_acc_cols_ptr(gb.as_mut_ptr(), a, dout, n, m, p, c0, c1) }
}

/// Column-range core of [`matmul_at_acc`]: accumulates gb[:, c0..c1) only.
/// Per element the sequence stays: initial gb value plus `a[i,j]·dout[i,c]`
/// for i ascending — independent of the column grid.
///
/// # Safety
///
/// Same contract as [`matmul_cols_ptr`]: `gb` points to an m×p buffer, and
/// concurrent calls must use disjoint [c0, c1) ranges (reads of gb are also
/// confined to this call's own range).
pub unsafe fn matmul_at_acc_cols_ptr(
    gb: *mut f32,
    a: &[f32],
    dout: &[f32],
    n: usize,
    m: usize,
    p: usize,
    c0: usize,
    c1: usize,
) {
    debug_assert!(c0 <= c1 && c1 <= p);
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(dout.len(), n * p);
    const MR: usize = 4;
    const NR: usize = 16;
    let w = c1 - c0;
    let m_main = m - m % MR;
    let c_main = c0 + (w - w % NR);
    for j0 in (0..m_main).step_by(MR) {
        for cc in (c0..c_main).step_by(NR) {
            let mut acc = [[0.0f32; NR]; MR];
            for (r, accr) in acc.iter_mut().enumerate() {
                accr.copy_from_slice(std::slice::from_raw_parts(
                    gb.add((j0 + r) * p + cc),
                    NR,
                ));
            }
            for i in 0..n {
                let drow = &dout[i * p + cc..i * p + cc + NR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let av = a[i * m + j0 + r];
                    for c in 0..NR {
                        accr[c] += av * drow[c];
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                std::slice::from_raw_parts_mut(gb.add((j0 + r) * p + cc), NR)
                    .copy_from_slice(accr);
            }
        }
        // Column remainder: scalar i-ascending accumulators (same order).
        for r in 0..MR {
            let j = j0 + r;
            for c in c_main..c1 {
                let mut acc = *gb.add(j * p + c);
                for i in 0..n {
                    acc += a[i * m + j] * dout[i * p + c];
                }
                *gb.add(j * p + c) = acc;
            }
        }
    }
    // Row remainder of gb: the reference axpy form over the column window.
    for i in 0..n {
        let drow = &dout[i * p + c0..i * p + c1];
        for j in m_main..m {
            let row = std::slice::from_raw_parts_mut(gb.add(j * p + c0), w);
            axpy(row, a[i * m + j], drow);
        }
    }
}

/// Fused softmax–cross-entropy over `targets.len()` rows of `v` logits:
/// one vocab sweep computes the row max, a second turns the row into
/// softmax numerators in place while accumulating the partition sum in
/// f64, and (when `grad`) a third scales it into dlogits — replacing the
/// seed's separate logits→softmax→loss→dlogits passes.
///
/// Returns Σ_r (mx_r + ln z_r − logit_r[target_r]) in f64 (the summed
/// negative log-likelihood; the caller divides by its token count). With
/// `grad`, `logits` is left holding `softmax · inv_n` with `inv_n`
/// subtracted at each target — the cross-entropy dlogits.
///
/// Bit-identical to [`reference::softmax_xent_split`] (same per-element
/// sequence, f64 partition sums in row-ascending order). The chunked
/// variant ([`softmax_colmax`] / [`softmax_expsum_ptr`] /
/// [`softmax_grad_ptr`] combined in fixed ascending-chunk order) differs
/// only by f64 reassociation of z — ≤ 1 ulp after f32 rounding, and its
/// chunk grid depends only on `v`, never on the thread count
/// (DESIGN.md §Parallelism).
pub fn softmax_xent(logits: &mut [f32], targets: &[i32], v: usize, inv_n: f32, grad: bool) -> f64 {
    let n = targets.len();
    debug_assert_eq!(logits.len(), n * v);
    let mut loss = 0.0f64;
    for r in 0..n {
        let row = &mut logits[r * v..(r + 1) * v];
        let t = targets[r] as usize;
        let tgt = row[t];
        let mut mx = f32::NEG_INFINITY;
        for &x in row.iter() {
            if x > mx {
                mx = x;
            }
        }
        let mut z = 0.0f64;
        for x in row.iter_mut() {
            let e = (*x - mx).exp();
            *x = e;
            z += e as f64;
        }
        loss += mx as f64 + z.ln() - tgt as f64;
        if grad {
            let s = (1.0 / z) as f32 * inv_n;
            for x in row.iter_mut() {
                *x *= s;
            }
            row[t] -= inv_n;
        }
    }
    loss
}

/// Phase 1 of the column-chunked softmax–xent: per-row f32 max over
/// logits[:, c0..c1) into `out` (one entry per row). Chunk maxima combine
/// exactly (max is associative), so the final row max is bit-identical to
/// the fused kernel's for every column grid.
pub fn softmax_colmax(logits: &[f32], v: usize, c0: usize, c1: usize, out: &mut [f32]) {
    let n = out.len();
    debug_assert_eq!(logits.len(), n * v);
    debug_assert!(c0 <= c1 && c1 <= v);
    for (r, o) in out.iter_mut().enumerate() {
        let mut mx = f32::NEG_INFINITY;
        for &x in &logits[r * v + c0..r * v + c1] {
            if x > mx {
                mx = x;
            }
        }
        *o = mx;
    }
}

/// Phase 2 of the column-chunked softmax–xent: replaces logits[:, c0..c1)
/// with exp(x − mx[row]) in place and writes each row's f64 partial sum of
/// this chunk into `zpart`. The caller combines chunk partials in
/// ascending-chunk order; that reassociation (vs the fused kernel's
/// whole-row sum) is the chunked variant's only numeric difference.
///
/// # Safety
///
/// `logits` points to an n×v buffer; concurrent calls must use disjoint
/// [c0, c1) ranges (the only references materialized are per-row
/// sub-slices of this call's own range).
pub unsafe fn softmax_expsum_ptr(
    logits: *mut f32,
    n: usize,
    v: usize,
    c0: usize,
    c1: usize,
    mx: &[f32],
    zpart: &mut [f64],
) {
    debug_assert!(c0 <= c1 && c1 <= v);
    debug_assert_eq!(mx.len(), n);
    debug_assert_eq!(zpart.len(), n);
    for r in 0..n {
        let row = std::slice::from_raw_parts_mut(logits.add(r * v + c0), c1 - c0);
        let m = mx[r];
        let mut z = 0.0f64;
        for x in row.iter_mut() {
            let e = (*x - m).exp();
            *x = e;
            z += e as f64;
        }
        zpart[r] = z;
    }
}

/// Phase 3 of the column-chunked softmax–xent: scales the in-place exp
/// values of logits[:, c0..c1) by `(1/z[row]) as f32 * inv_n` and
/// subtracts `inv_n` at targets that fall inside this chunk — producing
/// the same dlogits expression as the fused kernel (any difference comes
/// only from z's chunk reassociation).
///
/// # Safety
///
/// Same contract as [`softmax_expsum_ptr`]: disjoint [c0, c1) ranges
/// across concurrent calls on one buffer.
pub unsafe fn softmax_grad_ptr(
    logits: *mut f32,
    targets: &[i32],
    v: usize,
    c0: usize,
    c1: usize,
    z: &[f64],
    inv_n: f32,
) {
    let n = targets.len();
    debug_assert!(c0 <= c1 && c1 <= v);
    debug_assert_eq!(z.len(), n);
    for r in 0..n {
        let row = std::slice::from_raw_parts_mut(logits.add(r * v + c0), c1 - c0);
        let s = (1.0 / z[r]) as f32 * inv_n;
        for x in row.iter_mut() {
            *x *= s;
        }
        let t = targets[r] as usize;
        if (c0..c1).contains(&t) {
            row[t - c0] -= inv_n;
        }
    }
}

/// Euclidean norm (f64 accumulation for stability on large fragments).
/// Deliberately sequential — see the module docs.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Mean of `rows` (equal-length slices) written into `out`. The scale pass
/// is fused into the last accumulation.
pub fn mean_of(out: &mut [f32], rows: &[&[f32]]) {
    fused_mean_iter(out, rows.iter().copied());
}

/// Iterator-driven mean (same association order as [`mean_of`]) — lets the
/// backends average resident worker slices without collecting references.
pub fn fused_mean_iter<'r, I>(out: &mut [f32], rows: I)
where
    I: ExactSizeIterator<Item = &'r [f32]>,
{
    let m = rows.len();
    assert!(m > 0, "mean needs at least one row");
    let inv = 1.0 / m as f32;
    for (k, row) in rows.enumerate() {
        debug_assert_eq!(row.len(), out.len());
        if k == 0 {
            out.copy_from_slice(row);
            if m == 1 {
                return;
            }
        } else if k + 1 == m {
            add_scale(out, row, inv);
        } else {
            add_assign(out, row);
        }
    }
}

/// Averaged pseudo-gradient Δθ^g = mean_m(rows[m]) − θ_g (paper Eq. 1) in
/// exactly `rows.len()` memory passes: copy, accumulate, and a final fused
/// accumulate+scale+subtract.
///
/// `rows` are the per-worker fragment snapshots (anything slice-like, so
/// both pooled `Vec<f32>` buffers and borrowed slices work without an
/// intermediate ref vector).
pub fn fused_pseudo_mean<R: AsRef<[f32]>>(out: &mut [f32], rows: &[R], theta_g: &[f32]) {
    fused_pseudo_mean_iter(out, rows.iter().map(|r| r.as_ref()), theta_g);
}

/// Iterator-driven core of [`fused_pseudo_mean`] (lets callers stream
/// worker slices without collecting references).
pub fn fused_pseudo_mean_iter<'r, I>(out: &mut [f32], rows: I, theta_g: &[f32])
where
    I: ExactSizeIterator<Item = &'r [f32]>,
{
    let m = rows.len();
    assert!(m > 0, "pseudo-gradient mean needs at least one worker row");
    debug_assert_eq!(out.len(), theta_g.len());
    let inv = 1.0 / m as f32;
    for (k, row) in rows.enumerate() {
        debug_assert_eq!(row.len(), out.len());
        if k == 0 {
            if m == 1 {
                scale_sub_from(out, row, inv, theta_g);
                return;
            }
            out.copy_from_slice(row);
        } else if k + 1 == m {
            add_scale_sub(out, row, inv, theta_g);
        } else {
            add_assign(out, row);
        }
    }
}

/// CoCoDC delay compensation (Alg. 1, Eqs. 4/7/8) applied in place on a
/// worker's live fragment slice:
///
///   g      = (θ_local − θ_tp) / τ
///   g_corr = g + λ · g² · (θ_g − θ_tp) / H
///   θ_local ← θ_g + g_corr · τ
pub fn fused_delay_comp(
    theta_local: &mut [f32],
    theta_g: &[f32],
    theta_tp: &[f32],
    tau: f32,
    h: f32,
    lambda: f32,
) {
    debug_assert_eq!(theta_local.len(), theta_g.len());
    debug_assert_eq!(theta_local.len(), theta_tp.len());
    debug_assert!(tau > 0.0 && h > 0.0);
    let inv_tau = 1.0 / tau;
    let inv_h = 1.0 / h;
    let mut lc = theta_local.chunks_exact_mut(LANES);
    let mut gc = theta_g.chunks_exact(LANES);
    let mut pc = theta_tp.chunks_exact(LANES);
    for ((lo, g), p) in (&mut lc).zip(&mut gc).zip(&mut pc) {
        for i in 0..LANES {
            let gr = (lo[i] - p[i]) * inv_tau;
            let gcorr = gr + lambda * gr * gr * (g[i] - p[i]) * inv_h;
            lo[i] = g[i] + gcorr * tau;
        }
    }
    for ((lo, g), p) in lc.into_remainder().iter_mut().zip(gc.remainder()).zip(pc.remainder()) {
        let gr = (*lo - p) * inv_tau;
        let gcorr = gr + lambda * gr * gr * (g - p) * inv_h;
        *lo = g + gcorr * tau;
    }
}

/// Out-of-place variant of [`fused_delay_comp`] (θ_tl read separately).
pub fn fused_delay_comp_into(
    out: &mut [f32],
    theta_g: &[f32],
    theta_tl: &[f32],
    theta_tp: &[f32],
    tau: f32,
    h: f32,
    lambda: f32,
) {
    debug_assert_eq!(out.len(), theta_g.len());
    debug_assert_eq!(out.len(), theta_tl.len());
    debug_assert_eq!(out.len(), theta_tp.len());
    debug_assert!(tau > 0.0 && h > 0.0);
    let inv_tau = 1.0 / tau;
    let inv_h = 1.0 / h;
    let mut oc = out.chunks_exact_mut(LANES);
    let mut tc = theta_tl.chunks_exact(LANES);
    let mut gc = theta_g.chunks_exact(LANES);
    let mut pc = theta_tp.chunks_exact(LANES);
    for (((o, tl), g), p) in (&mut oc).zip(&mut tc).zip(&mut gc).zip(&mut pc) {
        for i in 0..LANES {
            let gr = (tl[i] - p[i]) * inv_tau;
            let gcorr = gr + lambda * gr * gr * (g[i] - p[i]) * inv_h;
            o[i] = g[i] + gcorr * tau;
        }
    }
    for (((o, tl), g), p) in oc
        .into_remainder()
        .iter_mut()
        .zip(tc.remainder())
        .zip(gc.remainder())
        .zip(pc.remainder())
    {
        let gr = (tl - p) * inv_tau;
        let gcorr = gr + lambda * gr * gr * (g - p) * inv_h;
        *o = g + gcorr * tau;
    }
}

/// Nesterov outer step (paper Eq. 2) on one fragment, unrolled:
///
///   grad = −delta;  mom ← μ·mom + grad;  θ_g ← θ_g − lr·(grad + μ·mom)
pub fn fused_outer_step(
    theta_g: &mut [f32],
    delta: &[f32],
    momentum_buf: &mut [f32],
    lr: f32,
    momentum: f32,
) {
    debug_assert_eq!(theta_g.len(), delta.len());
    debug_assert_eq!(theta_g.len(), momentum_buf.len());
    let mut tc = theta_g.chunks_exact_mut(LANES);
    let mut dc = delta.chunks_exact(LANES);
    let mut mc = momentum_buf.chunks_exact_mut(LANES);
    for ((t, d), mm) in (&mut tc).zip(&mut dc).zip(&mut mc) {
        for i in 0..LANES {
            let grad = -d[i];
            let m2 = momentum * mm[i] + grad;
            mm[i] = m2;
            t[i] -= lr * (grad + momentum * m2);
        }
    }
    for ((t, d), mm) in tc
        .into_remainder()
        .iter_mut()
        .zip(dc.remainder())
        .zip(mc.into_remainder().iter_mut())
    {
        let grad = -*d;
        let m2 = momentum * *mm + grad;
        *mm = m2;
        *t -= lr * (grad + momentum * m2);
    }
}

/// Streaming DiLoCo's mixing step (Eq. 3), fused:
/// x[i] ← (1−α)·x[i] + α·g[i]
pub fn fused_alpha_blend(x: &mut [f32], g: &[f32], alpha: f32) {
    debug_assert_eq!(x.len(), g.len());
    let om = 1.0 - alpha;
    let mut xc = x.chunks_exact_mut(LANES);
    let mut gc = g.chunks_exact(LANES);
    for (xs, gs) in (&mut xc).zip(&mut gc) {
        for i in 0..LANES {
            xs[i] = om * xs[i] + alpha * gs[i];
        }
    }
    for (xv, gv) in xc.into_remainder().iter_mut().zip(gc.remainder()) {
        *xv = om * *xv + alpha * gv;
    }
}

/// max_i |a[i] − b[i]|.
///
/// NaN-propagating: if any pairwise difference is NaN (poisoned input, or
/// ∞−∞), the result is NaN. The previous `fold(0.0, f32::max)` silently
/// dropped NaNs, so a poisoned fragment compared equal to a clean one.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut m = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x - y).abs();
        if d.is_nan() {
            return f32::NAN;
        }
        if d > m {
            m = d;
        }
    }
    m
}

/// Naive scalar references for the fused/unrolled kernels above.
///
/// These are the *seed implementations kept verbatim* (plus same-order
/// scalar twins for the new fused ops). They are the ground truth for the
/// 1-ulp property tests in tests/hotpath.rs and the before/after baselines
/// in benches/bench_vecops.rs — do not "optimize" them.
pub mod reference {
    /// Seed `vecops::sub`.
    pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x - y;
        }
    }

    /// Seed `vecops::add_assign`.
    pub fn add_assign(acc: &mut [f32], x: &[f32]) {
        for (a, &b) in acc.iter_mut().zip(x) {
            *a += b;
        }
    }

    /// Seed `vecops::scale`.
    pub fn scale(acc: &mut [f32], s: f32) {
        for a in acc.iter_mut() {
            *a *= s;
        }
    }

    /// Seed `vecops::mean_of`.
    pub fn mean_of(out: &mut [f32], rows: &[&[f32]]) {
        assert!(!rows.is_empty());
        let inv = 1.0 / rows.len() as f32;
        out.copy_from_slice(rows[0]);
        for r in &rows[1..] {
            add_assign(out, r);
        }
        scale(out, inv);
    }

    /// Scalar twin of `fused_pseudo_mean` (same association order).
    pub fn pseudo_mean(out: &mut [f32], rows: &[&[f32]], theta_g: &[f32]) {
        let m = rows.len();
        assert!(m > 0);
        let inv = 1.0 / m as f32;
        if m == 1 {
            for i in 0..out.len() {
                out[i] = rows[0][i] * inv - theta_g[i];
            }
            return;
        }
        out.copy_from_slice(rows[0]);
        for r in &rows[1..m - 1] {
            for (o, &v) in out.iter_mut().zip(*r) {
                *o += v;
            }
        }
        for i in 0..out.len() {
            out[i] = (out[i] + rows[m - 1][i]) * inv - theta_g[i];
        }
    }

    /// Seed accumulation order of `allreduce::mean_pseudo_gradients*`:
    /// Σ_m (θ_m − θ_g), then scale. Kept as the bench baseline and to
    /// document the reassociation tolerance.
    pub fn mean_pseudo_gradients_seed(acc: &mut [f32], rows: &[&[f32]], theta_g: &[f32]) {
        assert!(!rows.is_empty());
        acc.fill(0.0);
        for snap in rows {
            for i in 0..acc.len() {
                acc[i] += snap[i] - theta_g[i];
            }
        }
        let inv = 1.0 / rows.len() as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
    }

    /// Seed `delay_comp::delay_compensate` (out-of-place scalar loop).
    pub fn delay_compensate(
        out: &mut [f32],
        theta_g: &[f32],
        theta_tl: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) {
        let inv_tau = 1.0 / tau;
        let inv_h = 1.0 / h;
        for i in 0..out.len() {
            let g = (theta_tl[i] - theta_tp[i]) * inv_tau;
            let g_corr = g + lambda * g * g * (theta_g[i] - theta_tp[i]) * inv_h;
            out[i] = theta_g[i] + g_corr * tau;
        }
    }

    /// Seed `delay_comp::delay_compensate_inplace`.
    pub fn delay_compensate_inplace(
        theta_local: &mut [f32],
        theta_g: &[f32],
        theta_tp: &[f32],
        tau: f32,
        h: f32,
        lambda: f32,
    ) {
        let inv_tau = 1.0 / tau;
        let inv_h = 1.0 / h;
        for i in 0..theta_local.len() {
            let g = (theta_local[i] - theta_tp[i]) * inv_tau;
            let g_corr = g + lambda * g * g * (theta_g[i] - theta_tp[i]) * inv_h;
            theta_local[i] = theta_g[i] + g_corr * tau;
        }
    }

    /// Seed `outer_opt::outer_step`.
    pub fn outer_step(
        theta_g: &mut [f32],
        delta: &[f32],
        momentum_buf: &mut [f32],
        lr: f32,
        momentum: f32,
    ) {
        for i in 0..theta_g.len() {
            let grad = -delta[i];
            let m2 = momentum * momentum_buf[i] + grad;
            momentum_buf[i] = m2;
            theta_g[i] -= lr * (grad + momentum * m2);
        }
    }

    /// Seed α-blend loop from `streaming.rs::complete_due`.
    pub fn alpha_blend(x: &mut [f32], g: &[f32], alpha: f32) {
        for (xv, &gv) in x.iter_mut().zip(g) {
            *xv = (1.0 - alpha) * *xv + alpha * gv;
        }
    }

    /// Seed `runtime/native.rs::matmul` (axpy inner loop, moved here
    /// verbatim when the tiled kernel replaced it): out[n,p] = a[n,m] @
    /// b[m,p]. Ground truth for the exact-equality tile property tests.
    pub fn matmul(out: &mut [f32], a: &[f32], b: &[f32], n: usize, m: usize, p: usize) {
        debug_assert_eq!(out.len(), n * p);
        debug_assert_eq!(a.len(), n * m);
        debug_assert_eq!(b.len(), m * p);
        for i in 0..n {
            let row = &mut out[i * p..(i + 1) * p];
            row.fill(0.0);
            for j in 0..m {
                super::axpy(row, a[i * m + j], &b[j * p..(j + 1) * p]);
            }
        }
    }

    /// Seed `runtime/native.rs::matmul_bt` (dot-product inner loop):
    /// out[n,m] = dout[n,p] @ bᵀ where b is [m,p].
    pub fn matmul_bt(out: &mut [f32], dout: &[f32], b: &[f32], n: usize, m: usize, p: usize) {
        debug_assert_eq!(out.len(), n * m);
        for i in 0..n {
            let drow = &dout[i * p..(i + 1) * p];
            for j in 0..m {
                out[i * m + j] = super::dot(drow, &b[j * p..(j + 1) * p]);
            }
        }
    }

    /// Seed `runtime/native.rs::matmul_at_acc` (weight-gradient
    /// accumulation): gb[m,p] += aᵀ[m,n] @ dout[n,p].
    pub fn matmul_at_acc(gb: &mut [f32], a: &[f32], dout: &[f32], n: usize, m: usize, p: usize) {
        debug_assert_eq!(gb.len(), m * p);
        for i in 0..n {
            let drow = &dout[i * p..(i + 1) * p];
            for j in 0..m {
                super::axpy(&mut gb[j * p..(j + 1) * p], a[i * m + j], drow);
            }
        }
    }

    /// Multi-sweep twin of [`super::softmax_xent`], in the seed's
    /// structure (separate whole-batch passes for max, exp+sum, loss and
    /// grad, with per-pass scratch) but with the same per-element
    /// operation sequence and f64 partition sums — so the fused kernel is
    /// bit-identical to it, and this stays the ground truth for the 1-ulp
    /// property tests and the bench baseline.
    pub fn softmax_xent_split(
        logits: &mut [f32],
        targets: &[i32],
        v: usize,
        inv_n: f32,
        grad: bool,
    ) -> f64 {
        let n = targets.len();
        debug_assert_eq!(logits.len(), n * v);
        // Pass 0: save the target logits before the exp pass overwrites.
        let tgt: Vec<f32> = targets
            .iter()
            .enumerate()
            .map(|(r, &t)| logits[r * v + t as usize])
            .collect();
        // Pass 1: row maxima.
        let mut maxes = vec![f32::NEG_INFINITY; n];
        for (r, mx) in maxes.iter_mut().enumerate() {
            for &x in &logits[r * v..(r + 1) * v] {
                if x > *mx {
                    *mx = x;
                }
            }
        }
        // Pass 2: softmax numerators in place, f64 partition sums.
        let mut zs = vec![0.0f64; n];
        for (r, z) in zs.iter_mut().enumerate() {
            let mx = maxes[r];
            for x in logits[r * v..(r + 1) * v].iter_mut() {
                let e = (*x - mx).exp();
                *x = e;
                *z += e as f64;
            }
        }
        // Pass 3: summed negative log-likelihood.
        let mut loss = 0.0f64;
        for r in 0..n {
            loss += maxes[r] as f64 + zs[r].ln() - tgt[r] as f64;
        }
        // Pass 4: dlogits.
        if grad {
            for r in 0..n {
                let s = (1.0 / zs[r]) as f32 * inv_n;
                for x in logits[r * v..(r + 1) * v].iter_mut() {
                    *x *= s;
                }
                logits[r * v + targets[r] as usize] -= inv_n;
            }
        }
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sub_and_add_roundtrip() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![0.5f32, 1.0, -1.0];
        let mut d = vec![0.0; 3];
        sub(&mut d, &a, &b);
        assert_eq!(d, vec![0.5, 1.0, 4.0]);
        let mut acc = b.clone();
        add_assign(&mut acc, &d);
        assert_eq!(acc, a);
    }

    #[test]
    fn mean_matches_manual() {
        let r1 = vec![1.0f32, 2.0];
        let r2 = vec![3.0f32, 6.0];
        let mut out = vec![0.0; 2];
        mean_of(&mut out, &[&r1, &r2]);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn axpy_and_dot_basic() {
        let mut acc = vec![1.0f32; 19];
        let x: Vec<f32> = (0..19).map(|i| i as f32).collect();
        axpy(&mut acc, 2.0, &x);
        for (i, v) in acc.iter().enumerate() {
            assert_eq!(*v, 1.0 + 2.0 * i as f32);
        }
        // dot with a mixed remainder length
        let a: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let b = vec![2.0f32; 11];
        let want: f32 = (0..11).map(|i| 2.0 * i as f32).sum();
        assert_eq!(dot(&a, &b), want);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn l2_norm_known_value() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }

    #[test]
    fn max_abs_diff_propagates_nan() {
        assert!(max_abs_diff(&[1.0, f32::NAN], &[1.0, 0.0]).is_nan());
        // ∞ − ∞ poisons the comparison too.
        assert!(max_abs_diff(&[f32::INFINITY], &[f32::INFINITY]).is_nan());
        assert!(!max_abs_diff(&[1.0, 2.0], &[1.0, 2.0]).is_nan());
    }

    #[test]
    fn fused_pseudo_mean_basic() {
        // Two workers around theta_g: mean([2,4],[4,8])/1 - [1,1] = [2,5].
        let r1 = vec![2.0f32, 4.0];
        let r2 = vec![4.0f32, 8.0];
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0; 2];
        fused_pseudo_mean(&mut out, &[r1, r2], &g);
        assert_eq!(out, vec![2.0, 5.0]);
        // Single row reduces to row - theta_g.
        let mut out1 = vec![0.0; 2];
        fused_pseudo_mean(&mut out1, &[vec![3.0f32, 3.0]], &g);
        assert_eq!(out1, vec![2.0, 2.0]);
    }

    #[test]
    fn fused_alpha_blend_endpoints() {
        let g = vec![10.0f32; 9];
        let mut x = vec![2.0f32; 9];
        fused_alpha_blend(&mut x, &g, 0.0);
        assert_eq!(x, vec![2.0; 9]);
        fused_alpha_blend(&mut x, &g, 1.0);
        assert_eq!(x, vec![10.0; 9]);
    }

    #[test]
    fn fused_outer_step_matches_reference() {
        let delta = [0.3f32; 19];
        let mut t1 = [1.0f32; 19];
        let mut m1 = [0.1f32; 19];
        let mut t2 = t1;
        let mut m2 = m1;
        fused_outer_step(&mut t1, &delta, &mut m1, 0.7, 0.9);
        reference::outer_step(&mut t2, &delta, &mut m2, 0.7, 0.9);
        assert_eq!(t1, t2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn softmax_xent_fused_matches_split_bitwise() {
        for (n, v) in [(1usize, 5usize), (7, 13), (4, 32)] {
            let logits: Vec<f32> =
                (0..n * v).map(|i| ((i * 37 + 11) % 23) as f32 * 0.17 - 1.5).collect();
            let targets: Vec<i32> = (0..n).map(|r| ((r * 5 + 3) % v) as i32).collect();
            let inv_n = 1.0 / (n * v) as f32;
            for grad in [false, true] {
                let mut fused = logits.clone();
                let mut split = logits.clone();
                let lf = softmax_xent(&mut fused, &targets, v, inv_n, grad);
                let ls = reference::softmax_xent_split(&mut split, &targets, v, inv_n, grad);
                assert_eq!(lf.to_bits(), ls.to_bits(), "loss n={n} v={v} grad={grad}");
                assert_eq!(fused, split, "buffer n={n} v={v} grad={grad}");
            }
        }
    }

    #[test]
    fn fused_delay_comp_matches_reference_across_remainders() {
        for n in [0usize, 1, 7, 8, 9, 31, 64] {
            let g: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
            let tl: Vec<f32> = (0..n).map(|i| 1.0 + i as f32 * 0.5).collect();
            let tp: Vec<f32> = (0..n).map(|i| 0.5 - i as f32 * 0.125).collect();
            let mut got = tl.clone();
            fused_delay_comp(&mut got, &g, &tp, 5.0, 100.0, 0.5);
            let mut want = tl.clone();
            reference::delay_compensate_inplace(&mut want, &g, &tp, 5.0, 100.0, 0.5);
            assert_eq!(got, want, "n={n}");
            let mut out = vec![0.0; n];
            fused_delay_comp_into(&mut out, &g, &tl, &tp, 5.0, 100.0, 0.5);
            assert_eq!(out, want, "into n={n}");
        }
    }
}
