//! Property-testing driver (the proptest crate is unavailable offline).
//!
//! `forall(cases, |rng| ...)` runs a property over `cases` deterministic
//! random inputs; on failure it reports the case seed so the exact input
//! reproduces with `forall_seeded(seed, 1, ...)`. Used by the coordinator
//! invariant tests (routing/batching/state, per the dist-train guide).

use super::rng::Rng;

/// Run `prop` for `cases` deterministic cases. `prop` returns Err(msg) to
/// signal a counterexample.
pub fn forall<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    forall_seeded(0xC0C0DC, cases, &mut prop);
}

pub fn forall_seeded<F>(base_seed: u64, cases: u64, prop: &mut F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng::new(base_seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed on case {case} (seed {base_seed:#x}): {msg}");
        }
    }
}

/// Helpers for common generators.
impl Rng {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_gaussian() as f32 * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(32, |rng| {
            count += 1;
            let n = rng.usize_in(1, 10);
            let v = rng.f32_vec(n, 1.0);
            if v.is_empty() {
                return Err("empty".into());
            }
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        forall(8, |rng| {
            if rng.usize_in(0, 4) == 0 {
                Err("hit zero".into())
            } else {
                Ok(())
            }
        });
    }
}
