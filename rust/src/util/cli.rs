//! Minimal `--flag value` / `--flag` CLI parser (no clap offline).
//!
//! Supports long flags with values (`--steps 100`), boolean switches
//! (`--tau-network`), and positional arguments. Unknown flags error with
//! the set of known ones.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of args (without argv[0]). `bool_flags` lists
    /// the switches that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        bool_flags: &[&str],
    ) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (name.to_string(), None),
                };
                if bool_flags.contains(&name.as_str()) {
                    anyhow::ensure!(inline.is_none(), "--{name} takes no value");
                    out.switches.push(name);
                } else if let Some(v) = inline {
                    out.flags.insert(name, v);
                } else {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("--{name} expects a value")
                    })?;
                    out.flags.insert(name, v);
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(bool_flags: &[&str]) -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.switches.iter().any(|s| s == name)
    }

    /// Error on any flag the caller never looked at (catches typos).
    pub fn finish(&self) -> anyhow::Result<()> {
        let seen = self.consumed.borrow();
        for k in self.flags.keys() {
            anyhow::ensure!(
                seen.iter().any(|s| s == k),
                "unknown flag --{k} (known: {})",
                seen.join(", ")
            );
        }
        for k in &self.switches {
            anyhow::ensure!(seen.iter().any(|s| s == k), "unknown switch --{k}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags_switches_positionals() {
        let a = Args::parse(argv("train --steps 100 --tau-network --out x.csv"),
                            &["tau-network"]).unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_or::<u32>("steps", 0).unwrap(), 100);
        assert!(a.switch("tau-network"));
        assert_eq!(a.get("out"), Some("x.csv"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(argv("--steps=42"), &[]).unwrap();
        assert_eq!(a.get_or::<u32>("steps", 0).unwrap(), 42);
    }

    #[test]
    fn unknown_flag_detected() {
        let a = Args::parse(argv("--bogus 1"), &[]).unwrap();
        let _ = a.get("steps");
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("--steps"), &[]).is_err());
    }

    #[test]
    fn bad_parse_reports_flag() {
        let a = Args::parse(argv("--steps abc"), &[]).unwrap();
        let e = a.get_parse::<u32>("steps").unwrap_err().to_string();
        assert!(e.contains("steps"));
    }
}
