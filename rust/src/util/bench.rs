//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean / p50 / p95 and a black_box to stop
//! the optimizer from deleting the measured work. Used by every target in
//! rust/benches/ (all `harness = false`).
//!
//! [`HotpathReport`] additionally persists kernel measurements to
//! `BENCH_hotpath.json` at the repository root so the hot-path perf
//! trajectory is machine-readable (and committable as a baseline) across
//! PRs (see DESIGN.md §Hot path for the schema).

use std::hint::black_box as bb;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::{num, obj, s, Json};

pub use std::hint::black_box;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly for ~`budget` (after `warmup` iterations) and report.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    };
    println!(
        "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}",
        r.name, r.iters, r.mean, r.p50, r.p95, r.min
    );
    r
}

/// Convenience wrapper returning a value so closures can keep state alive.
pub fn bench_with_result<T, F: FnMut() -> T>(
    name: &str,
    warmup: usize,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    bench(name, warmup, budget, || {
        bb(f());
    })
}

/// Accumulates hot-path kernel measurements and merges them into
/// `BENCH_hotpath.json` (schema `cocodc-bench-hotpath-v1`):
///
/// ```json
/// { "schema": "cocodc-bench-hotpath-v1",
///   "entries": [ { "op": "pseudo_mean_fused", "n": 65536,
///                  "ns_per_elem": 0.21, "gb_per_s": 93.4,
///                  "mean_ns": 13762.0, "iters": 18031 },
///                { "op": "pseudo_mean_speedup", "n": 65536,
///                  "speedup": 2.6 } ] }
/// ```
///
/// Entries are keyed by `(op, n)`: re-running a bench replaces its own rows
/// and leaves rows written by other benches intact, so `bench_vecops` and
/// `bench_delay_comp` share one file.
#[derive(Debug, Default)]
pub struct HotpathReport {
    entries: Vec<(String, usize, Json)>,
}

impl HotpathReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one kernel measurement. `bytes_per_iter` is the total memory
    /// traffic (reads + writes) of one iteration, used for GB/s.
    pub fn push(&mut self, op: &str, n: usize, bytes_per_iter: f64, r: &BenchResult) {
        let mean_s = r.mean.as_secs_f64();
        let row = obj(vec![
            ("op", s(op)),
            ("n", num(n as f64)),
            ("ns_per_elem", num(mean_s * 1e9 / n.max(1) as f64)),
            ("gb_per_s", num(bytes_per_iter / mean_s / 1e9)),
            ("mean_ns", num(mean_s * 1e9)),
            ("iters", num(r.iters as f64)),
        ]);
        self.entries.push((op.to_string(), n, row));
    }

    /// Record a derived ratio (e.g. fused-vs-seed-scalar speedup).
    pub fn push_speedup(&mut self, op: &str, n: usize, speedup: f64) {
        let row = obj(vec![("op", s(op)), ("n", num(n as f64)), ("speedup", num(speedup))]);
        self.entries.push((op.to_string(), n, row));
    }

    /// Record a row with arbitrary numeric fields (e.g. the end-to-end
    /// train-loop rows: steps_per_s / sync_overhead_pct). Keyed by (op, n)
    /// like every other row.
    pub fn push_custom(&mut self, op: &str, n: usize, fields: &[(&str, f64)]) {
        let mut kv = vec![("op", s(op)), ("n", num(n as f64))];
        for (k, v) in fields {
            kv.push((*k, num(*v)));
        }
        self.entries.push((op.to_string(), n, obj(kv)));
    }

    /// `<repo root>/BENCH_hotpath.json` — one directory above the crate, so
    /// the committed perf-trajectory baseline sits at the repository root.
    pub fn default_path() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_hotpath.json")
    }

    /// Merge this report into `path`, replacing rows with matching (op, n).
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        let mut rows: Vec<Json> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(old) = Json::parse(&text) {
                if let Some(Json::Arr(entries)) = old.get("entries") {
                    for e in entries {
                        let replaced = match (e.get("op"), e.get("n")) {
                            (Some(Json::Str(op)), Some(Json::Num(n))) => self
                                .entries
                                .iter()
                                .any(|(o, nn, _)| o == op && *nn == *n as usize),
                            // Rows we can't key by (op, n) aren't ours to
                            // replace — keep them.
                            _ => false,
                        };
                        if !replaced {
                            rows.push(e.clone());
                        }
                    }
                }
            }
        }
        rows.extend(self.entries.iter().map(|(_, _, row)| row.clone()));
        let doc = obj(vec![
            ("schema", s("cocodc-bench-hotpath-v1")),
            ("entries", Json::Arr(rows)),
        ]);
        std::fs::write(path, doc.to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 2, Duration::from_millis(20), || {
            bb((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
    }

    #[test]
    fn hotpath_report_merges_by_op_and_n() {
        let path = std::env::temp_dir().join("cocodc_bench_hotpath_test.json");
        std::fs::remove_file(&path).ok();
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean: Duration::from_nanos(1000),
            p50: Duration::from_nanos(1000),
            p95: Duration::from_nanos(1000),
            min: Duration::from_nanos(1000),
        };
        let mut a = HotpathReport::new();
        a.push("op_a", 64, 64.0 * 4.0, &r);
        a.push_speedup("op_a_speedup", 64, 2.5);
        a.write(&path).unwrap();
        // Second report: replaces op_a@64, keeps the speedup row.
        let mut b = HotpathReport::new();
        b.push("op_a", 64, 64.0 * 4.0, &r);
        b.push("op_b", 128, 128.0 * 4.0, &r);
        b.write(&path).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let entries = doc.field("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 3, "{entries:?}");
        let ops: Vec<&str> = entries
            .iter()
            .map(|e| e.field("op").unwrap().as_str().unwrap())
            .collect();
        assert!(ops.contains(&"op_a") && ops.contains(&"op_b") && ops.contains(&"op_a_speedup"));
        std::fs::remove_file(&path).ok();
    }
}
