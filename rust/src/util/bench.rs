//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean / p50 / p95 and a black_box to stop
//! the optimizer from deleting the measured work. Used by every target in
//! rust/benches/ (all `harness = false`).

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly for ~`budget` (after `warmup` iterations) and report.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    };
    println!(
        "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}",
        r.name, r.iters, r.mean, r.p50, r.p95, r.min
    );
    r
}

/// Convenience wrapper returning a value so closures can keep state alive.
pub fn bench_with_result<T, F: FnMut() -> T>(
    name: &str,
    warmup: usize,
    budget: Duration,
    mut f: F,
) -> BenchResult {
    bench(name, warmup, budget, || {
        bb(f());
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 2, Duration::from_millis(20), || {
            bb((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
    }
}
