//! Deterministic splittable RNG: splitmix64 seeding + xoshiro256++ core.
//!
//! Every stochastic choice in the system (corpus generation, shard draws,
//! network jitter) flows through this so that a (seed, stream) pair fully
//! determines a run — the paper's experiments are seed-controlled and so are
//! ours.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a seed; `stream` decorrelates parallel consumers
    /// (e.g. per-worker data shards) drawn from the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xA0761D6478BD642F);
        let mut s = [0u64; 4];
        for x in s.iter_mut() {
            *x = splitmix64(&mut sm);
        }
        // xoshiro must not start at all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method is overkill here; modulo bias
        // is negligible for n << 2^64 and determinism is what matters.
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box-Muller (one value per call; simple and
    /// deterministic).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork an independent stream (for nested deterministic consumers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    /// Full generator state — with [`Rng::from_state`] this makes stream
    /// positions checkpointable (data cursors survive save/restore).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Resume a generator exactly where [`Rng::state`] captured it.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42, 7);
        let mut b = Rng::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn state_round_trip_resumes_exactly() {
        let mut a = Rng::new(7, 3);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(1, 0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(2, 0);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy_entries() {
        let mut r = Rng::new(3, 0);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > 8 * counts[1] / 2, "{counts:?}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(4, 0);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
        assert_eq!(r.below(1), 0);
    }
}
