//! Persistent worker thread pool for the trainer's fan-out points.
//!
//! The seed `Trainer::step_all` spawned fresh OS threads via
//! `std::thread::scope` on *every* lockstep round — thousands of
//! spawn/join cycles per run. This pool spawns its threads once and reuses
//! them for local train steps, CoCoDC's per-worker delay-compensation
//! fan-out and parallel validation batches.
//!
//! [`WorkerPool::scoped`] gives `thread::scope` semantics on pooled
//! threads: tasks may borrow from the caller's stack because the call
//! blocks until every submitted task has finished (a guard decrements the
//! completion count even on panic, and the first panic payload is re-thrown
//! on the caller thread). While waiting, the caller helps drain the queue,
//! so a pool of N threads actually applies N+1 workers and a task running
//! on the caller can never deadlock the scope.
//!
//! Do not call [`WorkerPool::scoped`] from *inside* a pool task: nested
//! scopes on the same pool can exhaust the threads and (with an empty
//! queue) wait on tasks that can no longer be scheduled. The trainer only
//! fans out from the coordinator thread.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A borrowed task submitted to [`WorkerPool::scoped`].
pub type ScopedTask<'scope> = Box<dyn FnOnce() + Send + 'scope>;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    available: Condvar,
}

struct ScopeState {
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Fixed-size pool of persistent worker threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` persistent workers (at least one).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("cocodc-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Pool sized to the host: one thread per available core, capped.
    pub fn with_default_size(cap: usize) -> WorkerPool {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        WorkerPool::new(hw.min(cap.max(1)))
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Run every task to completion, blocking the caller until all are done
    /// (the caller participates in draining the queue). Panics inside tasks
    /// are re-thrown here after the scope has fully quiesced.
    pub fn scoped<'scope>(&self, tasks: Vec<ScopedTask<'scope>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let state = Arc::new(ScopeState {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            for task in tasks {
                // SAFETY: `scoped` does not return until `remaining` hits
                // zero, and `run_one` decrements it for every task — on the
                // success path and on panic alike. No task (or borrow it
                // captures) can therefore outlive this call, which is
                // exactly the guarantee the 'scope lifetime needs; the
                // transmute only erases that lifetime so the task can sit
                // in the 'static queue.
                let task: Job = unsafe {
                    std::mem::transmute::<ScopedTask<'scope>, ScopedTask<'static>>(task)
                };
                let st = Arc::clone(&state);
                q.jobs.push_back(Box::new(move || run_one(task, &st)));
            }
            self.shared.available.notify_all();
        }
        // Help drain the queue while waiting.
        loop {
            let job = {
                let mut q = self.shared.queue.lock().expect("pool queue poisoned");
                q.jobs.pop_front()
            };
            match job {
                Some(job) => job(),
                None => break,
            }
        }
        let mut remaining = state.remaining.lock().expect("scope state poisoned");
        while *remaining > 0 {
            remaining = state.done.wait(remaining).expect("scope state poisoned");
        }
        drop(remaining);
        if let Some(payload) = state.panic.lock().expect("scope state poisoned").take() {
            resume_unwind(payload);
        }
    }
}

fn run_one(task: Job, st: &ScopeState) {
    let result = catch_unwind(AssertUnwindSafe(task));
    if let Err(payload) = result {
        let mut slot = st.panic.lock().expect("scope state poisoned");
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
    let mut remaining = st.remaining.lock().expect("scope state poisoned");
    *remaining -= 1;
    if *remaining == 0 {
        st.done.notify_all();
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("pool queue poisoned");
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.available.wait(q).expect("pool queue poisoned");
            }
        };
        job();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn tasks_borrow_and_fill_disjoint_slots() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0usize; 64];
        let tasks: Vec<ScopedTask<'_>> = out
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| Box::new(move || *slot = i * i) as ScopedTask<'_>)
            .collect();
        pool.scoped(tasks);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn pool_threads_are_reused_across_scopes() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let tasks: Vec<ScopedTask<'_>> = (0..8)
                .map(|_| {
                    let c = &counter;
                    Box::new(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.scoped(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 400);
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn single_thread_pool_still_completes_scopes() {
        let pool = WorkerPool::new(1);
        let mut xs = [0i64; 16];
        let tasks: Vec<ScopedTask<'_>> = xs
            .iter_mut()
            .enumerate()
            .map(|(i, x)| Box::new(move || *x = i as i64 + 1) as ScopedTask<'_>)
            .collect();
        pool.scoped(tasks);
        assert_eq!(xs.iter().sum::<i64>(), (1..=16).sum::<i64>());
    }

    #[test]
    fn empty_scope_is_a_no_op() {
        let pool = WorkerPool::new(2);
        pool.scoped(Vec::new());
    }

    #[test]
    fn panics_propagate_to_the_caller() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scoped(vec![Box::new(|| panic!("task exploded")) as ScopedTask<'_>]);
        }));
        assert!(result.is_err());
        // The pool must still be usable after a panicked scope.
        let done = AtomicUsize::new(0);
        pool.scoped(vec![Box::new(|| {
            done.fetch_add(1, Ordering::Relaxed);
        }) as ScopedTask<'_>]);
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }
}
