//! Persistent worker thread pool for the trainer's fan-out points.
//!
//! The seed `Trainer::step_all` spawned fresh OS threads via
//! `std::thread::scope` on *every* lockstep round — thousands of
//! spawn/join cycles per run. This pool spawns its threads once and reuses
//! them for local train steps, CoCoDC's per-worker delay-compensation
//! fan-out, parallel validation batches and the native backend's
//! intra-step row shards.
//!
//! [`WorkerPool::scoped`] gives `thread::scope` semantics on pooled
//! threads: tasks may borrow from the caller's stack because the call
//! blocks until every submitted task has finished (the completion count is
//! decremented even on panic, and the first panic payload is re-thrown on
//! the caller thread). A waiting caller never sleeps while work is
//! queued: it steals and runs jobs from the shared queue until its own
//! scope has quiesced, so a pool of N threads applies N+1 workers. A
//! scope with exactly one task skips the queue and runs inline on the
//! caller — cost-identical to a plain function call.
//!
//! Nested scopes are supported: a scope opened from *inside* a pool task
//! enqueues its sub-tasks on the same shared queue and the opening thread
//! steals jobs while it waits — including jobs of other scopes. Every
//! thread blocked in [`WorkerPool::scoped`] is therefore itself a worker,
//! so the scope tree always has at least one runnable executor and cannot
//! deadlock, even when every pool thread is already busy. The native
//! backend relies on this to shard one worker's batch rows from within
//! the trainer's worker-level fan-out.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A borrowed task submitted to [`WorkerPool::scoped`].
pub type ScopedTask<'scope> = Box<dyn FnOnce() + Send + 'scope>;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    available: Condvar,
}

struct ScopeState {
    remaining: AtomicUsize,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Fixed-size pool of persistent worker threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` persistent workers (at least one).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("cocodc-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Pool sized to the host: one thread per available core, capped.
    pub fn with_default_size(cap: usize) -> WorkerPool {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        WorkerPool::new(hw.min(cap.max(1)))
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Run every task to completion, blocking the caller until all are done.
    /// While blocked the caller steals queued jobs (its own scope's or any
    /// other's, so nested scopes make progress through blocked openers).
    /// Panics inside tasks are re-thrown here after the scope has fully
    /// quiesced.
    pub fn scoped<'scope>(&self, mut tasks: Vec<ScopedTask<'scope>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        // A one-task scope gains nothing from the queue: run it inline on
        // the caller, skipping the lock/notify/steal round-trip entirely
        // (a panic then unwinds directly, same as re-thrown). This makes
        // single-shard dispatches cost-identical to a plain call.
        if n == 1 {
            (tasks.pop().expect("one task"))();
            return;
        }
        let state = Arc::new(ScopeState {
            remaining: AtomicUsize::new(n),
            panic: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            for task in tasks {
                // SAFETY: `scoped` does not return until `remaining` hits
                // zero, and `run_one` decrements it for every task — on the
                // success path and on panic alike. No task (or borrow it
                // captures) can therefore outlive this call, which is
                // exactly the guarantee the 'scope lifetime needs; the
                // transmute only erases that lifetime so the task can sit
                // in the 'static queue.
                let task: Job = unsafe {
                    std::mem::transmute::<ScopedTask<'scope>, ScopedTask<'static>>(task)
                };
                let st = Arc::clone(&state);
                let sh = Arc::clone(&self.shared);
                q.jobs.push_back(Box::new(move || run_one(task, &st, &sh)));
            }
            self.shared.available.notify_all();
        }
        // Steal jobs while waiting. The `remaining` check happens under the
        // queue lock, and the final decrement notifies `available` under the
        // same lock, so a wakeup can never be lost between check and sleep.
        loop {
            let job = {
                let mut q = self.shared.queue.lock().expect("pool queue poisoned");
                loop {
                    if state.remaining.load(Ordering::Acquire) == 0 {
                        break None;
                    }
                    if let Some(job) = q.jobs.pop_front() {
                        break Some(job);
                    }
                    q = self.shared.available.wait(q).expect("pool queue poisoned");
                }
            };
            match job {
                Some(job) => job(),
                None => break,
            }
        }
        if let Some(payload) = state.panic.lock().expect("scope state poisoned").take() {
            resume_unwind(payload);
        }
    }
}

fn run_one(task: Job, st: &ScopeState, shared: &Shared) {
    let result = catch_unwind(AssertUnwindSafe(task));
    if let Err(payload) = result {
        let mut slot = st.panic.lock().expect("scope state poisoned");
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
    if st.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        // Last task of the scope: wake every waiter so the opener (possibly
        // asleep on `available` with an empty queue) can observe zero. The
        // lock makes the notification ordered against the opener's
        // check-then-sleep above.
        let _q = shared.queue.lock().expect("pool queue poisoned");
        shared.available.notify_all();
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("pool queue poisoned");
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.available.wait(q).expect("pool queue poisoned");
            }
        };
        job();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn tasks_borrow_and_fill_disjoint_slots() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0usize; 64];
        let tasks: Vec<ScopedTask<'_>> = out
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| Box::new(move || *slot = i * i) as ScopedTask<'_>)
            .collect();
        pool.scoped(tasks);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn pool_threads_are_reused_across_scopes() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let tasks: Vec<ScopedTask<'_>> = (0..8)
                .map(|_| {
                    let c = &counter;
                    Box::new(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.scoped(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 400);
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn single_thread_pool_still_completes_scopes() {
        let pool = WorkerPool::new(1);
        let mut xs = [0i64; 16];
        let tasks: Vec<ScopedTask<'_>> = xs
            .iter_mut()
            .enumerate()
            .map(|(i, x)| Box::new(move || *x = i as i64 + 1) as ScopedTask<'_>)
            .collect();
        pool.scoped(tasks);
        assert_eq!(xs.iter().sum::<i64>(), (1..=16).sum::<i64>());
    }

    #[test]
    fn empty_scope_is_a_no_op() {
        let pool = WorkerPool::new(2);
        pool.scoped(Vec::new());
    }

    /// A one-task scope must run inline on the caller thread (no queue
    /// round-trip), while still honouring borrow-and-mutate semantics.
    #[test]
    fn single_task_scope_runs_inline_on_caller() {
        let pool = WorkerPool::new(2);
        let caller = std::thread::current().id();
        let mut ran_on = None;
        let mut slot = 0usize;
        {
            let ran = &mut ran_on;
            let s = &mut slot;
            pool.scoped(vec![Box::new(move || {
                *ran = Some(std::thread::current().id());
                *s = 7;
            }) as ScopedTask<'_>]);
        }
        assert_eq!(ran_on, Some(caller));
        assert_eq!(slot, 7);
    }

    #[test]
    fn panics_propagate_to_the_caller() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scoped(vec![Box::new(|| panic!("task exploded")) as ScopedTask<'_>]);
        }));
        assert!(result.is_err());
        // The pool must still be usable after a panicked scope.
        let done = AtomicUsize::new(0);
        pool.scoped(vec![Box::new(|| {
            done.fetch_add(1, Ordering::Relaxed);
        }) as ScopedTask<'_>]);
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }

    /// Regression: a scope opened from inside a pool task must complete even
    /// when every pool thread is occupied by an outer task — the blocked
    /// openers steal the nested jobs. Run under a watchdog so a deadlock
    /// fails the test instead of hanging the suite.
    #[test]
    fn nested_scope_inside_pool_task_does_not_deadlock() {
        let (tx, rx) = mpsc::channel();
        let watched = std::thread::spawn(move || {
            let pool = WorkerPool::new(2);
            let mut out = vec![0usize; 4 * 8];
            let outer: Vec<ScopedTask<'_>> = out
                .chunks_mut(8)
                .enumerate()
                .map(|(ci, chunk)| {
                    let pref = &pool;
                    Box::new(move || {
                        let inner: Vec<ScopedTask<'_>> = chunk
                            .iter_mut()
                            .enumerate()
                            .map(|(i, slot)| {
                                Box::new(move || *slot = ci * 100 + i) as ScopedTask<'_>
                            })
                            .collect();
                        pref.scoped(inner);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.scoped(outer);
            tx.send(out).expect("send watchdog result");
        });
        let out = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("nested scope deadlocked (watchdog timeout)");
        watched.join().expect("watchdog thread panicked");
        for (ci, chunk) in out.chunks(8).enumerate() {
            for (i, v) in chunk.iter().enumerate() {
                assert_eq!(*v, ci * 100 + i);
            }
        }
    }

    fn fanout(pool: &WorkerPool, depth: usize, counter: &AtomicUsize) {
        if depth == 0 {
            counter.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let tasks: Vec<ScopedTask<'_>> = (0..3)
            .map(|_| Box::new(move || fanout(pool, depth - 1, counter)) as ScopedTask<'_>)
            .collect();
        pool.scoped(tasks);
    }

    /// Two levels of nesting on a single-thread pool: everything executes on
    /// the caller + the one worker via job stealing.
    #[test]
    fn deeply_nested_scopes_on_tiny_pool() {
        let (tx, rx) = mpsc::channel();
        let watched = std::thread::spawn(move || {
            let pool = WorkerPool::new(1);
            let total = AtomicUsize::new(0);
            fanout(&pool, 3, &total);
            tx.send(total.load(Ordering::Relaxed)).expect("send watchdog result");
        });
        let total = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("nested scope deadlocked (watchdog timeout)");
        watched.join().expect("watchdog thread panicked");
        assert_eq!(total, 27);
    }

    /// A panic in a nested scope unwinds through the outer scope to the
    /// original caller, and the pool stays usable.
    #[test]
    fn nested_panics_propagate_through_outer_scope() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let pref = &pool;
            pool.scoped(vec![Box::new(move || {
                pref.scoped(vec![Box::new(|| panic!("inner exploded")) as ScopedTask<'_>]);
            }) as ScopedTask<'_>]);
        }));
        assert!(result.is_err());
        let done = AtomicUsize::new(0);
        pool.scoped(vec![Box::new(|| {
            done.fetch_add(1, Ordering::Relaxed);
        }) as ScopedTask<'_>]);
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }
}
