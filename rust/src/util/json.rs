//! Minimal JSON parser/serializer (offline build: no serde available).
//!
//! Supports the full JSON grammar minus exotic escapes (\u is decoded for
//! BMP code points). Used for `artifacts/<preset>/meta.json` and for
//! RunConfig files; numbers are kept as f64 (all our integral fields fit
//! exactly in the 2^53 mantissa).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the missing path (anyhow).
    pub fn field(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field '{key}'"))
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => anyhow::bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let x = self.as_f64()?;
        anyhow::ensure!(x >= 0.0 && x.fract() == 0.0, "expected usize, got {x}");
        Ok(x as usize)
    }

    pub fn as_u64(&self) -> anyhow::Result<u64> {
        let x = self.as_f64()?;
        anyhow::ensure!(x >= 0.0 && x.fract() == 0.0, "expected u64, got {x}");
        Ok(x as u64)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => anyhow::bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("expected object, got {self:?}"),
        }
    }

    // ---------------- parsing ----------------
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing characters at {}", p.pos);
        Ok(v)
    }

    // ---------------- serialization ----------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    x.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek()? == b,
            "expected '{}' at byte {}, found '{}'",
            b as char,
            self.pos,
            self.peek()? as char
        );
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(s.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += s.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(
                                self.pos + 4 <= self.bytes.len(),
                                "truncated \\u escape"
                            );
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            );
                        }
                        _ => anyhow::bail!("bad escape \\{}", e as char),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // multi-byte UTF-8: copy the full sequence
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    anyhow::ensure!(start + len <= self.bytes.len(), "truncated UTF-8");
                    out.push_str(std::str::from_utf8(&self.bytes[start..start + len])?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number '{s}' at byte {start}: {e}")
        })?))
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                c => anyhow::bail!("expected ',' or ']' at byte {}, got '{}'",
                                   self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                c => anyhow::bail!("expected ',' or '}}' at byte {}, got '{}'",
                                   self.pos, c as char),
            }
        }
    }
}

/// Convenience builders used by serializers.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.field("c").unwrap().as_str().unwrap(), "x");
        let arr = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(!arr[2].field("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn round_trips_through_pretty_printer() {
        let src = r#"{"name":"frag/0","sizes":[1,2,3],"nested":{"x":1.25,"y":null},"flag":true}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string_pretty();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_accessors_enforce_integrality() {
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("42.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn handles_unicode_strings() {
        let v = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(back, v);
    }
}
