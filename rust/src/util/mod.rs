//! Small shared utilities, all dependency-free (this build is offline):
//! a deterministic splittable RNG, dense vector kernels (fused/unrolled),
//! a fragment-buffer recycling pool, a persistent worker thread pool, a
//! minimal JSON parser/serializer, a CLI flag parser, a micro-benchmark
//! harness and a property-testing driver.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod threadpool;
pub mod vecops;

pub use pool::{BufferPool, PoolStats};
pub use rng::Rng;
pub use threadpool::{ScopedTask, WorkerPool};

/// Explicitly saturating f64 → u32 conversion: NaN maps to 0, values below
/// zero clamp to 0, values at or above `u32::MAX` clamp to `u32::MAX`.
/// Used where schedule arithmetic (τ derivation, Eq. 9 fragment counts) can
/// produce huge or degenerate intermediates — `as` saturates too since Rust
/// 1.45, but this spells the policy out and is guarded by tests.
#[inline]
pub fn saturating_f64_to_u32(x: f64) -> u32 {
    if x.is_nan() {
        return 0;
    }
    if x <= 0.0 {
        0
    } else if x >= u32::MAX as f64 {
        u32::MAX
    } else {
        x as u32
    }
}

#[cfg(test)]
mod tests {
    use super::saturating_f64_to_u32;

    #[test]
    fn saturating_cast_covers_degenerate_inputs() {
        assert_eq!(saturating_f64_to_u32(f64::NAN), 0);
        assert_eq!(saturating_f64_to_u32(f64::NEG_INFINITY), 0);
        assert_eq!(saturating_f64_to_u32(-1.0), 0);
        assert_eq!(saturating_f64_to_u32(0.0), 0);
        assert_eq!(saturating_f64_to_u32(1.9), 1);
        assert_eq!(saturating_f64_to_u32(4.0), 4);
        assert_eq!(saturating_f64_to_u32(u32::MAX as f64), u32::MAX);
        assert_eq!(saturating_f64_to_u32(1e300), u32::MAX);
        assert_eq!(saturating_f64_to_u32(f64::INFINITY), u32::MAX);
    }
}
