//! Small shared utilities, all dependency-free (this build is offline):
//! a deterministic splittable RNG, dense vector kernels (fused/unrolled),
//! a fragment-buffer recycling pool, a persistent worker thread pool, a
//! minimal JSON parser/serializer, a CLI flag parser, a micro-benchmark
//! harness and a property-testing driver.

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod threadpool;
pub mod vecops;

pub use pool::{BufferPool, PoolStats};
pub use rng::Rng;
pub use threadpool::{ScopedTask, WorkerPool};
