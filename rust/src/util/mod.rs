//! Small shared utilities, all dependency-free (this build is offline):
//! a deterministic splittable RNG, dense vector helpers, a minimal JSON
//! parser/serializer, a CLI flag parser, a micro-benchmark harness and a
//! property-testing driver.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod vecops;

pub use rng::Rng;
