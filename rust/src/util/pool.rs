//! Fragment-sized `Vec<f32>` recycling for the sync hot path.
//!
//! Before this pool, every sync initiation heap-allocated M per-worker
//! snapshots plus the averaged pseudo-gradient, and every completion
//! allocated fragment copies of θ_g — per fragment, per sync, forever. The
//! pool turns those into one-time allocations: buffers are checked out with
//! [`BufferPool::take`], fully overwritten by the caller, and handed back
//! with [`BufferPool::put`] when the sync completes. In steady state a full
//! initiate/complete cycle performs **zero** heap allocations
//! (tests/alloc_steady_state.rs asserts this with a counting global
//! allocator; tests/hotpath.rs asserts it via [`PoolStats`]).
//!
//! Buffers are bucketed by exact length — fragment sizes are few and fixed
//! per run, so buckets stay small and lookups are a cheap BTreeMap probe.

use std::collections::BTreeMap;

/// Counters describing pool behavior since construction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers created fresh because no recycled one was available.
    pub fresh: usize,
    /// Takes served from the free lists.
    pub reused: usize,
    /// Buffers handed back via [`BufferPool::put`].
    pub returned: usize,
    /// Buffers currently checked out.
    pub outstanding: usize,
}

/// Recycling pool for fragment-sized f32 buffers (and the outer
/// `Vec<Vec<f32>>` snapshot shells).
#[derive(Debug, Default)]
pub struct BufferPool {
    buckets: BTreeMap<usize, Vec<Vec<f32>>>,
    shells: Vec<Vec<Vec<f32>>>,
    stats: PoolStats,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out a buffer of exactly `n` elements.
    ///
    /// Contents are unspecified — recycled buffers keep their stale values;
    /// callers must fully overwrite before reading (every hot-path use
    /// writes via `copy_from_slice` or a fused kernel).
    pub fn take(&mut self, n: usize) -> Vec<f32> {
        self.stats.outstanding += 1;
        if let Some(buf) = self.buckets.get_mut(&n).and_then(|b| b.pop()) {
            self.stats.reused += 1;
            debug_assert_eq!(buf.len(), n);
            return buf;
        }
        self.stats.fresh += 1;
        vec![0.0; n]
    }

    /// Return a buffer for reuse. Buffers that never allocated (capacity 0)
    /// are dropped.
    pub fn put(&mut self, buf: Vec<f32>) {
        self.stats.returned += 1;
        self.stats.outstanding = self.stats.outstanding.saturating_sub(1);
        if buf.capacity() == 0 {
            return;
        }
        self.buckets.entry(buf.len()).or_default().push(buf);
    }

    /// Check out an empty outer vector for a per-worker snapshot set; its
    /// capacity is retained across syncs.
    pub fn take_shell(&mut self) -> Vec<Vec<f32>> {
        self.shells.pop().unwrap_or_default()
    }

    /// Return a snapshot set: inner buffers go back to their buckets, the
    /// shell keeps its capacity for the next initiation.
    pub fn put_shell(&mut self, mut shell: Vec<Vec<f32>>) {
        for buf in shell.drain(..) {
            self.put(buf);
        }
        self.shells.push(shell);
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Buffers currently parked in the free lists.
    pub fn idle(&self) -> usize {
        self.buckets.values().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_take_reuses_the_buffer() {
        let mut pool = BufferPool::new();
        let mut a = pool.take(16);
        assert_eq!(a.len(), 16);
        a[0] = 7.0;
        let ptr = a.as_ptr();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.take(16);
        assert_eq!(b.as_ptr(), ptr, "same backing buffer must come back");
        let s = pool.stats();
        assert_eq!((s.fresh, s.reused, s.returned, s.outstanding), (1, 1, 1, 1));
    }

    #[test]
    fn distinct_sizes_use_distinct_buckets() {
        let mut pool = BufferPool::new();
        let a = pool.take(8);
        let b = pool.take(4);
        pool.put(a);
        pool.put(b);
        assert_eq!(pool.take(4).len(), 4);
        assert_eq!(pool.take(8).len(), 8);
        assert_eq!(pool.stats().fresh, 2);
        assert_eq!(pool.stats().reused, 2);
    }

    #[test]
    fn fresh_buffers_are_zeroed_reused_are_not_required_to_be() {
        let mut pool = BufferPool::new();
        let a = pool.take(4);
        assert!(a.iter().all(|&x| x == 0.0));
        pool.put(a);
    }

    #[test]
    fn shells_recycle_inner_buffers() {
        let mut pool = BufferPool::new();
        let mut shell = pool.take_shell();
        shell.push(pool.take(10));
        shell.push(pool.take(10));
        pool.put_shell(shell);
        assert_eq!(pool.idle(), 2);
        assert_eq!(pool.stats().outstanding, 0);
        // Shell comes back with retained capacity.
        let shell2 = pool.take_shell();
        assert!(shell2.capacity() >= 2);
        assert!(shell2.is_empty());
    }

    #[test]
    fn zero_capacity_buffers_are_dropped() {
        let mut pool = BufferPool::new();
        pool.put(Vec::new());
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.stats().returned, 1);
    }
}
