//! Durable checkpoint ring: a directory holding the last K checkpoints plus
//! a small JSON manifest. Every write is atomic (tmp + fsync + rename), so a
//! crash mid-save can never destroy an already-written snapshot, and
//! [`CheckpointRing::load_newest_valid`] walks the ring newest-first and
//! falls back past torn or corrupt files.
//!
//! Layout of a ring directory:
//!
//! ```text
//! <dir>/ckpt-0000000010.bin    checkpoint at step 10 (format v2)
//! <dir>/ckpt-0000000020.bin    checkpoint at step 20
//! <dir>/manifest.json          { "version": 1, "last_good": 20,
//!                                "entries": [ {"step":10,"file":"..."}, ... ] }
//! ```
//!
//! The manifest is advisory: recovery merges it with a directory scan, so a
//! missing or stale manifest (e.g. a crash between the checkpoint rename and
//! the manifest rename) only costs an extra integrity check, never data.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

use super::{write_atomic, Checkpoint};

/// Env hook for crash testing: set `COCODC_CKPT_KILL=torn:<step>` and the
/// ring will write a half-length (torn) file for that step *without* the
/// atomic dance or a manifest update, then abort the process with exit code
/// 3 — simulating a kill arriving mid-save. CI's recovery-matrix job uses
/// this to prove resume falls back to the previous snapshot.
pub const KILL_ENV: &str = "COCODC_CKPT_KILL";

const MANIFEST: &str = "manifest.json";

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RingEntry {
    pub step: u32,
    pub file: String,
}

#[derive(Debug)]
pub struct CheckpointRing {
    dir: PathBuf,
    keep: usize,
    /// Sorted by step ascending; newest last.
    entries: Vec<RingEntry>,
    last_good: Option<u32>,
}

fn entry_file(step: u32) -> String {
    format!("ckpt-{step:010}.bin")
}

/// Parse the step out of a `ckpt-<step>.bin` filename.
fn parse_entry_file(name: &str) -> Option<u32> {
    let stem = name.strip_prefix("ckpt-")?.strip_suffix(".bin")?;
    stem.parse::<u32>().ok()
}

impl CheckpointRing {
    /// Open (or create) a ring directory, merging the manifest — if present
    /// and parseable — with a scan for `ckpt-*.bin` files.
    pub fn new<P: AsRef<Path>>(dir: P, keep: usize) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let keep = keep.max(1);
        let mut entries: Vec<RingEntry> = Vec::new();
        let mut last_good = None;
        if let Ok(text) = std::fs::read_to_string(dir.join(MANIFEST)) {
            if let Ok(j) = Json::parse(&text) {
                if let Some(lg) = j.get("last_good") {
                    if let Ok(step) = lg.as_u64() {
                        last_good = Some(step as u32);
                    }
                }
                if let Some(arr) = j.get("entries").and_then(|e| e.as_arr().ok()) {
                    for e in arr {
                        let step = e.get("step").and_then(|s| s.as_u64().ok());
                        let file = e.get("file").and_then(|f| f.as_str().ok());
                        if let (Some(step), Some(file)) = (step, file) {
                            entries.push(RingEntry {
                                step: step as u32,
                                file: file.to_string(),
                            });
                        }
                    }
                }
            }
        }
        // Merge with what's actually on disk: files the manifest missed
        // (crash before the manifest write) are still recovery candidates.
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for entry in rd.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if let Some(step) = parse_entry_file(&name) {
                    if !entries.iter().any(|e| e.step == step) {
                        entries.push(RingEntry { step, file: name });
                    }
                }
            }
        }
        entries.sort_by_key(|e| e.step);
        entries.dedup_by_key(|e| e.step);
        Ok(CheckpointRing { dir, keep, entries, last_good })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn entries(&self) -> &[RingEntry] {
        &self.entries
    }

    pub fn last_good(&self) -> Option<u32> {
        self.last_good
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Atomically save `ck` into the ring, prune to the newest `keep`
    /// snapshots, and persist the manifest. Honors the [`KILL_ENV`] crash
    /// hook (writes a torn file and aborts) when it names this step.
    pub fn save(&mut self, ck: &Checkpoint) -> anyhow::Result<()> {
        let file = entry_file(ck.step);
        let path = self.dir.join(&file);
        if let Ok(spec) = std::env::var(KILL_ENV) {
            if spec == format!("torn:{}", ck.step) {
                let bytes = ck.to_bytes();
                // Simulate a non-atomic writer killed mid-save: a partial
                // file under the final name, no fsync, no manifest update.
                std::fs::write(&path, &bytes[..bytes.len() / 2])?;
                eprintln!(
                    "[ckpt-ring] {KILL_ENV} hook: wrote torn checkpoint for step {} and aborting",
                    ck.step
                );
                std::process::exit(3);
            }
        }
        ck.save(&path)?;
        self.entries.retain(|e| e.step != ck.step);
        self.entries.push(RingEntry { step: ck.step, file });
        self.entries.sort_by_key(|e| e.step);
        while self.entries.len() > self.keep {
            let old = self.entries.remove(0);
            std::fs::remove_file(self.dir.join(&old.file)).ok();
        }
        self.last_good = Some(ck.step);
        self.write_manifest()
    }

    fn write_manifest(&self) -> anyhow::Result<()> {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                json::obj(vec![
                    ("step", json::num(e.step as f64)),
                    ("file", json::s(&e.file)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("version", json::num(1.0)),
            ("keep", json::num(self.keep.min(u32::MAX as usize) as f64)),
            ("entries", Json::Arr(entries)),
        ];
        if let Some(step) = self.last_good {
            fields.push(("last_good", json::num(step as f64)));
        }
        let text = json::obj(fields).to_string_pretty();
        write_atomic(&self.dir.join(MANIFEST), text.as_bytes())
    }

    /// Load the newest entry that passes integrity checks, walking backwards
    /// past torn/corrupt/missing files. Returns the checkpoint and how many
    /// newer candidates were skipped (0 = the newest file was good).
    pub fn load_newest_valid(&mut self) -> anyhow::Result<(Checkpoint, usize)> {
        anyhow::ensure!(!self.entries.is_empty(), "checkpoint ring is empty");
        let mut skipped = 0usize;
        for e in self.entries.iter().rev() {
            match Checkpoint::load(self.dir.join(&e.file)) {
                Ok(ck) => {
                    self.last_good = Some(e.step);
                    return Ok((ck, skipped));
                }
                Err(err) => {
                    eprintln!(
                        "[ckpt-ring] skipping {} (step {}): {err}",
                        e.file, e.step
                    );
                    skipped += 1;
                }
            }
        }
        anyhow::bail!("no valid checkpoint in ring at {}", self.dir.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cocodc_ring_{name}"));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn ck(step: u32) -> Checkpoint {
        let mut c = Checkpoint::new(step);
        c.insert("x", vec![step as f32; 32]);
        c
    }

    #[test]
    fn ring_prunes_to_keep_and_tracks_last_good() {
        let d = tmp_dir("prune");
        let mut r = CheckpointRing::new(&d, 3).unwrap();
        for step in [10, 20, 30, 40, 50] {
            r.save(&ck(step)).unwrap();
        }
        assert_eq!(
            r.entries().iter().map(|e| e.step).collect::<Vec<_>>(),
            vec![30, 40, 50]
        );
        assert_eq!(r.last_good(), Some(50));
        assert!(!d.join(entry_file(10)).exists());
        assert!(d.join(entry_file(30)).exists());
        let (back, skipped) = r.load_newest_valid().unwrap();
        assert_eq!((back.step, skipped), (50, 0));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn load_newest_valid_skips_torn_newest_file() {
        let d = tmp_dir("torn");
        let mut r = CheckpointRing::new(&d, 4).unwrap();
        r.save(&ck(10)).unwrap();
        r.save(&ck(20)).unwrap();
        r.save(&ck(30)).unwrap();
        // Tear the newest file in half, as a killed non-atomic writer would.
        let newest = d.join(entry_file(30));
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let (back, skipped) = r.load_newest_valid().unwrap();
        assert_eq!((back.step, skipped), (20, 1));
        assert_eq!(back, ck(20));
        assert_eq!(r.last_good(), Some(20));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn reopen_without_manifest_falls_back_to_directory_scan() {
        let d = tmp_dir("scan");
        let mut r = CheckpointRing::new(&d, 4).unwrap();
        r.save(&ck(10)).unwrap();
        r.save(&ck(20)).unwrap();
        std::fs::remove_file(d.join(MANIFEST)).unwrap();
        let mut r2 = CheckpointRing::new(&d, 4).unwrap();
        assert_eq!(
            r2.entries().iter().map(|e| e.step).collect::<Vec<_>>(),
            vec![10, 20]
        );
        let (back, skipped) = r2.load_newest_valid().unwrap();
        assert_eq!((back.step, skipped), (20, 0));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn save_dedups_same_step_entries() {
        let d = tmp_dir("dedup");
        let mut r = CheckpointRing::new(&d, 3).unwrap();
        r.save(&ck(10)).unwrap();
        r.save(&ck(10)).unwrap();
        assert_eq!(r.entries().len(), 1);
        std::fs::remove_dir_all(&d).ok();
    }
}
