//! Checkpointing: named f32 sections in a simple length-prefixed binary
//! format with an FNV-1a integrity checksum. Stores the full training state
//! (per-worker params + inner optimizer moments, global fragment states,
//! outer momentum) so long cross-region runs can resume after preemption.
//!
//! Format v2 extends the checksum to cover the header and every length field
//! (v1 hashed only section names + payloads), so a bit-flip anywhere after
//! the magic is detected instead of silently changing `step` or a section
//! length. Saves are atomic: tmp file + fsync + rename + directory fsync,
//! so a crash mid-save can never destroy an existing good file. See
//! [`ring::CheckpointRing`] for the durable last-K ring with manifest.

pub mod ring;

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"CCDC";
const VERSION: u32 = 2;
const FNV_BASIS: u64 = 0xcbf29ce484222325;

/// A checkpoint is an ordered map of named f32 vectors plus a step counter.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub step: u32,
    pub sections: BTreeMap<String, Vec<f32>>,
}

fn fnv1a(bytes: &[u8], mut hash: u64) -> u64 {
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100000001b3);
    }
    hash
}

/// FNV-1a over the little-endian bytes of an f32 slice. This is the same
/// hash the checkpoint file format uses over section payloads, reused as the
/// per-fragment WAN payload checksum so integrity is one algorithm everywhere.
pub fn checksum_f32(data: &[f32]) -> u64 {
    let mut hash = FNV_BASIS;
    for x in data {
        hash = fnv1a(&x.to_le_bytes(), hash);
    }
    hash
}

/// Pack a u64 into two f32 *bit patterns* (lossless — sections store f32,
/// but run-context counters/RNG states must round-trip exactly).
pub fn pack_u64(x: u64) -> [f32; 2] {
    [f32::from_bits(x as u32), f32::from_bits((x >> 32) as u32)]
}

pub fn unpack_u64(lo: f32, hi: f32) -> u64 {
    (lo.to_bits() as u64) | ((hi.to_bits() as u64) << 32)
}

/// Bit-exact f64 packing (via its u64 representation).
pub fn pack_f64(x: f64) -> [f32; 2] {
    pack_u64(x.to_bits())
}

pub fn unpack_f64(lo: f32, hi: f32) -> f64 {
    f64::from_bits(unpack_u64(lo, hi))
}

/// Append each u64 as its two-f32 bit pattern.
pub fn pack_u64s(out: &mut Vec<f32>, xs: &[u64]) {
    for &x in xs {
        out.extend_from_slice(&pack_u64(x));
    }
}

/// Inverse of [`pack_u64s`] over a `2*n`-element slice.
pub fn unpack_u64s(data: &[f32]) -> Vec<u64> {
    data.chunks_exact(2).map(|c| unpack_u64(c[0], c[1])).collect()
}

pub fn pack_f64s(out: &mut Vec<f32>, xs: &[f64]) {
    for &x in xs {
        out.extend_from_slice(&pack_f64(x));
    }
}

pub fn unpack_f64s(data: &[f32]) -> Vec<f64> {
    data.chunks_exact(2).map(|c| unpack_f64(c[0], c[1])).collect()
}

fn tmp_sibling(path: &Path) -> std::path::PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "ckpt".to_string());
    path.with_file_name(format!("{name}.tmp"))
}

/// Crash-safe file replacement: write a sibling tmp file, fsync it, rename
/// over the target, then fsync the parent directory. A crash at any point
/// leaves either the old file or the new one, never a torn mix.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let tmp = tmp_sibling(path);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                d.sync_all().ok();
            }
        }
    }
    Ok(())
}

impl Checkpoint {
    pub fn new(step: u32) -> Self {
        Checkpoint { step, sections: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, data: Vec<f32>) {
        self.sections.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.sections.get(name).map(|v| v.as_slice())
    }

    /// Serialize to the v2 on-disk byte layout (including trailing hash).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        let mut hash = FNV_BASIS;
        for word in [VERSION, self.step, self.sections.len() as u32] {
            let b = word.to_le_bytes();
            out.extend_from_slice(&b);
            hash = fnv1a(&b, hash);
        }
        for (name, data) in &self.sections {
            let nb = name.as_bytes();
            let nlen = (nb.len() as u32).to_le_bytes();
            out.extend_from_slice(&nlen);
            hash = fnv1a(&nlen, hash);
            out.extend_from_slice(nb);
            hash = fnv1a(nb, hash);
            let dlen = (data.len() as u64).to_le_bytes();
            out.extend_from_slice(&dlen);
            hash = fnv1a(&dlen, hash);
            let start = out.len();
            out.reserve(data.len() * 4);
            for x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
            hash = fnv1a(&out[start..], hash);
        }
        out.extend_from_slice(&hash.to_le_bytes());
        out
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        write_atomic(path, &self.to_bytes())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let file_len = std::fs::metadata(path.as_ref())?.len();
        let mut f = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a CoCoDC checkpoint");
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        anyhow::ensure!(
            version == 1 || version == VERSION,
            "unsupported checkpoint version {version}"
        );
        // v1 hashed only section names + payloads; v2 covers everything
        // after the magic, so header/length bit-flips are detected too.
        let hash_all = version >= 2;
        let mut hash = FNV_BASIS;
        if hash_all {
            hash = fnv1a(&u32b, hash);
        }
        f.read_exact(&mut u32b)?;
        let step = u32::from_le_bytes(u32b);
        if hash_all {
            hash = fnv1a(&u32b, hash);
        }
        f.read_exact(&mut u32b)?;
        let n_sections = u32::from_le_bytes(u32b) as usize;
        if hash_all {
            hash = fnv1a(&u32b, hash);
        }
        // Payload bytes can never exceed what the file holds beyond the
        // 16-byte header and 8-byte trailing hash; validating lengths against
        // this budget keeps a corrupted length field from triggering an
        // arbitrary-size allocation before read_exact gets a chance to fail.
        let mut remaining = file_len.saturating_sub(16 + 8);
        let mut sections = BTreeMap::new();
        for _ in 0..n_sections {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            anyhow::ensure!(name_len <= 4096, "corrupt section name length");
            if hash_all {
                hash = fnv1a(&u32b, hash);
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            hash = fnv1a(&name, hash);
            let mut u64b = [0u8; 8];
            f.read_exact(&mut u64b)?;
            if hash_all {
                hash = fnv1a(&u64b, hash);
            }
            let len64 = u64::from_le_bytes(u64b);
            let byte_len = match len64.checked_mul(4) {
                Some(b) if b <= remaining => b as usize,
                _ => anyhow::bail!(
                    "corrupt checkpoint: section length {len64} exceeds file size"
                ),
            };
            remaining -= byte_len as u64;
            let mut bytes = vec![0u8; byte_len];
            f.read_exact(&mut bytes)?;
            hash = fnv1a(&bytes, hash);
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            sections.insert(String::from_utf8(name)?, data);
        }
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        anyhow::ensure!(
            u64::from_le_bytes(u64b) == hash,
            "checkpoint checksum mismatch (truncated or corrupted file)"
        );
        Ok(Checkpoint { step, sections })
    }

    /// Load the newest checkpoint in a ring directory that passes integrity
    /// checks, skipping torn/corrupt files. Returns the checkpoint and how
    /// many newer candidates were skipped.
    pub fn load_newest_valid<P: AsRef<Path>>(dir: P) -> anyhow::Result<(Self, usize)> {
        let mut r = ring::CheckpointRing::new(dir.as_ref(), usize::MAX)?;
        r.load_newest_valid()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cocodc_ckpt_{name}.bin"))
    }

    #[test]
    fn save_load_round_trip() {
        let mut c = Checkpoint::new(123);
        c.insert("worker0/params", vec![1.0, -2.5, 3.25]);
        c.insert("global/frag1", vec![0.0; 100]);
        let p = tmp("roundtrip");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, c);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut c = Checkpoint::new(1);
        c.insert("x", vec![1.0; 64]);
        let p = tmp("corrupt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn save_leaves_no_tmp_file_and_replaces_atomically() {
        let p = tmp("atomic");
        let mut c = Checkpoint::new(1);
        c.insert("x", vec![1.0; 8]);
        c.save(&p).unwrap();
        let mut c2 = Checkpoint::new(2);
        c2.insert("x", vec![2.0; 8]);
        c2.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, c2);
        let tmp_path = p.with_file_name(format!(
            "{}.tmp",
            p.file_name().unwrap().to_string_lossy()
        ));
        assert!(!tmp_path.exists(), "atomic save left tmp file behind");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_length_field_is_rejected_without_huge_alloc() {
        // Flip the section data-length field to u64::MAX: load must Err
        // (validated against file size) instead of attempting a ~2^66-byte
        // allocation and aborting.
        let mut c = Checkpoint::new(7);
        c.insert("x", vec![1.0; 16]);
        let p = tmp("hugelen");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Layout: magic(4) version(4) step(4) n_sections(4) name_len(4)
        // name(1, "x") data_len(8) ...
        let off = 4 + 4 + 4 + 4 + 4 + 1;
        bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn header_bit_flips_are_detected_in_v2() {
        // v1's hash covered only names + payloads, so a flipped `step` field
        // loaded "successfully" with the wrong step. v2 must reject it.
        let mut c = Checkpoint::new(1000);
        c.insert("x", vec![3.0; 8]);
        let p = tmp("headerflip");
        c.save(&p).unwrap();
        let clean = std::fs::read(&p).unwrap();
        for off in 4..16 {
            let mut bytes = clean.clone();
            bytes[off] ^= 0x01;
            std::fs::write(&p, &bytes).unwrap();
            assert!(
                Checkpoint::load(&p).is_err(),
                "flip at header offset {off} was not detected"
            );
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn checksum_f32_matches_byte_stream_hash() {
        let data = vec![1.5f32, -2.25, 0.0, f32::from_bits(0xFFFF_FFFF)];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(checksum_f32(&data), fnv1a(&bytes, FNV_BASIS));
        assert_ne!(checksum_f32(&[1.0]), checksum_f32(&[-1.0]));
    }

    #[test]
    fn packing_is_bit_exact() {
        for x in [0u64, 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D, 1 << 63] {
            let [lo, hi] = pack_u64(x);
            assert_eq!(unpack_u64(lo, hi), x);
        }
        for x in [0.0f64, -1.5, f64::MAX, 1e-300, std::f64::consts::PI] {
            let [lo, hi] = pack_f64(x);
            assert_eq!(unpack_f64(lo, hi).to_bits(), x.to_bits());
        }
        // Round-trip *through a saved file* too: NaN-pattern f32s must
        // survive serialization byte-for-byte.
        let mut c = Checkpoint::new(0);
        let [lo, hi] = pack_u64(0xFFFF_FFFF_FFFF_FFFF);
        c.insert("ctx", vec![lo, hi]);
        let p = tmp("packing");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        let s = back.get("ctx").unwrap();
        assert_eq!(unpack_u64(s[0], s[1]), u64::MAX);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn slice_packing_round_trips_bit_exactly() {
        let us = [0u64, 7, u64::MAX, 1 << 63];
        let mut buf = Vec::new();
        pack_u64s(&mut buf, &us);
        assert_eq!(buf.len(), 8);
        assert_eq!(unpack_u64s(&buf), us);
        let fs = [0.0f64, -1.5, f64::INFINITY, f64::MAX, 1e-300];
        let mut buf = Vec::new();
        pack_f64s(&mut buf, &fs);
        let back = unpack_f64s(&buf);
        for (a, b) in fs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_non_checkpoint() {
        let p = tmp("garbage");
        std::fs::write(&p, b"hello world").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
