//! Checkpointing: named f32 sections in a simple length-prefixed binary
//! format with an FNV-1a integrity checksum. Stores the full training state
//! (per-worker params + inner optimizer moments, global fragment states,
//! outer momentum) so long cross-region runs can resume after preemption.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"CCDC";
const VERSION: u32 = 1;

/// A checkpoint is an ordered map of named f32 vectors plus a step counter.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub step: u32,
    pub sections: BTreeMap<String, Vec<f32>>,
}

fn fnv1a(bytes: &[u8], mut hash: u64) -> u64 {
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x100000001b3);
    }
    hash
}

/// Pack a u64 into two f32 *bit patterns* (lossless — sections store f32,
/// but run-context counters/RNG states must round-trip exactly).
pub fn pack_u64(x: u64) -> [f32; 2] {
    [f32::from_bits(x as u32), f32::from_bits((x >> 32) as u32)]
}

pub fn unpack_u64(lo: f32, hi: f32) -> u64 {
    (lo.to_bits() as u64) | ((hi.to_bits() as u64) << 32)
}

/// Bit-exact f64 packing (via its u64 representation).
pub fn pack_f64(x: f64) -> [f32; 2] {
    pack_u64(x.to_bits())
}

pub fn unpack_f64(lo: f32, hi: f32) -> f64 {
    f64::from_bits(unpack_u64(lo, hi))
}

/// Append each u64 as its two-f32 bit pattern.
pub fn pack_u64s(out: &mut Vec<f32>, xs: &[u64]) {
    for &x in xs {
        out.extend_from_slice(&pack_u64(x));
    }
}

/// Inverse of [`pack_u64s`] over a `2*n`-element slice.
pub fn unpack_u64s(data: &[f32]) -> Vec<u64> {
    data.chunks_exact(2).map(|c| unpack_u64(c[0], c[1])).collect()
}

pub fn pack_f64s(out: &mut Vec<f32>, xs: &[f64]) {
    for &x in xs {
        out.extend_from_slice(&pack_f64(x));
    }
}

pub fn unpack_f64s(data: &[f32]) -> Vec<f64> {
    data.chunks_exact(2).map(|c| unpack_f64(c[0], c[1])).collect()
}

impl Checkpoint {
    pub fn new(step: u32) -> Self {
        Checkpoint { step, sections: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, data: Vec<f32>) {
        self.sections.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.sections.get(name).map(|v| v.as_slice())
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.sections.len() as u32).to_le_bytes())?;
        let mut hash = 0xcbf29ce484222325u64;
        for (name, data) in &self.sections {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(data.len() as u64).to_le_bytes())?;
            // SAFETY-free: serialize via to_le_bytes per element would be
            // slow; reinterpret through chunks instead.
            let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
            hash = fnv1a(nb, hash);
            hash = fnv1a(&bytes, hash);
        }
        f.write_all(&hash.to_le_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a CoCoDC checkpoint");
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        anyhow::ensure!(u32::from_le_bytes(u32b) == VERSION, "version mismatch");
        f.read_exact(&mut u32b)?;
        let step = u32::from_le_bytes(u32b);
        f.read_exact(&mut u32b)?;
        let n_sections = u32::from_le_bytes(u32b) as usize;
        let mut sections = BTreeMap::new();
        let mut hash = 0xcbf29ce484222325u64;
        for _ in 0..n_sections {
            f.read_exact(&mut u32b)?;
            let name_len = u32::from_le_bytes(u32b) as usize;
            anyhow::ensure!(name_len <= 4096, "corrupt section name length");
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let mut u64b = [0u8; 8];
            f.read_exact(&mut u64b)?;
            let len = u64::from_le_bytes(u64b) as usize;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            hash = fnv1a(&name, hash);
            hash = fnv1a(&bytes, hash);
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            sections.insert(String::from_utf8(name)?, data);
        }
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        anyhow::ensure!(
            u64::from_le_bytes(u64b) == hash,
            "checkpoint checksum mismatch (truncated or corrupted file)"
        );
        Ok(Checkpoint { step, sections })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cocodc_ckpt_{name}.bin"))
    }

    #[test]
    fn save_load_round_trip() {
        let mut c = Checkpoint::new(123);
        c.insert("worker0/params", vec![1.0, -2.5, 3.25]);
        c.insert("global/frag1", vec![0.0; 100]);
        let p = tmp("roundtrip");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, c);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_detected() {
        let mut c = Checkpoint::new(1);
        c.insert("x", vec![1.0; 64]);
        let p = tmp("corrupt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn packing_is_bit_exact() {
        for x in [0u64, 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D, 1 << 63] {
            let [lo, hi] = pack_u64(x);
            assert_eq!(unpack_u64(lo, hi), x);
        }
        for x in [0.0f64, -1.5, f64::MAX, 1e-300, std::f64::consts::PI] {
            let [lo, hi] = pack_f64(x);
            assert_eq!(unpack_f64(lo, hi).to_bits(), x.to_bits());
        }
        // Round-trip *through a saved file* too: NaN-pattern f32s must
        // survive serialization byte-for-byte.
        let mut c = Checkpoint::new(0);
        let [lo, hi] = pack_u64(0xFFFF_FFFF_FFFF_FFFF);
        c.insert("ctx", vec![lo, hi]);
        let p = tmp("packing");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        let s = back.get("ctx").unwrap();
        assert_eq!(unpack_u64(s[0], s[1]), u64::MAX);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn slice_packing_round_trips_bit_exactly() {
        let us = [0u64, 7, u64::MAX, 1 << 63];
        let mut buf = Vec::new();
        pack_u64s(&mut buf, &us);
        assert_eq!(buf.len(), 8);
        assert_eq!(unpack_u64s(&buf), us);
        let fs = [0.0f64, -1.5, f64::INFINITY, f64::MAX, 1e-300];
        let mut buf = Vec::new();
        pack_f64s(&mut buf, &fs);
        let back = unpack_f64s(&buf);
        for (a, b) in fs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_non_checkpoint() {
        let p = tmp("garbage");
        std::fs::write(&p, b"hello world").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
